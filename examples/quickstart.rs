//! Quickstart: the library in ~40 lines.
//!
//! Spawns 8 in-process ranks, runs the paper's reduce-scatter
//! (Algorithm 1) and allreduce (Algorithm 2) through the MPI-like
//! [`Communicator`] API, and prints the Theorem 1/2 counters.
//!
//! Run: `cargo run --release --example quickstart`

use circulant_collectives::coordinator::Launcher;
use circulant_collectives::util::ceil_log2;

fn main() {
    let p = 8; // ranks (any p works — that is the paper's point)
    let b = 1024; // elements per block

    let results = Launcher::new(p).run(move |mut comm| {
        let rank = comm.rank();
        let p = comm.size();

        // --- MPI_Reduce_scatter_block ---------------------------------
        // Every rank contributes p blocks; rank r gets block r reduced.
        let send: Vec<f32> = (0..p * b).map(|j| (rank + j) as f32).collect();
        let mut mine = vec![0.0f32; b];
        comm.reduce_scatter_block(&send, &mut mine, "sum").unwrap();

        // --- MPI_Allreduce ---------------------------------------------
        let mut vec_sum = vec![rank as f32; 4];
        comm.allreduce(&mut vec_sum, "sum").unwrap();

        (mine[0], vec_sum[0], comm.counters())
    });

    // Verify against the closed-form oracle and report.
    let expect_rs0 = |r: usize| -> f32 { (0..p).map(|src| (src + r * b) as f32).sum() };
    let expect_ar = (0..p).map(|r| r as f32).sum::<f32>();
    for (r, (rs0, ar, _)) in results.iter().enumerate() {
        assert_eq!(*rs0, expect_rs0(r), "reduce-scatter block {r}");
        assert_eq!(*ar, expect_ar, "allreduce at rank {r}");
    }
    let c = &results[0].2;
    println!("p = {p}, block = {b} f32");
    println!("reduce-scatter + allreduce completed and verified ✓");
    println!(
        "rounds used: {} (Theorem 1: ⌈log2 {p}⌉ = {} for RS, 2⌈log2 {p}⌉ = {} for AR, +1 tiny AR)",
        c.sendrecv_rounds,
        ceil_log2(p),
        2 * ceil_log2(p),
    );
    println!(
        "elements sent per rank: {} (optimal volume: RS (p−1)·b = {}, AR 2(p−1)·m/p)",
        c.elems_sent,
        (p - 1) * b,
    );
}
