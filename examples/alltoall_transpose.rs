//! Distributed matrix transpose via all-to-all (paper §4).
//!
//! A classic workload for MPI_Alltoall: a dense `N×N` matrix is stored
//! row-sharded across `p` ranks; transposing it requires every rank to
//! exchange a tile with every other. We run the paper's circulant
//! all-to-all (⊕ = concatenation, `⌈log2 p⌉` rounds) and check the result
//! against a serial transpose, then compare its measured message volume
//! with the direct-exchange lower bound.
//!
//! Run: `cargo run --release --example alltoall_transpose [p] [n_per_rank]`

use circulant_collectives::collectives::alltoall::alltoall_send_volume;
use circulant_collectives::coordinator::Launcher;
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::topology::skips::SkipScheme;
use circulant_collectives::util::ceil_log2;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16); // rows per rank
    let n = p * rows; // global N×N matrix

    // Rank r owns rows [r·rows, (r+1)·rows). Tile (r→g) is the rows of r
    // restricted to columns owned by g — a rows×rows tile, flattened.
    let tile = rows * rows;
    let results = Launcher::new(p).run(move |mut comm| {
        let r = comm.rank();
        // Build my row shard of A with A[i][j] = i*N + j.
        let mut send = vec![0.0f32; p * tile];
        for g in 0..p {
            for i in 0..rows {
                for j in 0..rows {
                    let gi = r * rows + i; // global row
                    let gj = g * rows + j; // global col
                    send[g * tile + i * rows + j] = (gi * n + gj) as f32;
                }
            }
        }
        let recv = comm.alltoall(&send, tile).unwrap();
        // Assemble my shard of Aᵀ: row gi of Aᵀ (for gi in my range) is
        // column gi of A; tile from rank g supplies its rows.
        let mut out = vec![0.0f32; rows * n];
        for g in 0..p {
            for i in 0..rows {
                for j in 0..rows {
                    // recv[g*tile + i*rows + j] = A[g*rows + i][r*rows + j]
                    let v = recv[g * tile + i * rows + j];
                    // Aᵀ[r*rows + j][g*rows + i] = v
                    out[j * n + g * rows + i] = v;
                }
            }
        }
        (out, comm.counters())
    });

    // Verify: Aᵀ[i][j] == A[j][i] == j*N + i.
    for (r, (out, _)) in results.iter().enumerate() {
        for i in 0..rows {
            for j in 0..n {
                let gi = r * rows + i;
                assert_eq!(out[i * n + j], (j * n + gi) as f32, "rank {r} Aᵀ[{gi}][{j}]");
            }
        }
    }
    let c = &results[0].1;
    let m = p * tile;
    let part = BlockPartition::uniform(p, tile);
    let skips = SkipScheme::HalvingUp.skips(p).unwrap();
    let predicted = alltoall_send_volume(&part, &skips);
    println!("transposed a {n}×{n} matrix over p={p} ranks ✓");
    println!(
        "rounds: {} = ⌈log2 {p}⌉ (direct exchange would take p−1 = {})",
        ceil_log2(p),
        p - 1
    );
    println!(
        "payload sent per rank: {} elems (model predicts ≈ {}, direct exchange sends {});",
        c.elems_sent, predicted, m - tile,
    );
    println!("the log-round schedule trades ~(⌈log2 p⌉/2)× volume for (p−1)/⌈log2 p⌉× fewer rounds (§4).");
}
