//! End-to-end driver: data-parallel training with the paper's allreduce.
//!
//! Proves the three layers compose (DESIGN.md §5, row E2E):
//!   Layer 1 — Pallas combine kernel (sum), AOT-lowered;
//!   Layer 2 — JAX MLP fwd/bwd (`mlp_loss_grad.hlo.txt`), AOT-lowered;
//!   Layer 3 — Rust: thread network + Algorithm 2 allreduce of the flat
//!             gradient, γ term executed through PJRT.
//!
//! Workload: 4 workers × 300 SGD steps on a synthetic tanh-teacher
//! regression (74 497-parameter MLP, batch 64/worker). Prints the loss
//! curve and the per-step collective counters; the run is recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example train_allreduce [workers] [steps]`

use circulant_collectives::coordinator::{train, TrainConfig};
use circulant_collectives::runtime::default_artifact_dir;
use circulant_collectives::util::ceil_log2;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = TrainConfig::default();
    if let Some(w) = args.first().and_then(|s| s.parse().ok()) {
        cfg.workers = w;
    }
    if let Some(s) = args.get(1).and_then(|s| s.parse().ok()) {
        cfg.steps = s;
    }

    let dir = default_artifact_dir();
    println!(
        "training: {} workers × {} steps, lr {}, artifacts at {}",
        cfg.workers,
        cfg.steps,
        cfg.lr,
        dir.display()
    );
    let report = train(&dir, &cfg).expect("training run");

    println!("\nloss curve (mean over workers):");
    for (step, loss) in &report.losses {
        let bar = "#".repeat(((loss / report.first_loss).min(1.0) * 50.0) as usize);
        println!("  step {step:4}  {loss:.6}  {bar}");
    }
    println!(
        "\n{} params, loss {:.4} → {:.4} in {:.2}s ({:.1} steps/s)",
        report.params,
        report.first_loss,
        report.final_loss,
        report.wall_seconds,
        report.steps as f64 / report.wall_seconds
    );
    let p = report.workers;
    println!(
        "gradient allreduce per step: {} rounds (= 2⌈log2 {p}⌉ = {}), {} elems/worker (Theorem 2: 2(p−1)/p·P ≈ {})",
        report.rounds_per_allreduce,
        2 * ceil_log2(p),
        report.grad_elems_per_step,
        2 * (p - 1) * report.params / p
    );
    assert!(
        report.final_loss < report.first_loss * 0.5,
        "training failed to converge: {} → {}",
        report.first_loss,
        report.final_loss
    );
    println!("convergence check ✓ (final < 0.5 × initial)");
}
