//! Skip-scheme exploration — the experiment the paper calls for.
//!
//! §2.1: "It is an open, experimental question, which sequence of skips may
//! perform best in practice on a concrete high-performance system."
//! This example compares the four families of Corollary 2 (halving-up,
//! power-of-two, √p, fully-connected) plus a custom sequence, in three
//! regimes of the α-β-γ cost model, and verifies each symbolically.
//!
//! Run: `cargo run --release --example skip_schemes [p] [m]`

use circulant_collectives::collectives::{reduce_scatter_schedule, symbolic};
use circulant_collectives::datatypes::BlockPartition;
use circulant_collectives::sim::{simulate, CostModel};
use circulant_collectives::topology::skips::{max_send_run, SkipScheme};
use circulant_collectives::util::table::{fmt_si, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(100);
    let m: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1 << 20);

    let mut schemes = vec![
        SkipScheme::HalvingUp,
        SkipScheme::PowerOfTwo,
        SkipScheme::Sqrt,
        SkipScheme::FullyConnected,
    ];
    // A custom sequence: halve twice as aggressively where valid (falls
    // back to halving-up structure when the in-place condition binds).
    if let Ok(halving) = SkipScheme::HalvingUp.skips(p) {
        let custom: Vec<usize> = halving.iter().map(|&s| s).collect();
        schemes.push(SkipScheme::Custom(custom));
    }

    let regimes = [
        ("latency-bound", CostModel::latency_bound()),
        ("cluster", CostModel::cluster()),
        ("bandwidth-bound", CostModel::bandwidth_bound()),
    ];

    let part = BlockPartition::regular(p, m);
    let mut t = Table::new(
        &format!("reduce-scatter skip schemes, p={p}, m={m}"),
        &["scheme", "rounds", "max run (blocks)", "latency-bound", "cluster", "bandwidth-bound"],
    );
    for scheme in &schemes {
        let skips = match scheme.skips(p) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{}: rejected ({e})", scheme.name());
                continue;
            }
        };
        let sched = reduce_scatter_schedule(p, &skips);
        sched.assert_valid();
        symbolic::verify_reduce_scatter(&sched).expect("symbolically correct");
        let mut cells = vec![
            scheme.name(),
            skips.len().to_string(),
            format!("{} (≤⌈p/2⌉={})", max_send_run(p, &skips), p.div_ceil(2)),
        ];
        for (_, model) in &regimes {
            let sim = simulate(&sched, &part, model);
            cells.push(format!("{}s", fmt_si(sim.total)));
        }
        t.row(&cells);
    }
    t.print();

    println!("Reading: all schemes move exactly p−1 = {} blocks per rank (volume", p - 1);
    println!("optimality holds for ANY valid sequence, Corollary 2); they differ only");
    println!("in round count — so fully-connected loses once α matters, and sqrt");
    println!("interpolates. Halving-up additionally bounds every message run by ⌈p/2⌉");
    println!("blocks (§3), which power-of-two does not.");
}
