//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT artifact (an HLO-text module plus its signature).
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub file: PathBuf,
    pub kind: ArtifactKind,
    /// Operator name for combine artifacts ("sum"/"prod"/"min"/"max"),
    /// "fma" for combine_scaled, "none" for models.
    pub op: String,
    /// Bucket length (combine) or parameter count (mlp).
    pub n: usize,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Combine,
    CombineScaled,
    MlpLossGrad,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "combine" => Some(Self::Combine),
            "combine_scaled" => Some(Self::CombineScaled),
            "mlp_loss_grad" => Some(Self::MlpLossGrad),
            _ => None,
        }
    }
}

/// MLP architecture constants recorded by the AOT step (the Rust training
/// driver sizes its buffers from these, never hard-coding python values).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpMeta {
    pub params: usize,
    pub d_in: usize,
    pub hidden: usize,
    pub d_out: usize,
    pub batch: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<usize>,
    pub ops: Vec<String>,
    pub mlp: MlpMeta,
    pub artifacts: Vec<Artifact>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("cannot read manifest {path}: {source}")]
    Io { path: PathBuf, source: std::io::Error },
    #[error("manifest parse error: {0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("manifest format {got} unsupported (want 1)")]
    Format { got: usize },
    #[error("manifest missing/invalid field: {0}")]
    Field(&'static str),
}

fn shape_list(j: &Json) -> Option<Vec<Vec<usize>>> {
    j.as_arr()?
        .iter()
        .map(|s| s.as_arr().map(|dims| dims.iter().filter_map(Json::as_usize).collect()))
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|source| ManifestError::Io { path: path.clone(), source })?;
        let j = Json::parse(&text)?;
        let format = j.get("format").and_then(Json::as_usize).ok_or(ManifestError::Field("format"))?;
        if format != 1 {
            return Err(ManifestError::Format { got: format });
        }
        let buckets = j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or(ManifestError::Field("buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let ops = j
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or(ManifestError::Field("ops"))?
            .iter()
            .filter_map(|x| x.as_str().map(str::to_string))
            .collect();
        let mlp_j = j.get("mlp").ok_or(ManifestError::Field("mlp"))?;
        let geti = |k: &'static str| -> Result<usize, ManifestError> {
            mlp_j.get(k).and_then(Json::as_usize).ok_or(ManifestError::Field(k))
        };
        let mlp = MlpMeta {
            params: geti("params")?,
            d_in: geti("d_in")?,
            hidden: geti("hidden")?,
            d_out: geti("d_out")?,
            batch: geti("batch")?,
        };
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).ok_or(ManifestError::Field("artifacts"))? {
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .and_then(ArtifactKind::parse)
                .ok_or(ManifestError::Field("kind"))?;
            artifacts.push(Artifact {
                file: dir.join(a.get("file").and_then(Json::as_str).ok_or(ManifestError::Field("file"))?),
                kind,
                op: a.get("op").and_then(Json::as_str).unwrap_or("none").to_string(),
                n: a.get("n").and_then(Json::as_usize).ok_or(ManifestError::Field("n"))?,
                inputs: a.get("inputs").and_then(shape_list).ok_or(ManifestError::Field("inputs"))?,
                outputs: a.get("outputs").and_then(shape_list).ok_or(ManifestError::Field("outputs"))?,
            });
        }
        Ok(Self { dir, buckets, ops, mlp, artifacts })
    }

    /// Find the combine artifact for `op` with the smallest bucket ≥ `n`.
    /// Falls back to the largest bucket (caller chunks) if `n` exceeds all.
    pub fn combine_bucket(&self, op: &str, n: usize) -> Option<&Artifact> {
        let mut best: Option<&Artifact> = None;
        let mut largest: Option<&Artifact> = None;
        for a in &self.artifacts {
            if a.kind != ArtifactKind::Combine || a.op != op {
                continue;
            }
            if largest.is_none_or(|l| a.n > l.n) {
                largest = Some(a);
            }
            if a.n >= n && best.is_none_or(|b| a.n < b.n) {
                best = Some(a);
            }
        }
        best.or(largest)
    }

    pub fn mlp_artifact(&self) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.kind == ArtifactKind::MlpLossGrad)
    }

    pub fn combine_scaled_bucket(&self, n: usize) -> Option<&Artifact> {
        let mut best: Option<&Artifact> = None;
        let mut largest: Option<&Artifact> = None;
        for a in &self.artifacts {
            if a.kind != ArtifactKind::CombineScaled {
                continue;
            }
            if largest.is_none_or(|l| a.n > l.n) {
                largest = Some(a);
            }
            if a.n >= n && best.is_none_or(|b| a.n < b.n) {
                best = Some(a);
            }
        }
        best.or(largest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let text = r#"{
          "format": 1, "jax": "0.8.2", "buckets": [8, 32],
          "ops": ["sum", "max"],
          "mlp": {"params": 10, "d_in": 2, "hidden": 3, "d_out": 1, "batch": 4},
          "artifacts": [
            {"file": "combine_sum_8.hlo.txt", "kind": "combine", "op": "sum",
             "n": 8, "inputs": [[8],[8]], "outputs": [[8]]},
            {"file": "combine_sum_32.hlo.txt", "kind": "combine", "op": "sum",
             "n": 32, "inputs": [[32],[32]], "outputs": [[32]]},
            {"file": "mlp.hlo.txt", "kind": "mlp_loss_grad", "op": "none",
             "n": 10, "inputs": [[10],[4,2],[4,1]], "outputs": [[],[10]]}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_selects_buckets() {
        let dir = std::env::temp_dir().join(format!("ccoll-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets, vec![8, 32]);
        assert_eq!(m.mlp.params, 10);
        assert_eq!(m.combine_bucket("sum", 5).unwrap().n, 8);
        assert_eq!(m.combine_bucket("sum", 8).unwrap().n, 8);
        assert_eq!(m.combine_bucket("sum", 9).unwrap().n, 32);
        // larger than all buckets → largest (caller chunks)
        assert_eq!(m.combine_bucket("sum", 100).unwrap().n, 32);
        assert!(m.combine_bucket("prod", 5).is_none());
        assert!(m.mlp_artifact().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_io_error() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(matches!(err, ManifestError::Io { .. }));
    }
}
