//! PJRT runtime: AOT artifact loading and execution (Layer 2/1 → Layer 3
//! bridge). See `engine` for the executable cache and `manifest` for the
//! python↔rust contract; [`ServiceOp`] adapts the AOT Pallas combine kernel to
//! the [`crate::ops::ReduceOp`] interface so collectives can run their γ
//! term through XLA.

#[cfg(feature = "pjrt")]
pub mod engine;
/// Stub engine when the `xla` bindings are unavailable (default build).
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;
pub mod service;

pub use engine::{Engine, EngineStats};
pub use service::{ComputeService, ServiceHandle, ServiceOp};
pub use manifest::{Artifact, ArtifactKind, Manifest, ManifestError};

// NOTE: `PjRtClient` is `Rc`-based (not `Send`), so the [`Engine`] is
// thread-confined. Cross-thread access goes through the compute service
// ([`ComputeService`] / [`ServiceOp`]); single-thread code (benches, the
// perf harness) may use [`Engine`] directly.

/// Default artifact directory: `$CCOLL_ARTIFACTS` or `artifacts/` found by
/// walking up from the current directory.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("CCOLL_ARTIFACTS") {
        return dir.into();
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
