//! PJRT execution engine: load AOT HLO-text artifacts, compile once,
//! execute from the Layer-3 hot path.
//!
//! This is the runtime half of the three-layer architecture: the HLO was
//! produced from the Layer-2 JAX graphs (which call the Layer-1 Pallas
//! kernels) by `python/compile/aot.py`; Python is never invoked here.
//!
//! Executables are compiled lazily and cached per artifact. Combine
//! requests are *shape-bucketed*: a request of `n` elements runs on the
//! smallest compiled bucket ≥ `n`, padded with the operator's identity;
//! requests larger than the largest bucket are chunked. Padding/chunking
//! policies are measured in the perf bench (`perf_hotpath`).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Artifact, Manifest};

/// A PJRT client plus the compiled-executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// Keyed by artifact file name.
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Execution counters (hot-path visibility for the perf pass).
    pub stats: Mutex<EngineStats>,
}

/// Counters for engine activity.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub padded_elems: u64,
    pub chunked_calls: u64,
}

impl Engine {
    /// Create a CPU PJRT engine over the artifacts in `dir`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir).context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { manifest, client, cache: Mutex::new(HashMap::new()), stats: Mutex::new(EngineStats::default()) })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for an artifact.
    fn executable(&self, art: &Artifact) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = art.file.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let path = art.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.stats.lock().unwrap().compiles += 1;
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Warm the cache: compile every combine bucket for `op` (and the
    /// scaled/mlp artifacts if requested). Called at coordinator startup so
    /// compilation never happens on the request path.
    pub fn warmup(&self, ops: &[&str], scaled: bool, mlp: bool) -> Result<usize> {
        let mut compiled = 0;
        let artifacts: Vec<Artifact> = self.manifest.artifacts.clone();
        for art in &artifacts {
            let wanted = match art.kind {
                super::manifest::ArtifactKind::Combine => ops.contains(&art.op.as_str()),
                super::manifest::ArtifactKind::CombineScaled => scaled,
                super::manifest::ArtifactKind::MlpLossGrad => mlp,
            };
            if wanted {
                self.executable(art)?;
                compiled += 1;
            }
        }
        Ok(compiled)
    }

    /// Execute one bucket-sized combine: inputs must be exactly `art.n`.
    fn run_combine_exact(&self, art: &Artifact, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        debug_assert_eq!(a.len(), art.n);
        debug_assert_eq!(b.len(), art.n);
        let exe = self.executable(art)?;
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let result = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow!("execute combine: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        self.stats.lock().unwrap().executions += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Preferred chunk bucket for large combines.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): under CPU interpret-mode Pallas,
    /// per-call dispatch amortizes up to ~8 Ki elements but the lowered
    /// grid loop makes *larger* buckets slower per element, inverting the
    /// usual amortization — so big requests are chunked at the measured
    /// sweet spot instead of routed to the largest bucket. On a real TPU
    /// (Mosaic pipelines the grid) the largest bucket would win; override
    /// with `CCOLL_PJRT_CHUNK=<elems>`.
    fn preferred_chunk(&self) -> usize {
        // Parsed once per process by `crate::env_knobs` — malformed values
        // abort loudly at first use instead of silently defaulting.
        let want = crate::env_knobs::knobs().pjrt_chunk.unwrap_or(8192);
        // snap to an available bucket
        self.manifest
            .buckets
            .iter()
            .copied()
            .min_by_key(|&b| b.abs_diff(want))
            .unwrap_or(want)
    }

    /// `acc ⊕= other` through the AOT Pallas kernel, with bucketing,
    /// identity padding and chunking. `identity` must be ⊕'s identity.
    pub fn combine_into(&self, op: &str, acc: &mut [f32], other: &[f32], identity: f32) -> Result<()> {
        anyhow::ensure!(acc.len() == other.len(), "length mismatch");
        if acc.is_empty() {
            return Ok(());
        }
        let chunk = self.preferred_chunk();
        let mut off = 0usize;
        while off < acc.len() {
            let rest = acc.len() - off;
            // Throughput-aware policy: chunk long requests at the sweet
            // spot; route short (and tail) requests to the smallest
            // covering bucket.
            let want = if rest > chunk { chunk } else { rest };
            let art = self
                .manifest
                .combine_bucket(op, want)
                .ok_or_else(|| anyhow!("no combine artifact for op {op}"))?
                .clone();
            let take = art.n.min(rest);
            if take < acc.len() - off {
                self.stats.lock().unwrap().chunked_calls += 1;
            }
            let out = if take == art.n {
                self.run_combine_exact(&art, &acc[off..off + take], &other[off..off + take])?
            } else {
                // pad with identity up to the bucket
                let mut pa = vec![identity; art.n];
                let mut pb = vec![identity; art.n];
                pa[..take].copy_from_slice(&acc[off..off + take]);
                pb[..take].copy_from_slice(&other[off..off + take]);
                self.stats.lock().unwrap().padded_elems += (art.n - take) as u64;
                self.run_combine_exact(&art, &pa, &pb)?
            };
            acc[off..off + take].copy_from_slice(&out[..take]);
            off += take;
        }
        Ok(())
    }

    /// Diagnostic: run one combine on the *exact* bucket `n == art.n`,
    /// bypassing the chunking policy — used by `perf_hotpath` to profile
    /// buckets individually. Not a hot-path API.
    pub fn combine_bucket_exact(&self, op: &str, acc: &mut [f32], other: &[f32]) -> Result<()> {
        let art = self
            .manifest
            .combine_bucket(op, acc.len())
            .ok_or_else(|| anyhow!("no combine artifact for op {op}"))?
            .clone();
        anyhow::ensure!(art.n == acc.len(), "not an exact bucket: {} (nearest {})", acc.len(), art.n);
        let out = self.run_combine_exact(&art, acc, other)?;
        acc.copy_from_slice(&out);
        Ok(())
    }

    /// `r + scale·t` (fused gradient averaging), same bucketing rules.
    pub fn combine_scaled_into(&self, r: &mut [f32], t: &[f32], scale: f32) -> Result<()> {
        anyhow::ensure!(r.len() == t.len(), "length mismatch");
        if r.is_empty() {
            return Ok(());
        }
        let mut off = 0usize;
        while off < r.len() {
            let art = self
                .manifest
                .combine_scaled_bucket(r.len() - off)
                .ok_or_else(|| anyhow!("no combine_scaled artifact"))?
                .clone();
            let take = art.n.min(r.len() - off);
            let (pa, pb);
            let (sa, sb): (&[f32], &[f32]) = if take == art.n {
                (&r[off..off + take], &t[off..off + take])
            } else {
                pa = {
                    let mut v = vec![0.0f32; art.n];
                    v[..take].copy_from_slice(&r[off..off + take]);
                    v
                };
                pb = {
                    let mut v = vec![0.0f32; art.n];
                    v[..take].copy_from_slice(&t[off..off + take]);
                    v
                };
                self.stats.lock().unwrap().padded_elems += (art.n - take) as u64;
                (&pa[..], &pb[..])
            };
            let exe = self.executable(&art)?;
            let result = exe
                .execute::<xla::Literal>(&[
                    xla::Literal::vec1(sa),
                    xla::Literal::vec1(sb),
                    xla::Literal::scalar(scale),
                ])
                .map_err(|e| anyhow!("execute combine_scaled: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
            let out = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            r[off..off + take].copy_from_slice(&out[..take]);
            self.stats.lock().unwrap().executions += 1;
            off += take;
        }
        Ok(())
    }

    /// Run the MLP loss+grad artifact: `(loss, grad)` for flat `params`,
    /// batch `x` (row-major `[batch, d_in]`) and targets `y` (`[batch, d_out]`).
    pub fn mlp_loss_grad(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let meta = self.manifest.mlp;
        anyhow::ensure!(params.len() == meta.params, "params len {} != {}", params.len(), meta.params);
        anyhow::ensure!(x.len() == meta.batch * meta.d_in, "x len");
        anyhow::ensure!(y.len() == meta.batch * meta.d_out, "y len");
        let art = self.manifest.mlp_artifact().ok_or_else(|| anyhow!("no mlp artifact"))?.clone();
        let exe = self.executable(&art)?;
        let lp = xla::Literal::vec1(params);
        let lx = xla::Literal::vec1(x)
            .reshape(&[meta.batch as i64, meta.d_in as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let ly = xla::Literal::vec1(y)
            .reshape(&[meta.batch as i64, meta.d_out as i64])
            .map_err(|e| anyhow!("reshape y: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lp, lx, ly])
            .map_err(|e| anyhow!("execute mlp: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let (loss_l, grad_l) = result.to_tuple2().map_err(|e| anyhow!("untuple2: {e:?}"))?;
        let loss = loss_l.get_first_element::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?;
        let grad = grad_l.to_vec::<f32>().map_err(|e| anyhow!("grad: {e:?}"))?;
        self.stats.lock().unwrap().executions += 1;
        Ok((loss, grad))
    }
}
