//! Compute service: a dedicated thread owning the PJRT [`Engine`].
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the engine
//! cannot be shared across the rank threads directly. Instead the
//! coordinator runs one *compute service* thread that owns the engine —
//! the same shape as a real deployment where γ-work is offloaded to a
//! single accelerator queue — and rank threads submit combine / model
//! requests through a channel. [`ServiceOp`] adapts the handle to the
//! [`ReduceOp`] interface so the schedule executor is oblivious to the
//! backend. The hot combine path is zero-copy: the executor's slices are
//! passed to the service by pointer (sound because the submitter blocks
//! for the reply), not round-tripped through owned `Vec`s.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::Engine;
use crate::ops::ReduceOp;

/// A `*mut [f32]` that may cross the channel. Soundness: the submitting
/// thread constructs it from a live `&mut [f32]` and then **blocks on the
/// reply channel** until the service is done with the pointer, so the
/// borrow outlives every access and stays exclusive (see
/// [`ServiceHandle::combine_in_place`]).
struct RawSliceMut(*mut f32, usize);
unsafe impl Send for RawSliceMut {}

/// Shared-slice companion of [`RawSliceMut`], same blocking protocol.
struct RawSlice(*const f32, usize);
unsafe impl Send for RawSlice {}

enum Request {
    /// Zero-copy combine: the engine reduces straight into the caller's
    /// slice — no `to_vec` round-trips through the channel.
    CombineInPlace { op: &'static str, acc: RawSliceMut, other: RawSlice, identity: f32, reply: Sender<Result<()>> },
    CombineScaled { r: Vec<f32>, t: Vec<f32>, scale: f32, reply: Sender<Result<Vec<f32>>> },
    MlpLossGrad { params: Vec<f32>, x: Vec<f32>, y: Vec<f32>, reply: Sender<Result<(f32, Vec<f32>)>> },
    Stats { reply: Sender<super::EngineStats> },
    Shutdown,
}

/// Cloneable, `Send` handle to the compute service.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Request>,
}

/// The running service (join on drop of the owner).
pub struct ComputeService {
    pub handle: ServiceHandle,
    thread: Option<JoinHandle<()>>,
    shutdown_tx: Sender<Request>,
}

impl ComputeService {
    /// Spawn the service over the artifacts in `dir`, pre-compiling the
    /// given ops (plus scaled/mlp artifacts if flagged).
    pub fn start(
        dir: impl AsRef<std::path::Path>,
        warm_ops: Vec<String>,
        warm_scaled: bool,
        warm_mlp: bool,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let ops: Vec<&str> = warm_ops.iter().map(String::as_str).collect();
                if let Err(e) = engine.warmup(&ops, warm_scaled, warm_mlp) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
                let _ = ready_tx.send(Ok(()));
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::CombineInPlace { op, acc, other, identity, reply } => {
                            // SAFETY: the submitter blocks on `reply` for
                            // the whole call (combine_in_place), so both
                            // slices are live and unaliased right now, and
                            // all access ends before the reply is sent.
                            let acc = unsafe { std::slice::from_raw_parts_mut(acc.0, acc.1) };
                            let other = unsafe { std::slice::from_raw_parts(other.0, other.1) };
                            let _ = reply.send(engine.combine_into(op, acc, other, identity));
                        }
                        Request::CombineScaled { mut r, t, scale, reply } => {
                            let res = engine.combine_scaled_into(&mut r, &t, scale).map(|()| r);
                            let _ = reply.send(res);
                        }
                        Request::MlpLossGrad { params, x, y, reply } => {
                            let _ = reply.send(engine.mlp_loss_grad(&params, &x, &y));
                        }
                        Request::Stats { reply } => {
                            let _ = reply.send(engine.stats.lock().unwrap().clone());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn compute service");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("compute service died during startup"))??;
        Ok(Self { handle: ServiceHandle { tx: tx.clone() }, thread: Some(thread), shutdown_tx: tx })
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl ServiceHandle {
    /// Combine directly into the caller's slice — the zero-copy path the
    /// schedule executor uses. Blocks until the service thread finishes,
    /// which is what makes handing raw pointers across the channel sound.
    pub fn combine_in_place(
        &self,
        op: &'static str,
        acc: &mut [f32],
        other: &[f32],
        identity: f32,
    ) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::CombineInPlace {
                op,
                acc: RawSliceMut(acc.as_mut_ptr(), acc.len()),
                other: RawSlice(other.as_ptr(), other.len()),
                identity,
                reply,
            })
            .map_err(|_| anyhow!("compute service gone"))?;
        // Block until the service replies: the raw pointers must not
        // outlive this call. A dropped reply means the service exited and
        // no longer touches the slices.
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }

    pub fn combine_scaled(&self, r: Vec<f32>, t: Vec<f32>, scale: f32) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::CombineScaled { r, t, scale, reply })
            .map_err(|_| anyhow!("compute service gone"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }

    pub fn mlp_loss_grad(&self, params: Vec<f32>, x: Vec<f32>, y: Vec<f32>) -> Result<(f32, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::MlpLossGrad { params, x, y, reply })
            .map_err(|_| anyhow!("compute service gone"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }

    pub fn stats(&self) -> Result<super::EngineStats> {
        let (reply, rx) = channel();
        self.tx.send(Request::Stats { reply }).map_err(|_| anyhow!("compute service gone"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))
    }
}

/// [`ReduceOp`] over the compute service — usable from any rank thread.
pub struct ServiceOp {
    handle: ServiceHandle,
    op: &'static str,
    identity: f32,
}

impl ServiceOp {
    pub fn new(handle: ServiceHandle, op: &str) -> Option<Self> {
        let (op, identity): (&'static str, f32) = match op {
            "sum" => ("sum", 0.0),
            "prod" => ("prod", 1.0),
            "min" => ("min", f32::INFINITY),
            "max" => ("max", f32::NEG_INFINITY),
            _ => return None,
        };
        Some(Self { handle, op, identity })
    }
}

impl ReduceOp for ServiceOp {
    fn name(&self) -> &'static str {
        self.op
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        self.handle
            .combine_in_place(self.op, acc, other, self.identity)
            .unwrap_or_else(|e| panic!("service combine({}): {e}", self.op));
    }

    fn identity(&self) -> f32 {
        self.identity
    }
}
