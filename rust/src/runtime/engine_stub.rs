//! Stub engine used when the crate is built **without** the `pjrt`
//! feature (the `xla` bindings are not on crates.io, so the default build
//! must not reference them — see `Cargo.toml`).
//!
//! The public surface mirrors `engine.rs` exactly; [`Engine::load`] always
//! fails, so the methods below are unreachable in practice but keep every
//! call site (compute service, benches, CLI) compiling. The native ⊕
//! backend, schedules, transport and simulator are unaffected.

use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;

/// Stand-in for the PJRT client + executable cache. Never constructed:
/// [`Engine::load`] errors out after validating the manifest.
pub struct Engine {
    pub manifest: Manifest,
    pub stats: Mutex<EngineStats>,
}

/// Counters for engine activity (same shape as the real engine's).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub padded_elems: u64,
    pub chunked_calls: u64,
}

const UNAVAILABLE: &str =
    "PJRT engine unavailable: built without the `pjrt` feature (xla bindings not linked)";

impl Engine {
    /// Always fails: the artifacts may exist, but there is no PJRT client
    /// to execute them without the `pjrt` feature.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _manifest = Manifest::load(&dir).context("loading artifact manifest")?;
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn warmup(&self, _ops: &[&str], _scaled: bool, _mlp: bool) -> Result<usize> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn combine_into(&self, _op: &str, _acc: &mut [f32], _other: &[f32], _identity: f32) -> Result<()> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn combine_bucket_exact(&self, _op: &str, _acc: &mut [f32], _other: &[f32]) -> Result<()> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn combine_scaled_into(&self, _r: &mut [f32], _t: &[f32], _scale: f32) -> Result<()> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn mlp_loss_grad(&self, _params: &[f32], _x: &[f32], _y: &[f32]) -> Result<(f32, Vec<f32>)> {
        Err(anyhow!(UNAVAILABLE))
    }
}
