//! Topology layer: skip sequences, circulant graphs, and the spanning
//! forests that prove the reduce-scatter schedule correct (paper §2.1).

pub mod circulant;
pub mod search;
pub mod skips;
pub mod spanning;

pub use circulant::Circulant;
pub use skips::{SkipError, SkipScheme};
pub use spanning::SpanningTree;
