//! The spanning forest behind Theorem 1's correctness argument.
//!
//! Work in *distance space*: for a root processor `r`, identify every other
//! processor `x` with its distance `d = (r − x) mod p ∈ {1, …, p−1}`; the
//! algorithm is vertex-transitive, so the forest is the same for every
//! root. Block `R[d]` (distance `d`) is sent exactly once — in the round
//! `k(d)` whose skips satisfy `σ_k ≤ d < σ_{k−1}` — and is folded into
//! `R[d − σ_k]`. This yields a forest that contracts to a single spanning
//! tree rooted at distance 0, with edge labels `σ_k`:
//!
//!   parent(d) = d − σ_{k(d)},   label(d) = σ_{k(d)}.
//!
//! The paper's path property (any `i` is a sum of distinct skips) is the
//! statement that following parents from `d` reaches 0 using strictly
//! decreasing labels.

use super::skips::validate;

/// Spanning tree (in distance space) induced by a valid skip sequence.
#[derive(Debug, Clone)]
pub struct SpanningTree {
    pub p: usize,
    pub skips: Vec<usize>,
    /// `parent[d]` for `d in 1..p`; `parent[0]` is 0 (root).
    pub parent: Vec<usize>,
    /// Round (1-based) in which block `d` is sent; 0 for the root.
    pub round_sent: Vec<usize>,
}

impl SpanningTree {
    /// Build the forest from a validated skip sequence.
    pub fn build(p: usize, skips: &[usize]) -> Self {
        validate(p, skips).expect("invalid skip sequence");
        let mut parent = vec![0usize; p];
        let mut round_sent = vec![0usize; p];
        let mut prev = p;
        for (k, &s) in skips.iter().enumerate() {
            for d in s..prev {
                parent[d] = d - s;
                round_sent[d] = k + 1;
            }
            prev = s;
        }
        Self { p, skips: skips.to_vec(), parent, round_sent }
    }

    /// Depth of distance-`d` node (root has depth 0).
    pub fn depth(&self, mut d: usize) -> usize {
        let mut depth = 0;
        while d != 0 {
            d = self.parent[d];
            depth += 1;
            assert!(depth <= self.p, "cycle in spanning tree");
        }
        depth
    }

    /// Path labels from `d` to the root — the distinct-skip decomposition
    /// of `d` the *schedule itself* realizes.
    pub fn decomposition(&self, mut d: usize) -> Vec<usize> {
        let mut labels = Vec::new();
        while d != 0 {
            let s = d - self.parent[d];
            labels.push(s);
            d = self.parent[d];
        }
        labels
    }

    /// `children[d]` lists direct children (allocated on demand).
    pub fn children(&self) -> Vec<Vec<usize>> {
        let mut ch = vec![Vec::new(); self.p];
        for d in 1..self.p {
            ch[self.parent[d]].push(d);
        }
        ch
    }

    /// Subtree sizes (number of nodes incl. self). `sizes[0] == p` iff the
    /// forest spans — this drives the all-to-all payload-growth model.
    pub fn subtree_sizes(&self) -> Vec<usize> {
        let mut size = vec![1usize; self.p];
        // parent[d] < d for all d ≥ 1, so a reverse scan accumulates bottom-up.
        for d in (1..self.p).rev() {
            let par = self.parent[d];
            size[par] += size[d];
        }
        size
    }

    /// The size of the partial result `R[d]` *at the moment it is sent*
    /// (number of leaf contributions merged so far): the subtree of `d`
    /// restricted to nodes hooked in earlier rounds, which is exactly the
    /// full subtree of `d` because children of `d` hook in strictly earlier
    /// rounds than `d` is sent... (verified by `invariant_checks`).
    pub fn contributions_when_sent(&self) -> Vec<usize> {
        self.subtree_sizes()
    }

    /// Verify the Theorem 1 invariants; returns an error string on failure.
    /// Used by property tests across many (p, scheme) pairs.
    pub fn invariant_checks(&self) -> Result<(), String> {
        let p = self.p;
        // (a) Every non-root block is sent exactly once, in a valid round.
        for d in 1..p {
            let k = self.round_sent[d];
            if k == 0 || k > self.skips.len() {
                return Err(format!("block {d} never sent"));
            }
            let s = self.skips[k - 1];
            let prev = if k == 1 { p } else { self.skips[k - 2] };
            if !(s <= d && d < prev) {
                return Err(format!("block {d} sent in wrong round {k}"));
            }
            if self.parent[d] != d - s {
                return Err(format!("block {d} wrong parent"));
            }
            // Fold target must be in the live region after round k.
            if self.parent[d] >= s {
                return Err(format!("block {d} folds outside live region"));
            }
        }
        // (b) Children hook in strictly earlier rounds than their parent is
        //     sent (so partial sums are complete when forwarded).
        for d in 1..p {
            let par = self.parent[d];
            if par != 0 && self.round_sent[d] >= self.round_sent[par] {
                return Err(format!(
                    "child {d} (round {}) not before parent {par} (round {})",
                    self.round_sent[d], self.round_sent[par]
                ));
            }
        }
        // (c) The forest spans: every node reaches the root.
        for d in 1..p {
            let _ = self.depth(d); // panics on cycles
        }
        if self.subtree_sizes()[0] != p {
            return Err("tree does not span".into());
        }
        // (d) Per-round live-root structure: after round k the live blocks
        //     are exactly 0..σ_k, and they partition all blocks into
        //     disjoint subtrees (holds by construction; spot-check sizes).
        let mut live = p;
        let sizes_total: usize = {
            let ch = self.children();
            let mut seen = vec![false; p];
            let mut stack: Vec<usize> = vec![0];
            let mut cnt = 0;
            while let Some(v) = stack.pop() {
                if seen[v] {
                    return Err(format!("node {v} visited twice (not a forest)"));
                }
                seen[v] = true;
                cnt += 1;
                stack.extend(ch[v].iter().copied());
            }
            cnt
        };
        if sizes_total != p {
            return Err(format!("reachable nodes {sizes_total} != p {p}"));
        }
        for &s in &self.skips {
            // blocks s..live are exactly the ones sent this round
            for d in s..live {
                if self.round_sent[d] == 0 {
                    return Err(format!("block {d} unsent in its round"));
                }
            }
            live = s;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::SkipScheme;

    #[test]
    fn p22_structure_matches_paper_example() {
        // skips 11,6,3,2,1; root receives rounds' partials from distances
        // 11, 6, 3, 2, 1 — i.e. ranks 10, 15, 18, 19, 20 for r=21.
        let t = SpanningTree::build(22, &[11, 6, 3, 2, 1]);
        t.invariant_checks().unwrap();
        // Direct children of the root are exactly the skip distances.
        let ch = t.children();
        assert_eq!(ch[0], vec![1, 2, 3, 6, 11]);
        // x4 hooks into x15's partial: distance of rank 15 from 21 is 6,
        // rank 4 is distance 17 = 6 + 11 ⇒ parent(17) = 6.
        assert_eq!(t.parent[17], 6);
        assert_eq!(t.round_sent[17], 1); // hooked via σ_1 = 11
        // Rank 10 (distance 11) is sent round 1 directly to the root.
        assert_eq!(t.parent[11], 0);
        assert_eq!(t.round_sent[11], 1);
    }

    #[test]
    fn invariants_hold_across_schemes_and_p() {
        for p in 2..=256usize {
            for scheme in [
                SkipScheme::HalvingUp,
                SkipScheme::PowerOfTwo,
                SkipScheme::Sqrt,
                SkipScheme::FullyConnected,
            ] {
                let skips = scheme.skips(p).unwrap();
                let t = SpanningTree::build(p, &skips);
                t.invariant_checks()
                    .unwrap_or_else(|e| panic!("{} p={p}: {e}", scheme.name()));
            }
        }
    }

    #[test]
    fn decomposition_sums_to_distance_with_distinct_labels() {
        let skips = SkipScheme::HalvingUp.skips(100).unwrap();
        let t = SpanningTree::build(100, &skips);
        for d in 1..100 {
            let dec = t.decomposition(d);
            assert_eq!(dec.iter().sum::<usize>(), d);
            let mut sorted = dec.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), dec.len(), "labels must be distinct for d={d}");
        }
    }

    #[test]
    fn depth_bounded_by_rounds() {
        for p in [22usize, 100, 511, 512, 513] {
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let t = SpanningTree::build(p, &skips);
            for d in 1..p {
                assert!(t.depth(d) <= skips.len(), "p={p} d={d}");
            }
        }
    }

    #[test]
    fn subtree_sizes_sum() {
        let skips = SkipScheme::HalvingUp.skips(22).unwrap();
        let t = SpanningTree::build(22, &skips);
        let sizes = t.subtree_sizes();
        assert_eq!(sizes[0], 22);
        // The root's round-k received partial has the size of subtree σ_k.
        // Round 1 (σ=11): the paper's example shows 2 contributions (x10=x_{21-11} carries x_{21-11-?}.. )
        // — exact values checked via symbolic execution in crate::analysis.
        assert!(sizes[11] >= 1);
    }
}
