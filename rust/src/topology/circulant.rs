//! Circulant graph `C_p^{σ_1,…,σ_q}` — the communication pattern of all the
//! paper's schedules: vertices `0..p`, directed edges `r → (r+σ_k) mod p`.

use super::skips::{SkipScheme, SkipError};

/// A circulant ("loop network") graph over `p` vertices.
#[derive(Debug, Clone)]
pub struct Circulant {
    pub p: usize,
    /// The skip set (distances of outgoing edges).
    pub skips: Vec<usize>,
}

impl Circulant {
    pub fn new(p: usize, skips: Vec<usize>) -> Self {
        Self { p, skips }
    }

    pub fn from_scheme(p: usize, scheme: &SkipScheme) -> Result<Self, SkipError> {
        Ok(Self::new(p, scheme.skips(p)?))
    }

    /// Out-degree = in-degree = number of distinct skips (regularity).
    pub fn degree(&self) -> usize {
        let mut s = self.skips.clone();
        s.sort_unstable();
        s.dedup();
        s.len()
    }

    /// Outgoing neighbors of `r` (the to-processors over all rounds).
    pub fn out_neighbors(&self, r: usize) -> Vec<usize> {
        self.skips.iter().map(|&s| (r + s) % self.p).collect()
    }

    /// Incoming neighbors of `r` (the from-processors over all rounds).
    pub fn in_neighbors(&self, r: usize) -> Vec<usize> {
        self.skips.iter().map(|&s| (r + self.p - s % self.p) % self.p).collect()
    }

    /// BFS hop distance from `a` to `b` using only the skip edges —
    /// used to sanity-check that the graph is strongly connected (any
    /// complete skip set reaches every vertex).
    pub fn hop_distance(&self, a: usize, b: usize) -> Option<usize> {
        let mut dist = vec![usize::MAX; self.p];
        let mut queue = std::collections::VecDeque::new();
        dist[a] = 0;
        queue.push_back(a);
        while let Some(v) = queue.pop_front() {
            if v == b {
                return Some(dist[v]);
            }
            for &s in &self.skips {
                let w = (v + s) % self.p;
                if dist[w] == usize::MAX {
                    dist[w] = dist[v] + 1;
                    queue.push_back(w);
                }
            }
        }
        None
    }

    /// True iff every vertex reaches every other (strong connectivity).
    pub fn strongly_connected(&self) -> bool {
        // Vertex-transitive, so reachability from 0 suffices.
        (0..self.p).all(|v| self.hop_distance(0, v).is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::SkipScheme;

    #[test]
    fn regular_degree_matches_round_count() {
        let g = Circulant::from_scheme(22, &SkipScheme::HalvingUp).unwrap();
        assert_eq!(g.skips, vec![11, 6, 3, 2, 1]);
        assert_eq!(g.degree(), 5); // ⌈log2 22⌉-regular
        assert_eq!(g.out_neighbors(21), vec![10, 5, 2, 1, 0]);
        assert_eq!(g.in_neighbors(21), vec![10, 15, 18, 19, 20]); // the paper's from-list
    }

    #[test]
    fn neighbors_are_inverse_relations() {
        let g = Circulant::from_scheme(37, &SkipScheme::HalvingUp).unwrap();
        for r in 0..37 {
            for &t in &g.out_neighbors(r) {
                assert!(g.in_neighbors(t).contains(&r));
            }
        }
    }

    #[test]
    fn strongly_connected_for_all_schemes() {
        for p in [2usize, 5, 22, 64, 100] {
            for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
                let g = Circulant::from_scheme(p, &scheme).unwrap();
                assert!(g.strongly_connected(), "{} p={p}", scheme.name());
            }
        }
    }

    #[test]
    fn hop_distance_bounded_by_rounds() {
        // With a complete skip set, any vertex is reachable within q hops
        // (each skip used at most once on the path) — the path property in
        // the proof of Theorem 1.
        let g = Circulant::from_scheme(100, &SkipScheme::HalvingUp).unwrap();
        for v in 0..100 {
            assert!(g.hop_distance(0, v).unwrap() <= g.skips.len());
        }
    }
}
