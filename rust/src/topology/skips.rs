//! Skip (jump) sequences for circulant-graph collective schedules.
//!
//! A schedule is driven by a strictly decreasing sequence of skips
//! `σ_1 > σ_2 > … > σ_q = 1` (with `σ_0 = p` implied). In round `k`
//! (1-based) every processor `r` sends blocks `R[σ_k … σ_{k−1})` to
//! processor `(r + σ_k) mod p` and receives the corresponding blocks from
//! `(r − σ_k) mod p`, folding them into `R[0 … σ_{k−1} − σ_k)` — Algorithm 1
//! of the paper, generalized to any valid sequence per Corollary 2.
//!
//! Validity (checked by [`validate`]):
//!   1. strictly decreasing, last element 1, all `< p`;
//!   2. *in-place condition* `σ_{k−1} − σ_k ≤ σ_k` (i.e. `σ_{k−1} ≤ 2σ_k`,
//!      with `σ_0 = p`): the fold target range must lie inside the live
//!      region `[0, σ_k)` that survives the round;
//!   3. the in-place condition implies Corollary 2's requirement that every
//!      `0 < i < p` is a sum of *distinct* skips ([`is_complete`] verifies
//!      this independently by dynamic programming, used in property tests).


/// The skip-sequence families studied in the paper (§2.1 Examples) plus a
/// user-supplied escape hatch. The open experimental question the paper
/// poses — which family performs best on a concrete system — is the T3
/// bench (`rust/benches/t3_skip_schemes.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SkipScheme {
    /// The paper's scheme: repeated halving with round-up,
    /// `σ_k = ⌈σ_{k−1}/2⌉`. Exactly `⌈log2 p⌉` rounds; no sent sequence is
    /// longer than `⌈p/2⌉` blocks (§3).
    HalvingUp,
    /// Straight power-of-two halving à la Bruck et al.:
    /// `σ_k` = largest power of two `< σ_{k−1}`. Also `⌈log2 p⌉` rounds.
    PowerOfTwo,
    /// `σ_k = p − k·⌈√p⌉` while that stays above `⌈√p⌉`, then halving-up:
    /// `Θ(√p)` rounds — the paper's square-root example.
    Sqrt,
    /// `p−1, p−2, …, 1`: the folklore fully-connected algorithm,
    /// `p−1` rounds, one block per round.
    FullyConnected,
    /// Explicit sequence (validated before use).
    Custom(Vec<usize>),
}

impl SkipScheme {
    /// Parse a scheme name as used by the CLI/config (`halving`, `pow2`,
    /// `sqrt`, `full`, or a comma-separated custom list like `13,7,4,2,1`).
    ///
    /// Custom sequences are validated *eagerly* for every `p`-independent
    /// rule (strictly decreasing, ending at 1, consecutive in-place
    /// condition), so a bad sequence like `"5"` is a [`SkipError`] at the
    /// CLI boundary instead of a panic later inside schedule generation.
    /// The `p`-dependent rules (`σ_1 < p`, `p ≤ 2σ_1`) still run in
    /// [`SkipScheme::skips`].
    pub fn parse(s: &str) -> Result<Self, SkipError> {
        match s {
            "halving" | "halving-up" => Ok(Self::HalvingUp),
            "pow2" | "power-of-two" => Ok(Self::PowerOfTwo),
            "sqrt" => Ok(Self::Sqrt),
            "full" | "fully-connected" => Ok(Self::FullyConnected),
            other => {
                let parts: Result<Vec<usize>, _> =
                    other.split(',').map(|t| t.trim().parse::<usize>()).collect();
                match parts {
                    Ok(v) if !v.is_empty() => {
                        validate_shape(&v)?;
                        Ok(Self::Custom(v))
                    }
                    _ => Err(SkipError::UnknownScheme(other.to_string())),
                }
            }
        }
    }

    /// Canonical name; custom sequences render as the comma list
    /// [`SkipScheme::parse`] accepts, so names always round-trip.
    pub fn name(&self) -> String {
        match self {
            Self::HalvingUp => "halving-up".into(),
            Self::PowerOfTwo => "power-of-two".into(),
            Self::Sqrt => "sqrt".into(),
            Self::FullyConnected => "fully-connected".into(),
            Self::Custom(v) => {
                v.iter().map(ToString::to_string).collect::<Vec<_>>().join(",")
            }
        }
    }

    /// Generate and validate the skip sequence `σ_1 … σ_q` for `p` ranks.
    pub fn skips(&self, p: usize) -> Result<Vec<usize>, SkipError> {
        if p == 0 {
            return Err(SkipError::BadP(p));
        }
        if p == 1 {
            return Ok(Vec::new()); // no communication at all
        }
        let v = match self {
            Self::HalvingUp => {
                let mut v = Vec::new();
                let mut s = p;
                while s > 1 {
                    s = s.div_ceil(2);
                    v.push(s);
                }
                v
            }
            Self::PowerOfTwo => {
                let mut v = Vec::new();
                let mut s = p;
                while s > 1 {
                    let mut t = 1usize;
                    while t * 2 < s {
                        t *= 2;
                    }
                    s = t;
                    v.push(s);
                }
                v
            }
            Self::Sqrt => {
                let c = (p as f64).sqrt().ceil() as usize;
                let mut v = Vec::new();
                let mut s = p;
                // Arithmetic descent by c while valid and above c…
                while s > c && s - c > 0 && 2 * (s - c) >= s {
                    s -= c;
                    v.push(s);
                }
                // …then halving-up to finish.
                while s > 1 {
                    s = s.div_ceil(2);
                    v.push(s);
                }
                v
            }
            Self::FullyConnected => (1..p).rev().collect(),
            Self::Custom(v) => v.clone(),
        };
        validate(p, &v)?;
        Ok(v)
    }
}

/// Why a skip sequence was rejected.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SkipError {
    #[error("p must be ≥ 1, got {0}")]
    BadP(usize),
    #[error("unknown skip scheme {0:?}")]
    UnknownScheme(String),
    #[error("skip sequence for p={p} must be non-empty and end at 1, got {seq:?}")]
    MustEndAtOne { p: usize, seq: Vec<usize> },
    #[error("skips must be strictly decreasing and < p={p}: {seq:?}")]
    NotDecreasing { p: usize, seq: Vec<usize> },
    #[error(
        "in-place condition violated at round {round}: σ_{{k-1}}={prev} > 2·σ_k={cur} (p={p})"
    )]
    InPlace { p: usize, round: usize, prev: usize, cur: usize },
    #[error("custom skip sequence {seq:?} rejected at parse time: {why}")]
    BadCustom { seq: Vec<usize>, why: &'static str },
}

/// The `p`-independent validity rules, applied eagerly when parsing a
/// custom sequence (before any `p` is known): non-empty, strictly
/// decreasing, last element 1, and the in-place condition between
/// consecutive skips (`σ_{k−1} ≤ 2σ_k`).
fn validate_shape(seq: &[usize]) -> Result<(), SkipError> {
    if seq.last() != Some(&1) {
        return Err(SkipError::BadCustom { seq: seq.to_vec(), why: "must end at 1" });
    }
    for w in seq.windows(2) {
        if w[1] >= w[0] {
            return Err(SkipError::BadCustom {
                seq: seq.to_vec(),
                why: "must be strictly decreasing",
            });
        }
        if w[0] > 2 * w[1] {
            return Err(SkipError::BadCustom {
                seq: seq.to_vec(),
                why: "in-place condition σ_{k-1} ≤ 2·σ_k violated",
            });
        }
    }
    Ok(())
}

/// Validate a skip sequence for `p` ranks (rules in the module docs).
pub fn validate(p: usize, skips: &[usize]) -> Result<(), SkipError> {
    if p <= 1 {
        return if skips.is_empty() {
            Ok(())
        } else {
            Err(SkipError::NotDecreasing { p, seq: skips.to_vec() })
        };
    }
    if skips.last() != Some(&1) {
        return Err(SkipError::MustEndAtOne { p, seq: skips.to_vec() });
    }
    let mut prev = p;
    for (k, &s) in skips.iter().enumerate() {
        if s == 0 || s >= prev {
            return Err(SkipError::NotDecreasing { p, seq: skips.to_vec() });
        }
        if prev > 2 * s {
            return Err(SkipError::InPlace { p, round: k + 1, prev, cur: s });
        }
        prev = s;
    }
    Ok(())
}

/// Corollary 2's completeness requirement, checked directly: every
/// `0 < i < p` must be a sum of *distinct* skips. (The in-place condition
/// implies this; property tests assert the implication.)
pub fn is_complete(p: usize, skips: &[usize]) -> bool {
    // Subset-sum reachability over 0..p with each skip usable once.
    let mut reach = vec![false; p];
    reach[0] = true;
    for &s in skips {
        for i in (0..p).rev() {
            if i >= s && reach[i - s] {
                reach[i] = true;
            }
        }
    }
    reach.iter().all(|&r| r)
}

/// Decompose `i` into distinct skips, greedily (largest first). Returns the
/// chosen skips, or `None` if greedy fails (cannot happen for valid
/// sequences; the spanning-forest construction in `topology::spanning` uses
/// the *schedule's* decomposition, which this mirrors).
pub fn greedy_decompose(i: usize, skips: &[usize]) -> Option<Vec<usize>> {
    let mut rest = i;
    let mut used = Vec::new();
    for &s in skips {
        if s <= rest {
            used.push(s);
            rest -= s;
        }
    }
    if rest == 0 {
        Some(used)
    } else {
        None
    }
}

/// Number of communication rounds for a scheme at `p` (len of the skips).
pub fn rounds(scheme: &SkipScheme, p: usize) -> usize {
    scheme.skips(p).map(|v| v.len()).unwrap_or(0)
}

/// The longest consecutive block sequence any processor sends in one round
/// (`max_k σ_{k−1} − σ_k`). For HalvingUp this is ≤ ⌈p/2⌉ (§3), which is
/// what lets an implementation avoid half of the result copies [22].
pub fn max_send_run(p: usize, skips: &[usize]) -> usize {
    let mut prev = p;
    let mut best = 0;
    for &s in skips {
        best = best.max(prev - s);
        prev = s;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ceil_log2;

    #[test]
    fn halving_up_p22_matches_paper() {
        // §2.1 worked example: skips 11, 6, 3, 2, 1.
        let v = SkipScheme::HalvingUp.skips(22).unwrap();
        assert_eq!(v, vec![11, 6, 3, 2, 1]);
    }

    #[test]
    fn halving_up_round_count_is_ceil_log2() {
        for p in 2..=4096 {
            let v = SkipScheme::HalvingUp.skips(p).unwrap();
            assert_eq!(v.len() as u32, ceil_log2(p), "p={p}");
        }
    }

    #[test]
    fn pow2_round_count_is_ceil_log2() {
        for p in 2..=4096 {
            let v = SkipScheme::PowerOfTwo.skips(p).unwrap();
            assert_eq!(v.len() as u32, ceil_log2(p), "p={p} {v:?}");
        }
    }

    #[test]
    fn fully_connected_p_minus_1_rounds() {
        for p in 2..=128 {
            let v = SkipScheme::FullyConnected.skips(p).unwrap();
            assert_eq!(v.len(), p - 1);
        }
    }

    #[test]
    fn sqrt_scheme_valid_and_sublinear() {
        for p in 2..=2048 {
            let v = SkipScheme::Sqrt.skips(p).unwrap();
            validate(p, &v).unwrap();
            if p >= 64 {
                assert!(v.len() < p / 2, "p={p} rounds={}", v.len());
            }
        }
    }

    #[test]
    fn all_schemes_complete() {
        for p in [2, 3, 7, 22, 100, 255, 256, 257, 1000] {
            for scheme in [
                SkipScheme::HalvingUp,
                SkipScheme::PowerOfTwo,
                SkipScheme::Sqrt,
                SkipScheme::FullyConnected,
            ] {
                let v = scheme.skips(p).unwrap();
                assert!(is_complete(p, &v), "{} p={p} {v:?}", scheme.name());
            }
        }
    }

    #[test]
    fn validate_rejects_bad_sequences() {
        assert!(matches!(validate(8, &[]), Err(SkipError::MustEndAtOne { .. })));
        assert!(matches!(validate(8, &[4, 2]), Err(SkipError::MustEndAtOne { .. })));
        assert!(matches!(validate(8, &[5, 6, 1]), Err(SkipError::NotDecreasing { .. })));
        assert!(matches!(validate(8, &[8, 4, 2, 1]), Err(SkipError::NotDecreasing { .. })));
        // 10 > 2*4: fold range would spill outside the live region.
        assert!(matches!(validate(10, &[4, 2, 1]), Err(SkipError::InPlace { .. })));
    }

    #[test]
    fn custom_roundtrip_via_parse() {
        let s = SkipScheme::parse("6,3,2,1").unwrap();
        assert_eq!(s.skips(11).unwrap(), vec![6, 3, 2, 1]);
        assert!(SkipScheme::parse("wat").is_err());
        assert_eq!(SkipScheme::parse("halving").unwrap(), SkipScheme::HalvingUp);
        // Canonical names parse back to the same scheme (incl. custom).
        for s in [
            SkipScheme::HalvingUp,
            SkipScheme::PowerOfTwo,
            SkipScheme::Sqrt,
            SkipScheme::FullyConnected,
            SkipScheme::Custom(vec![6, 3, 2, 1]),
        ] {
            assert_eq!(SkipScheme::parse(&s.name()).unwrap(), s, "{}", s.name());
        }
    }

    #[test]
    fn parse_rejects_invalid_custom_sequences_eagerly() {
        // A lone number is not a valid skip sequence — it must fail at
        // parse time (SkipError), not panic later in schedule generation.
        assert!(matches!(
            SkipScheme::parse("5"),
            Err(SkipError::BadCustom { why: "must end at 1", .. })
        ));
        assert!(matches!(
            SkipScheme::parse("3,3,1"),
            Err(SkipError::BadCustom { why: "must be strictly decreasing", .. })
        ));
        assert!(matches!(SkipScheme::parse("9,4,2,1"), Err(SkipError::BadCustom { .. })));
        assert!(matches!(SkipScheme::parse("2,4,1"), Err(SkipError::BadCustom { .. })));
        // Valid sequences still parse.
        assert!(SkipScheme::parse("4,2,1").is_ok());
        assert!(SkipScheme::parse("1").is_ok());
    }

    #[test]
    fn halving_up_max_run_at_most_half() {
        for p in 2..=2048 {
            let v = SkipScheme::HalvingUp.skips(p).unwrap();
            assert!(max_send_run(p, &v) <= p.div_ceil(2), "p={p}");
        }
    }

    #[test]
    fn greedy_decompose_covers_all_targets() {
        for p in [22usize, 100, 257] {
            let v = SkipScheme::HalvingUp.skips(p).unwrap();
            for i in 1..p {
                let d = greedy_decompose(i, &v).expect("decomposable");
                assert_eq!(d.iter().sum::<usize>(), i);
                // distinct by construction (each skip used at most once)
                let mut dd = d.clone();
                dd.dedup();
                assert_eq!(dd, d);
            }
        }
    }

    #[test]
    fn p1_degenerate() {
        assert!(SkipScheme::HalvingUp.skips(1).unwrap().is_empty());
    }
}
