//! Skip-sequence search — a tool for the paper's open question.
//!
//! §2.1: "It is an open, experimental question, which sequence of skips
//! may perform best in practice on a concrete high-performance system."
//! Corollary 2 admits *any* strictly decreasing sequence ending at 1 that
//! satisfies the in-place condition `σ_{k−1} ≤ 2σ_k`; this module searches
//! that space against a user-supplied cost functional (typically a DES run
//! of the induced schedule under a concrete machine model):
//!
//!   * [`enumerate_valid`] — exhaustive DFS over all valid sequences
//!     (tractable for p up to the low hundreds; the count grows roughly
//!     like the number of "halving chains");
//!   * [`beam_search`] — bounded-width beam for large p.
//!
//! The T7 bench (`rust/benches/t7_skip_search.rs`) runs both against the
//! homogeneous model (everything with ⌈log2 p⌉ rounds ties — confirming
//! the paper's analysis) and the clustered contention model of
//! `sim::hier`, where *node-aware* sequences win.

/// Valid next skips after `s` (`s ≥ 2`): all `σ ∈ [⌈s/2⌉, s−1]`.
fn next_skips(s: usize) -> std::ops::RangeInclusive<usize> {
    s.div_ceil(2)..=s - 1
}

/// Exhaustively enumerate valid sequences for `p`, calling `f` on each.
/// Stops early if `f` returns `false`. Returns the number visited.
pub fn enumerate_valid(p: usize, mut f: impl FnMut(&[usize]) -> bool) -> usize {
    let mut seq = Vec::new();
    let mut count = 0usize;
    let mut go = true;
    fn dfs(
        s: usize,
        seq: &mut Vec<usize>,
        count: &mut usize,
        go: &mut bool,
        f: &mut impl FnMut(&[usize]) -> bool,
    ) {
        if !*go {
            return;
        }
        if s == 1 {
            *count += 1;
            if !f(seq) {
                *go = false;
            }
            return;
        }
        for nxt in next_skips(s) {
            seq.push(nxt);
            dfs(nxt, seq, count, go, f);
            seq.pop();
            if !*go {
                return;
            }
        }
    }
    if p >= 2 {
        dfs(p, &mut seq, &mut count, &mut go, &mut f);
    }
    count
}

/// Exhaustive minimization of `cost` over all valid sequences for `p`.
/// Returns `(best_sequence, best_cost, sequences_examined)`.
pub fn exhaustive_best(
    p: usize,
    mut cost: impl FnMut(&[usize]) -> f64,
) -> (Vec<usize>, f64, usize) {
    let mut best: Option<(Vec<usize>, f64)> = None;
    let visited = enumerate_valid(p, |seq| {
        let c = cost(seq);
        if best.as_ref().is_none_or(|(_, bc)| c < *bc) {
            best = Some((seq.to_vec(), c));
        }
        true
    });
    let (seq, c) = best.expect("p ≥ 2 has at least the halving sequence");
    (seq, c, visited)
}

/// Beam search: keep the `beam` cheapest partial sequences per depth,
/// scoring partials with `cost` applied to the *completed* sequence
/// (partial + greedy halving tail). Returns `(sequence, cost)`.
pub fn beam_search(
    p: usize,
    beam: usize,
    mut cost: impl FnMut(&[usize]) -> f64,
) -> (Vec<usize>, f64) {
    assert!(p >= 2 && beam >= 1);
    let complete = |prefix: &[usize]| -> Vec<usize> {
        let mut seq = prefix.to_vec();
        let mut s = *prefix.last().unwrap_or(&p);
        while s > 1 {
            s = s.div_ceil(2);
            seq.push(s);
        }
        seq
    };
    let mut frontier: Vec<(Vec<usize>, f64)> = vec![{
        let full = complete(&[]);
        let c = cost(&full);
        (Vec::new(), c)
    }];
    let mut best: (Vec<usize>, f64) = (complete(&[]), frontier[0].1);
    loop {
        let mut next: Vec<(Vec<usize>, f64)> = Vec::new();
        for (prefix, _) in &frontier {
            let s = *prefix.last().unwrap_or(&p);
            if s == 1 {
                continue;
            }
            for nxt in next_skips(s) {
                let mut cand = prefix.clone();
                cand.push(nxt);
                let full = complete(&cand);
                let c = cost(&full);
                if c < best.1 {
                    best = (full, c);
                }
                next.push((cand, c));
            }
        }
        if next.is_empty() {
            return best;
        }
        next.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        next.truncate(beam);
        frontier = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::{validate, SkipScheme};
    use crate::util::ceil_log2;

    #[test]
    fn enumeration_yields_only_valid_sequences() {
        for p in [2usize, 3, 8, 13, 22] {
            let n = enumerate_valid(p, |seq| {
                validate(p, seq).unwrap();
                true
            });
            assert!(n >= 1, "p={p}");
        }
        // known tiny counts: p=2 → [1]; p=3 → [2,1]; p=4 → [2,1] and [3,2,1]
        assert_eq!(enumerate_valid(2, |_| true), 1);
        assert_eq!(enumerate_valid(3, |_| true), 1);
        assert_eq!(enumerate_valid(4, |_| true), 2);
    }

    #[test]
    fn exhaustive_minimizes_rounds_to_ceil_log2() {
        // cost = number of rounds ⇒ optimum is ⌈log2 p⌉ (the lower bound),
        // achieved by halving-up among others.
        for p in [5usize, 16, 22, 30] {
            let (seq, c, _) = exhaustive_best(p, |s| s.len() as f64);
            assert_eq!(c as u32, ceil_log2(p), "p={p} got {seq:?}");
        }
    }

    #[test]
    fn early_stop_works() {
        let mut seen = 0;
        enumerate_valid(22, |_| {
            seen += 1;
            seen < 10
        });
        assert_eq!(seen, 10);
    }

    #[test]
    fn beam_matches_exhaustive_on_small_p() {
        // cost: rounds + tiny penalty on max run (a mixed objective)
        let cost = |s: &[usize]| {
            s.len() as f64 + 0.001 * crate::topology::skips::max_send_run(22, s) as f64
        };
        let (_, exact, _) = exhaustive_best(22, cost);
        let (_, beamed) = beam_search(22, 32, cost);
        assert!((beamed - exact).abs() < 1e-12, "beam {beamed} vs exact {exact}");
    }

    #[test]
    fn beam_handles_large_p_quickly() {
        let (seq, _) = beam_search(4096, 8, |s| s.len() as f64);
        validate(4096, &seq).unwrap();
        assert_eq!(seq.len() as u32, ceil_log2(4096));
    }

    #[test]
    fn halving_up_is_among_the_optima_for_round_count() {
        let halving = SkipScheme::HalvingUp.skips(22).unwrap();
        let (_, best, _) = exhaustive_best(22, |s| s.len() as f64);
        assert_eq!(halving.len() as f64, best);
    }
}
