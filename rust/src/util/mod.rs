//! Small shared utilities: deterministic PRNG, statistics, table printing,
//! and a minimal JSON reader/writer (the image is offline, so serde & co.
//! are unavailable — see Cargo.toml).

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// `⌈log2 p⌉` for `p ≥ 1` — the paper's round lower bound (and the round
/// count of Algorithm 1 with the halving-up scheme, Theorem 1).
pub fn ceil_log2(p: usize) -> u32 {
    assert!(p >= 1, "ceil_log2 undefined for 0");
    (usize::BITS - (p - 1).leading_zeros()).min(usize::BITS)
}

/// Ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_small_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(22), 5); // the paper's worked example
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn ceil_log2_is_round_lower_bound() {
        // 2^(k-1) < p <= 2^k  ⇔  ceil_log2(p) == k
        for p in 1..10_000usize {
            let k = ceil_log2(p);
            assert!(1usize << k >= p);
            if k > 0 {
                assert!(1usize << (k - 1) < p);
            }
        }
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(7, 2), 4);
        assert_eq!(div_ceil(8, 2), 4);
        assert_eq!(div_ceil(1, 5), 1);
    }
}
