//! Fixed-width ASCII table printing for bench harness output.
//!
//! Every bench binary prints the rows of the table/figure it regenerates
//! (DESIGN.md §5) through this module so EXPERIMENTS.md can be assembled by
//! copy-paste and diffed across runs.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from `Display` items.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with column alignment, markdown-pipe style (paste-ready for
    /// EXPERIMENTS.md).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with engineering-style precision for table cells.
pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if x == 0.0 {
        "0".to_string()
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else if ax >= 1.0 {
        format!("{:.3}", x)
    } else if ax >= 1e-3 {
        format!("{:.3}m", x * 1e3)
    } else if ax >= 1e-6 {
        format!("{:.3}u", x * 1e6)
    } else {
        format!("{:.3}n", x * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("demo", &["p", "rounds"]);
        t.row(&["22".into(), "5".into()]);
        t.row(&["1024".into(), "10".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.lines().count() == 5);
        // all data lines have equal width
        let lens: Vec<usize> = r.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(0.0), "0");
        assert_eq!(fmt_si(1500.0), "1.50k");
        assert_eq!(fmt_si(2.5e7), "25.00M");
        assert_eq!(fmt_si(0.002), "2.000m");
        assert_eq!(fmt_si(3.2e-7), "320.000n");
    }
}
