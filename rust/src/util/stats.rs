//! Basic summary statistics used by the bench harness and metrics.

/// Summary of a sample of measurements (e.g. repeated bench timings).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Panics on empty.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Self {
            n,
            min: s[0],
            max: s[n - 1],
            mean,
            median: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pearson correlation of two equal-length series (used to check DES time
/// against measured wall-clock in the perf bench).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(a.len() > 1);
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let da = a[i] - ma;
        let db = b[i] - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    cov / (va.sqrt() * vb.sqrt()).max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.n, 5);
        // interpolated tail percentiles of [1..5]
        assert!((s.p95 - 4.8).abs() < 1e-12);
        assert!((s.p99 - 4.96).abs() < 1e-12);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
