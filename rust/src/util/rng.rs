//! Deterministic PRNG (splitmix64) — reproducible workloads without `rand`.
//!
//! Every workload generator in the benches/tests takes an explicit seed so
//! that EXPERIMENTS.md rows are exactly reproducible.

/// Splitmix64: tiny, fast, passes BigCrush when used as a stream seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound > 0`. (Lemire-style rejection-free
    /// multiply-shift; bias is < 2^-32 for the bounds used here.)
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (one value per call, simple > fast).
    pub fn next_normal_f32(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal_f32()).collect()
    }

    /// Vector of small integer-valued f32 in `[lo, hi)` — used where tests
    /// need *exact* floating-point sums (commutativity checks).
    pub fn int_valued_vec(&mut self, n: usize, lo: i64, hi: i64) -> Vec<f32> {
        assert!(hi > lo);
        let span = (hi - lo) as usize;
        (0..n).map(|_| (lo + self.next_below(span) as i64) as f32).collect()
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            v.swap(i, self.next_below(i + 1));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bounds_respected() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(13) < 13);
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = SplitMix64::new(3);
        let v = r.normal_vec(20_000);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = SplitMix64::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x]);
            seen[x] = true;
        }
    }

    #[test]
    fn int_valued_exactness() {
        let mut r = SplitMix64::new(11);
        for x in r.int_valued_vec(1000, -5, 6) {
            assert_eq!(x, x.round());
            assert!((-5.0..6.0).contains(&x));
        }
    }
}
