//! Minimal JSON parser + writer.
//!
//! Used to read `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and to dump metrics. serde is unavailable in the offline image
//! (Cargo.toml), so this is a small, strict, recursive-descent implementation
//! covering the JSON actually exchanged: objects, arrays, strings (with the
//! standard escapes), f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj[key]` or panic with a useful message — manifest fields are
    /// required, so missing keys are build-system bugs.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key {key:?} in {self}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Render compactly (stable key order — Obj is a BTreeMap).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only — manifest content never needs surrogates.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of unescaped bytes (UTF-8 passes through).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"format": 1, "buckets": [1024, 8192],
            "artifacts": [{"file": "a.hlo.txt", "n": 1024, "op": "sum"}]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("format").as_usize(), Some(1));
        assert_eq!(j.req("buckets").as_arr().unwrap().len(), 2);
        let a = &j.req("artifacts").as_arr().unwrap()[0];
        assert_eq!(a.req("file").as_str(), Some("a.hlo.txt"));
        assert_eq!(a.req("n").as_usize(), Some(1024));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\n\"y\"","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.render()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn fuzz_never_panics() {
        // Random byte soup (valid UTF-8 subsets) must produce Err, never a
        // panic — the manifest is produced by our own tooling but parse
        // errors should stay recoverable.
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(0xF00D);
        let alphabet: &[u8] = b"{}[]\",:0123456789.eE+-truefalsenull \n\t\\u";
        for _ in 0..2000 {
            let len = rng.next_below(64);
            let bytes: Vec<u8> =
                (0..len).map(|_| alphabet[rng.next_below(alphabet.len())]).collect();
            if let Ok(text) = std::str::from_utf8(&bytes) {
                let _ = Json::parse(text); // Ok or Err, both fine
            }
        }
    }

    #[test]
    fn deep_nesting_ok() {
        let depth = 200;
        let text = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let j = Json::parse(&text).unwrap();
        let mut cur = &j;
        for _ in 0..depth {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }
}
