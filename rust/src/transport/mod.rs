//! In-process message-passing substrate.
//!
//! Substitutes for the paper's MPI cluster (DESIGN.md §2): `p` ranks run as
//! OS threads; each rank owns an [`Endpoint`] supporting the paper's
//! communication primitive — a *one-ported simultaneous send/receive*
//! (MPI_Sendrecv): in one operation a rank sends one message to one peer
//! and receives one message from a possibly different peer.
//!
//! Messages are tagged `(from, round)` and stashed on arrival, so the
//! rendezvous is insensitive to thread scheduling while still enforcing the
//! round structure (a message for round `k` can only be consumed by the
//! round-`k` sendrecv). Per-endpoint counters record rounds, messages and
//! element volume for the Theorem 1/2 benches.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// A message between ranks: payload plus matching tag.
#[derive(Debug)]
pub struct Msg {
    pub from: usize,
    pub round: u64,
    pub payload: Vec<f32>,
}

/// Transport-level errors (used by failure-injection tests).
#[derive(Debug, thiserror::Error)]
pub enum TransportError {
    #[error("rank {rank}: timeout waiting for round {round} message from {from}")]
    Timeout { rank: usize, from: usize, round: u64 },
    #[error("rank {rank}: peer {to} disconnected")]
    Disconnected { rank: usize, to: usize },
}

/// Volume counters for one endpoint.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Counters {
    pub sendrecv_rounds: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    pub elems_sent: u64,
    pub elems_recv: u64,
}

/// One rank's communication handle.
pub struct Endpoint {
    pub rank: usize,
    pub p: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Early arrivals keyed by (from, round).
    stash: HashMap<(usize, u64), Vec<f32>>,
    pub counters: Counters,
    /// Receive timeout — deadlock detection in tests; generous default.
    pub timeout: Duration,
}

/// Build a fully-connected network of `p` endpoints (one per rank).
pub fn network(p: usize) -> Vec<Endpoint> {
    assert!(p >= 1);
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank,
            p,
            txs: txs.clone(),
            rx,
            stash: HashMap::new(),
            counters: Counters::default(),
            timeout: Duration::from_secs(30),
        })
        .collect()
}

impl Endpoint {
    /// The paper's combined `Send(..) ‖ Recv(..)` primitive.
    ///
    /// `send`: optional `(to, payload)`; `recv_from`: optional peer to wait
    /// for. Either side may be `None` (tree rounds). Returns the received
    /// payload if `recv_from` was given.
    pub fn sendrecv(
        &mut self,
        send: Option<(usize, Vec<f32>)>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<f32>>, TransportError> {
        self.counters.sendrecv_rounds += 1;
        if let Some((to, payload)) = send {
            debug_assert!(to < self.p && to != self.rank, "bad send target {to}");
            self.counters.msgs_sent += 1;
            self.counters.elems_sent += payload.len() as u64;
            self.txs[to]
                .send(Msg { from: self.rank, round, payload })
                .map_err(|_| TransportError::Disconnected { rank: self.rank, to })?;
        }
        match recv_from {
            None => Ok(None),
            Some(from) => {
                let payload = self.recv_tagged(from, round)?;
                self.counters.msgs_recv += 1;
                self.counters.elems_recv += payload.len() as u64;
                Ok(Some(payload))
            }
        }
    }

    /// Receive the message tagged `(from, round)`, stashing out-of-order
    /// arrivals from other peers/rounds.
    fn recv_tagged(&mut self, from: usize, round: u64) -> Result<Vec<f32>, TransportError> {
        if let Some(payload) = self.stash.remove(&(from, round)) {
            return Ok(payload);
        }
        loop {
            match self.rx.recv_timeout(self.timeout) {
                Ok(msg) => {
                    if msg.from == from && msg.round == round {
                        return Ok(msg.payload);
                    }
                    self.stash.insert((msg.from, msg.round), msg.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { rank: self.rank, from, round })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Disconnected { rank: self.rank, to: from })
                }
            }
        }
    }

    /// Raw one-directional send (used by the coordinator's control plane).
    pub fn send_to(&mut self, to: usize, round: u64, payload: Vec<f32>) -> Result<(), TransportError> {
        self.counters.msgs_sent += 1;
        self.counters.elems_sent += payload.len() as u64;
        self.txs[to]
            .send(Msg { from: self.rank, round, payload })
            .map_err(|_| TransportError::Disconnected { rank: self.rank, to })
    }

    /// Raw one-directional receive.
    pub fn recv_from(&mut self, from: usize, round: u64) -> Result<Vec<f32>, TransportError> {
        let payload = self.recv_tagged(from, round)?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Ok(payload)
    }
}

/// Run `f(rank, endpoint)` on `p` threads, one per rank, and collect the
/// per-rank results in rank order. Panics in any rank are propagated.
pub fn run_ranks<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint) -> T + Send + Sync + 'static,
{
    let endpoints = network(p);
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::with_capacity(p);
    for (rank, mut ep) in endpoints.into_iter().enumerate() {
        let f = f.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(8 << 20)
                .spawn(move || f(rank, &mut ep))
                .expect("spawn rank thread"),
        );
    }
    handles
        .into_iter()
        .enumerate()
        .map(|(rank, h)| h.join().unwrap_or_else(|e| std::panic::resume_unwind(Box::new(format!("rank {rank} panicked: {e:?}")))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_sendrecv_roundtrip() {
        let out = run_ranks(4, |rank, ep| {
            let to = (rank + 1) % 4;
            let from = (rank + 3) % 4;
            let got = ep
                .sendrecv(Some((to, vec![rank as f32])), Some(from), 0)
                .unwrap()
                .unwrap();
            got[0]
        });
        assert_eq!(out, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn out_of_order_rounds_are_stashed() {
        // Rank 1 sends rounds 0 and 1 immediately; rank 0 consumes round 1
        // first, then round 0 — the stash must reorder.
        let out = run_ranks(2, |rank, ep| {
            if rank == 1 {
                ep.send_to(0, 0, vec![10.0]).unwrap();
                ep.send_to(0, 1, vec![11.0]).unwrap();
                vec![]
            } else {
                let b = ep.recv_from(1, 1).unwrap();
                let a = ep.recv_from(1, 0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[0], vec![10.0, 11.0]);
    }

    #[test]
    fn counters_track_volume() {
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            ep.sendrecv(Some((peer, vec![0.0; 7])), Some(peer), 0).unwrap();
            ep.counters.clone()
        });
        for c in out {
            assert_eq!(c.msgs_sent, 1);
            assert_eq!(c.msgs_recv, 1);
            assert_eq!(c.elems_sent, 7);
            assert_eq!(c.elems_recv, 7);
        }
    }

    #[test]
    fn timeout_detects_missing_peer() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.timeout = Duration::from_millis(50);
                ep.sendrecv(None, Some(1), 7).map(|_| ()).is_err()
            } else {
                true // rank 1 never sends
            }
        });
        assert!(out[0], "rank 0 should have timed out");
    }

    #[test]
    fn sendrecv_with_only_send_side() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.sendrecv(Some((1, vec![5.0])), None, 0).unwrap();
                0.0
            } else {
                ep.sendrecv(None, Some(0), 0).unwrap().unwrap()[0]
            }
        });
        assert_eq!(out[1], 5.0);
    }
}
