//! In-process message-passing substrate with a three-tier copy discipline,
//! generic over the element type.
//!
//! Substitutes for the paper's MPI cluster (DESIGN.md §2): `p` ranks run as
//! OS threads; each rank owns an [`Endpoint`] supporting the paper's
//! communication primitive — a *one-ported simultaneous send/receive*
//! (MPI_Sendrecv): in one operation a rank sends one message to one peer
//! and receives one message from a possibly different peer.
//!
//! Messages are tagged `(from, op, round)` ([`Tag`]) and stashed on
//! arrival, so the rendezvous is insensitive to thread scheduling while
//! still enforcing the round structure (a message for round `k` of
//! operation `o` can only be consumed by that operation's round-`k`
//! sendrecv). Per-endpoint counters record rounds, messages and element
//! volume for the Theorem 1/2 benches.
//!
//! # Op tags (the wire discipline for concurrent collectives)
//!
//! A plain `round: u64` tag is enough for one collective at a time — the
//! communicator reserves monotonic round windows so *back-to-back* ops
//! never collide. It is **not** enough for several collectives in flight
//! on the same endpoints (the [`crate::engine`] worker loop interleaves
//! them): two concurrent schedules both counting rounds 0,1,2,… would
//! cross-match messages and rendezvous acks. Every wire artifact —
//! messages, the stash, rendezvous ack channels and the pending-publish
//! set — is therefore keyed by a [`Tag`]: an operation epoch `op` plus the
//! round within that operation. The legacy `round: u64` APIs all operate
//! in epoch 0 (`Tag::untagged`), so single-collective callers (and every
//! pre-engine test) keep their exact wire behavior; the engine allocates a
//! fresh nonzero epoch per submitted operation.
//!
//! # Element types (dtypes)
//!
//! [`Endpoint`] is generic over its payload element `E:`[`Elem`], with
//! `f32` as the default type parameter — `Endpoint`, [`network`],
//! [`run_ranks`] and [`run_ranks_inputs`] keep their original f32 meaning,
//! while [`network_typed`], [`run_ranks_typed`] and
//! [`run_ranks_inputs_typed`] build networks of any supported dtype. The
//! element size is a compile-time property of the endpoint:
//!
//! * **pooled tier** — pools recycle `Vec<E>` by *capacity*; since every
//!   payload on an `Endpoint<E>` shares one element size, capacity
//!   matching in elements is exactly capacity matching in bytes, and one
//!   pool serves every payload shape of the network's dtype;
//! * **rendezvous tier** — [`RemoteSlices<E>`] descriptors carry their
//!   element size ([`RemoteSlices::elem_bytes`]) statically in the type,
//!   so a publish can never be reinterpreted at the wrong width;
//! * **copy accounting** — `Counters::bytes_copied` is credited
//!   `size_of::<E>()` per element, so cross-dtype ablations compare real
//!   byte volume.
//!
//! # The three-tier copy discipline
//!
//! The paper's algorithms move exactly `p−1` blocks per processor
//! (Theorem 1); the transport must not add memory traffic on top. Payloads
//! travel by one of three tiers, fastest first, each falling back to the
//! next when its precondition fails:
//!
//! 1. **Rendezvous** (zero-copy, [`SendSlices::rendezvous`]) — the sender
//!    publishes *descriptors* of its ≤ 2 working-vector slices
//!    ([`RemoteSlices`]); the receiver combines/stores **directly from the
//!    sender's memory** in one fused pass and then acks
//!    ([`Endpoint::rendezvous_ack`]); the sender blocks in
//!    [`Endpoint::finish_round`] until that ack before it may mutate or
//!    release the published region. Engages only when the caller
//!    guarantees the published region is not written during the round
//!    (the executor's send/recv block-range disjointness check), the
//!    endpoint opted in ([`Endpoint::rendezvous`], off for raw endpoints,
//!    on for the executor drivers and [`crate::coordinator::Communicator`]),
//!    the payload is at least [`Endpoint::rendezvous_min_elems`] elements
//!    (below that, the blocking ack costs more than the copy it saves)
//!    and the `CCOLL_NO_RENDEZVOUS` knob is off. Payload bytes copied:
//!    **zero**.
//! 2. **Pooled** (single-copy, [`Endpoint::sendrecv`]) — the sender
//!    gathers its slices into a `Vec<E>` *loaned* from its per-peer
//!    [`BufferPool`]; the receiver consumes it and [`Endpoint::release`]s
//!    the buffer back to the sender's pool over a dedicated return
//!    channel. After warm-up every acquire is a pool hit and the
//!    steady-state path performs zero payload allocations per round
//!    (`Counters::pool_hits` / `pool_misses`; one caveat: a released
//!    buffer races the owner's next acquire, so a handful of misses
//!    bounded by the number of (peer, capacity) classes can occur at any
//!    point, but misses never scale with rounds).
//! 3. **Owned** ([`Endpoint::sendrecv_owned`]) — ownership transfer for
//!    payloads that are *built* rather than gathered (the framed, growing
//!    all-to-all messages); pair with [`Endpoint::acquire`] to keep this
//!    path pooled too.
//!
//! `Counters::bytes_copied` tallies the payload bytes each tier physically
//! copies (the gather on tier 2/3 sends, plus `Store` scatters counted by
//! the executor), and `Counters::rendezvous_hits` counts tier-1 publishes —
//! the `perf_hotpath` ablation compares the tiers with both.
//!
//! Environment knobs (`CCOLL_NO_RENDEZVOUS`,
//! `CCOLL_RENDEZVOUS_MIN_ELEMS`) are parsed once per process by
//! [`crate::env_knobs`] — malformed values abort loudly instead of
//! silently defaulting.
//!
//! ## Rendezvous safety contract
//!
//! [`RemoteSlices`] carries raw pointers across threads; the protocol —
//! not the borrow checker — guarantees their validity:
//!
//! * the sender's published region stays **unwritten and alive** from
//!   publish until [`Endpoint::finish_round`] returns (the executor only
//!   writes its *recv* ranges during a round and validates they are
//!   disjoint from the published *send* range, falling back to tier 2
//!   otherwise);
//! * the receiver reads the region **only before acking** and never
//!   writes it;
//! * sender and receiver working vectors are distinct allocations, so the
//!   receiver's own writes cannot alias the published region.
//!
//! A receiver that dies before acking parks the sender in
//! `finish_round` until its timeout fires and surfaces an error. Note
//! the timeout is a failure *detector*, not a cancellation: a receiver
//! that is merely stalled (not dead) past the sender's timeout still
//! holds the descriptors, so once `AckTimeout` has fired the publish
//! contract is void and freeing the published buffer while that peer
//! lives is a use-after-free hazard. The safety argument for this
//! in-process transport is therefore that `timeout` (a deliberately
//! generous 30 s default against thread-scheduling stalls) exceeds any
//! realistic receiver stall, and that errors abort the whole collective:
//! tests that shrink the timeout for failure injection also own and
//! tear down the entire network. A production shared-memory/RDMA port
//! must replace the timeout with real cancellation (e.g. revoking the
//! registration) before reclaiming published memory. Consumers other
//! than the schedule executor (the control plane, all-to-all) never see
//! tier-1 payloads because only the executor publishes them.
//!
//! This pool + descriptor seam is also where a future shared-memory or
//! RDMA-style transport plugs in: registered buffers replace heap `Vec`s
//! and descriptors become remote keys, with no executor change.
//!
//! # Transport backends (the [`Transport`] trait)
//!
//! The surface the schedule executor ([`crate::collectives`]) and the
//! engine workers actually consume is the [`Transport`] trait: tagged
//! send/recv plus try-variants, pooled acquire/release, the rendezvous
//! quiesce family (`finish_op`/`try_finish`/`forget_op`) and counters.
//! Each backend reports [`TransportCaps`] — capability flags that replace
//! the old hard-coded three-tier assumption: the executor publishes
//! rendezvous descriptors only when `caps().supports_rendezvous` holds,
//! falling back rendezvous → pooled → framed copy per backend.
//!
//! Two backends are registered ([`backends`], selected by the
//! `transport.backend` config key / `CCOLL_TRANSPORT` env knob):
//!
//! * [`ThreadTransport`] (= [`Endpoint`], the default) — ranks are OS
//!   threads sharing one address space; supports every tier and remains
//!   the semantics oracle for all others;
//! * [`uds::UdsTransport`] — ranks are OS processes on one machine,
//!   exchanging length-prefixed [`Tag`]-framed messages over Unix-domain
//!   sockets (`ccoll launch --backend uds`). Rendezvous is unsupported
//!   (no shared address space); recv-side buffers are pooled and reused
//!   across rounds.
//!
//! A third piece — not a registered backend but a wrapper over any of
//! them — is [`fault::FaultTransport`]: deterministic, seeded fault
//! injection (drop/delay/duplicate/truncate/kill) for chaos-testing the
//! failure paths reproducibly (`ccoll chaos`, `rust/tests/faults.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::datatypes::Elem;

/// Wire tag of one message/ack: the operation epoch plus the round within
/// that operation. See the module docs ("Op tags") — epoch 0 is the
/// legacy/untagged space shared by every `round: u64` API; the engine
/// allocates epochs ≥ 1 so concurrent collectives on the same endpoints
/// can never cross-match.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Tag {
    /// Operation epoch (0 = the untagged/legacy space).
    pub op: u64,
    /// Round within the operation.
    pub round: u64,
}

impl Tag {
    pub fn new(op: u64, round: u64) -> Self {
        Self { op, round }
    }

    /// The epoch-0 tag the plain `round: u64` APIs use.
    pub fn untagged(round: u64) -> Self {
        Self { op: 0, round }
    }
}

/// Bits of [`Tag::op`] reserved for the recovery **generation epoch**:
/// the high 16 bits carry the generation, the low 48 the per-generation
/// sequence number. Generation 0 composed with sequence `s` is exactly
/// `s`, so pre-recovery engines (and every epoch-0 legacy tag) are
/// bit-identical to the pre-generation wire format — no frame layout
/// change, no compatibility break.
pub const GEN_SHIFT: u32 = 48;

/// Compose an operation epoch from a generation and a per-generation
/// sequence number. 48 bits of sequence is ~280 trillion operations per
/// generation; 16 bits of generation is 65k reconfigurations.
pub fn compose_op(gen: u64, seq: u64) -> u64 {
    debug_assert!(gen < (1 << 16), "generation {gen} overflows 16 bits");
    debug_assert!(seq < (1u64 << GEN_SHIFT), "sequence {seq} overflows 48 bits");
    (gen << GEN_SHIFT) | seq
}

/// The generation epoch carried in an operation tag.
pub fn generation_of(op: u64) -> u64 {
    op >> GEN_SHIFT
}

/// The per-generation sequence number carried in an operation tag.
pub fn sequence_of(op: u64) -> u64 {
    op & ((1u64 << GEN_SHIFT) - 1)
}

/// Process-wide count of rank worker threads ever spawned (by
/// [`run_ranks`]-family drivers and the [`crate::engine`] workers). The
/// `ccoll serve` soak and the engine tests read this to prove the
/// persistent engine spawns its `p` workers **once** — not per operation.
static RANK_THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Total rank threads spawned by this process so far.
pub fn rank_threads_spawned() -> u64 {
    RANK_THREADS_SPAWNED.load(Ordering::Relaxed)
}

pub(crate) fn note_rank_thread_spawn() {
    RANK_THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
}

/// Descriptors of the (≤ 2) working-vector slices a rendezvous sender
/// published for one round. See the module docs for the safety contract
/// that keeps the pointers valid until the receiver acks. The element
/// type — and therefore the element size — travels in the type parameter,
/// so the receiving side can never reinterpret the region at the wrong
/// width.
#[derive(Debug)]
pub struct RemoteSlices<E: Elem = f32> {
    head: *const E,
    head_len: usize,
    tail: *const E,
    tail_len: usize,
}

// SAFETY: the pointed-to memory is owned by the publishing rank's thread
// and, per the protocol above, stays alive and unwritten until the
// receiving thread acks; the receiver only reads. See module docs.
unsafe impl<E: Elem> Send for RemoteSlices<E> {}

impl<E: Elem> RemoteSlices<E> {
    fn new(head: &[E], tail: &[E]) -> Self {
        Self {
            head: head.as_ptr(),
            head_len: head.len(),
            tail: tail.as_ptr(),
            tail_len: tail.len(),
        }
    }

    /// Total published elements.
    pub fn len(&self) -> usize {
        self.head_len + self.tail_len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size of one published element in bytes (the descriptor's element
    /// size — fixed by the endpoint's dtype).
    pub fn elem_bytes(&self) -> usize {
        std::mem::size_of::<E>()
    }

    /// Reconstruct the published slices.
    ///
    /// # Safety
    ///
    /// Caller must be the rendezvous receiver for this round and must not
    /// use the slices after calling [`Endpoint::rendezvous_ack`] (which is
    /// what frees the sender to mutate the region again).
    pub unsafe fn slices<'a>(&self) -> (&'a [E], &'a [E]) {
        let head = if self.head_len == 0 {
            &[][..]
        } else {
            std::slice::from_raw_parts(self.head, self.head_len)
        };
        let tail = if self.tail_len == 0 {
            &[][..]
        } else {
            std::slice::from_raw_parts(self.tail, self.tail_len)
        };
        (head, tail)
    }
}

/// A received payload: either a pooled/owned buffer (tiers 2–3) or
/// published rendezvous descriptors (tier 1).
#[derive(Debug)]
pub enum Payload<E: Elem = f32> {
    /// A materialized buffer; hand back via [`Endpoint::release`] when it
    /// came from a pooled sender.
    Copied(Vec<E>),
    /// Zero-copy descriptors; consume then [`Endpoint::rendezvous_ack`].
    Remote(RemoteSlices<E>),
}

impl<E: Elem> Payload<E> {
    /// Payload length in elements.
    pub fn len(&self) -> usize {
        match self {
            Payload::Copied(v) => v.len(),
            Payload::Remote(r) => r.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn expect_copied(self, rank: usize, from: usize) -> Vec<E> {
        match self {
            Payload::Copied(v) => v,
            Payload::Remote(_) => panic!(
                "rank {rank}: peer {from} published a rendezvous payload on a \
                 copied-payload API (sendrecv/recv_from) — only the schedule \
                 executor speaks the rendezvous protocol"
            ),
        }
    }
}

/// A message between ranks: payload plus matching tag.
#[derive(Debug)]
pub struct Msg<E: Elem = f32> {
    pub from: usize,
    pub tag: Tag,
    pub payload: Payload<E>,
}

/// Transport-level errors (used by failure-injection tests).
#[derive(Debug, thiserror::Error)]
pub enum TransportError {
    #[error("rank {rank}: timeout waiting for round {round} message from {from}")]
    Timeout { rank: usize, from: usize, round: u64 },
    #[error("rank {rank}: peer {to} disconnected")]
    Disconnected { rank: usize, to: usize },
    #[error("rank {rank}: timeout waiting for rendezvous ack (round {round})")]
    AckTimeout { rank: usize, round: u64 },
    /// A peer was positively detected dead (EOF / IO error on its
    /// connection, or a fault-injected kill) — unlike [`Timeout`]
    /// (TransportError::Timeout), which merely says nothing arrived in
    /// time. The distinction is the error taxonomy the engine's
    /// fast-fail path keys on: a down peer fails every operation that
    /// still needs it *immediately* instead of burning one liveness
    /// timeout per in-flight op.
    #[error("rank {rank}: peer {peer} is down ({detail})")]
    PeerDown { rank: usize, peer: usize, detail: String },
}

/// Volume counters for one endpoint.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Counters {
    pub sendrecv_rounds: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    pub elems_sent: u64,
    pub elems_recv: u64,
    /// Acquires served allocation-free from a pool (a recycled buffer
    /// with sufficient capacity, ours or another peer's).
    pub pool_hits: u64,
    /// Acquires that had to heap-allocate (no pooled buffer was big
    /// enough) — zero per round in steady state.
    pub pool_misses: u64,
    /// Buffers that came back over the return channel.
    pub bufs_recycled: u64,
    /// Sends that published zero-copy rendezvous descriptors (tier 1)
    /// instead of gathering into a pooled buffer.
    pub rendezvous_hits: u64,
    /// Payload bytes physically copied by this endpoint's sends (the
    /// tier-2/3 gather, `size_of::<E>()` per element) plus `Store`
    /// scatters credited by the executor. Rendezvous publishes copy
    /// nothing.
    pub bytes_copied: u64,
    /// Collectives this rank ran whose `(algorithm, p, partition, dtype)`
    /// plan was served from a [`crate::schedule::PlanCache`] (credited by
    /// the communicator / engine, not the transport itself).
    pub plan_hits: u64,
    /// Collectives whose plan had to be generated fresh (a cache miss).
    pub plan_misses: u64,
}

/// Recycled payload buffers destined for one peer. Capacity matching is
/// per element, which — the endpoint's dtype being fixed — is equivalent
/// to matching by byte capacity.
#[derive(Debug)]
struct BufferPool<E: Elem> {
    free: Vec<Vec<E>>,
}

impl<E: Elem> Default for BufferPool<E> {
    fn default() -> Self {
        Self { free: Vec::new() }
    }
}

/// The send half of the executor's borrow-pack sendrecv: up to two
/// working-vector slices (a circular block range resolves to at most two)
/// plus the caller's verdict on whether publishing them zero-copy is safe
/// this round (send/recv range disjointness — see the module docs).
pub struct SendSlices<'a, E: Elem = f32> {
    pub to: usize,
    pub head: &'a [E],
    pub tail: &'a [E],
    /// Caller guarantees the slices are not written during this round.
    /// The endpoint still falls back to the pooled tier when rendezvous
    /// is disabled on this endpoint or the payload is empty.
    pub rendezvous: bool,
}

impl<'a, E: Elem> SendSlices<'a, E> {
    pub fn len(&self) -> usize {
        self.head.len() + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Process-wide rendezvous kill-switch: the `CCOLL_NO_RENDEZVOUS` knob
/// (parsed once by [`crate::env_knobs`]; `1|true|yes` disables, malformed
/// values abort) forces every endpoint to the pooled tier — for
/// transports/platforms that cannot honor the publish contract, and for
/// A/B measurements. Enforced inside the transport's publish decision
/// itself — setting [`Endpoint::rendezvous`] directly cannot bypass it.
pub fn rendezvous_env_enabled() -> bool {
    crate::env_knobs::knobs().rendezvous_enabled
}

/// Default payload threshold (elements) below which a rendezvous-eligible
/// send still travels the pooled tier: publishing makes the sender block
/// for the receiver's ack, so for small payloads the copy is cheaper than
/// putting the receiver's combine on the sender's critical path. 256
/// elements = 1 KiB of f32 (2 KiB of f64/i64/u64). Override per process
/// with `CCOLL_RENDEZVOUS_MIN_ELEMS` (validated by [`crate::env_knobs`]),
/// per endpoint via [`Endpoint::rendezvous_min_elems`] (the executor test
/// drivers pin it to 0 to exercise the zero-copy tier deterministically).
pub const DEFAULT_RENDEZVOUS_MIN_ELEMS: usize = 256;

/// Default retry budget for transient send errors (`WouldBlock` on a
/// backend writer): how many re-attempts a frame segment gets before the
/// peer is declared down. Override per process with `CCOLL_RETRY_ATTEMPTS`
/// or per engine via `EngineConfig::retry_attempts` → [`Transport::set_retry`].
pub const DEFAULT_RETRY_ATTEMPTS: usize = 3;

/// Default base backoff (milliseconds) between transient-send retries;
/// attempt `k` sleeps `base << (k-1)`, capped. Override with
/// `CCOLL_RETRY_BASE_MS` / `EngineConfig::retry_base_ms`.
pub const DEFAULT_RETRY_BASE_MS: u64 = 10;

/// Default heartbeat probe interval for the UDS backend, in milliseconds.
/// `0` disables liveness probes entirely (the PR-7 fail-fast behaviour):
/// a peer is only declared down when a read or write on its stream
/// actually fails. Override with `CCOLL_HEARTBEAT_MS`.
pub const DEFAULT_HEARTBEAT_MS: u64 = 0;

/// Default reconnect budget for a UDS peer whose stream dropped: how many
/// bounded, backed-off dial attempts `UdsTransport` makes before giving the
/// peer up as dead. `0` disables reconnection (fail-fast, the historical
/// behaviour — a broken stream is immediately a dead peer). Override with
/// `CCOLL_RECONNECT_ATTEMPTS`.
pub const DEFAULT_RECONNECT_ATTEMPTS: usize = 0;

/// Default base backoff (milliseconds) between UDS reconnect attempts;
/// attempt `k` sleeps `base << (k-1)` (shift capped at 6). Override with
/// `CCOLL_RECONNECT_BASE_MS`.
pub const DEFAULT_RECONNECT_BASE_MS: u64 = 50;

/// One rank's communication handle for payloads of element type `E`
/// (default `f32`, so pre-dtype code compiles unchanged).
pub struct Endpoint<E: Elem = f32> {
    pub rank: usize,
    pub p: usize,
    txs: Vec<Sender<Msg<E>>>,
    rx: Receiver<Msg<E>>,
    /// Return path: `(returning peer, buffer)` flowing back to this owner.
    ret_txs: Vec<Sender<(usize, Vec<E>)>>,
    ret_rx: Receiver<(usize, Vec<E>)>,
    /// Rendezvous completion path: `ack_txs[r]` feeds rank r's `ack_rx`.
    ack_txs: Vec<Sender<Tag>>,
    ack_rx: Receiver<Tag>,
    /// Tags of un-acked rendezvous publishes. A single blocking collective
    /// has at most one outstanding (one-ported sends + `finish_round` per
    /// round); the engine's interleaved operations can each have one, so
    /// this is a (tiny) set rather than an `Option`.
    pending_acks: Vec<Tag>,
    /// `pools[peer]` holds recycled buffers last used for messages to
    /// `peer` (affinity keeps capacities matched to that link's payloads).
    pools: Vec<BufferPool<E>>,
    /// Early arrivals keyed by (from, tag).
    stash: HashMap<(usize, Tag), Payload<E>>,
    pub counters: Counters,
    /// Opt-in for the zero-copy rendezvous tier. Raw endpoints default to
    /// `false` so plain `sendrecv` users keep the pooled protocol; the
    /// schedule-executor drivers and the Communicator switch it on.
    pub rendezvous: bool,
    /// Minimum payload (elements) for a rendezvous publish; smaller
    /// rendezvous-eligible sends stay pooled (latency: the ack round-trip
    /// outweighs a small copy). See [`DEFAULT_RENDEZVOUS_MIN_ELEMS`].
    pub rendezvous_min_elems: usize,
    /// Receive timeout — deadlock detection in tests; generous default.
    pub timeout: Duration,
    /// Recovery generation this endpoint accepts frames for: arrivals
    /// tagged with an *older* generation are counted into
    /// [`Endpoint::stale_frames`] and dropped at the stash boundary, so
    /// pre-recovery traffic can never cross-match a post-recovery
    /// operation. 0 = never reconfigured (all traffic current).
    generation: u64,
    /// Frames dropped for carrying a stale generation.
    stale_frames: u64,
}

/// Build a fully-connected network of `p` f32 endpoints (one per rank) —
/// the pre-dtype entry point; see [`network_typed`] for other dtypes.
pub fn network(p: usize) -> Vec<Endpoint> {
    network_typed::<f32>(p)
}

/// Build a fully-connected network of `p` endpoints over any element type.
pub fn network_typed<E: Elem>(p: usize) -> Vec<Endpoint<E>> {
    assert!(p >= 1);
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    let mut ret_txs = Vec::with_capacity(p);
    let mut ret_rxs = Vec::with_capacity(p);
    let mut ack_txs = Vec::with_capacity(p);
    let mut ack_rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Msg<E>>();
        txs.push(tx);
        rxs.push(rx);
        let (rtx, rrx) = channel::<(usize, Vec<E>)>();
        ret_txs.push(rtx);
        ret_rxs.push(rrx);
        let (atx, arx) = channel::<Tag>();
        ack_txs.push(atx);
        ack_rxs.push(arx);
    }
    rxs.into_iter()
        .zip(ret_rxs)
        .zip(ack_rxs)
        .enumerate()
        .map(|(rank, ((rx, ret_rx), ack_rx))| Endpoint {
            rank,
            p,
            txs: txs.clone(),
            rx,
            ret_txs: ret_txs.clone(),
            ret_rx,
            ack_txs: ack_txs.clone(),
            ack_rx,
            pending_acks: Vec::new(),
            pools: (0..p).map(|_| BufferPool::default()).collect(),
            stash: HashMap::new(),
            counters: Counters::default(),
            rendezvous: false,
            rendezvous_min_elems: crate::env_knobs::knobs().rendezvous_min_elems,
            timeout: Duration::from_secs(30),
            generation: 0,
            stale_frames: 0,
        })
        .collect()
}

impl<E: Elem> Endpoint<E> {
    /// Pull every returned buffer off the return channel into its pool.
    fn drain_returns(&mut self) {
        while let Ok((peer, buf)) = self.ret_rx.try_recv() {
            self.counters.bufs_recycled += 1;
            self.pools[peer].free.push(buf);
        }
    }

    /// Take a buffer with at least `need` capacity from `free`, if one
    /// exists. Undersized buffers are never handed out: a *hit* must mean
    /// the acquire performs no heap allocation (the zero-alloc regression
    /// tests and the perf ablation rely on that counter being honest).
    fn take_from(free: &mut Vec<Vec<E>>, need: usize) -> Option<Vec<E>> {
        let i = free.iter().position(|b| b.capacity() >= need)?;
        let mut buf = free.swap_remove(i);
        buf.clear();
        Some(buf)
    }

    /// Check out an empty buffer of at least `need` capacity for a message
    /// to `to`, recycling returned payloads when possible (per-peer
    /// affinity first, then any pool, then — a pool miss — a fresh
    /// allocation). Undersized pooled buffers stay put; they keep serving
    /// the smaller payloads of later rounds.
    ///
    /// `need == 0` (zero-length transfers on degenerate partitions)
    /// bypasses the pool and the hit/miss counters entirely: an empty
    /// `Vec` allocates nothing, and pulling a real buffer out of
    /// circulation for it would starve the payload-carrying rounds.
    pub fn acquire(&mut self, to: usize, need: usize) -> Vec<E> {
        if need == 0 {
            return Vec::new();
        }
        self.drain_returns();
        if let Some(buf) = Self::take_from(&mut self.pools[to].free, need) {
            self.counters.pool_hits += 1;
            return buf;
        }
        for peer in 0..self.p {
            if peer == to {
                continue;
            }
            if let Some(buf) = Self::take_from(&mut self.pools[peer].free, need) {
                self.counters.pool_hits += 1;
                return buf;
            }
        }
        self.counters.pool_misses += 1;
        Vec::with_capacity(need)
    }

    /// Hand a consumed payload back to the rank that sent it (the buffer's
    /// owner). Best-effort: if the owner already exited, the buffer is
    /// simply dropped.
    pub fn release(&mut self, from: usize, payload: Vec<E>) {
        if payload.capacity() == 0 || from == self.rank {
            return; // nothing worth shipping back
        }
        let _ = self.ret_txs[from].send((self.rank, payload));
    }

    /// Signal a rendezvous sender that its round-`round` publish has been
    /// fully consumed — the receiver must not touch the published slices
    /// afterwards. Best-effort like [`release`](Endpoint::release).
    /// Epoch-0 form of [`rendezvous_ack_tagged`]
    /// (Endpoint::rendezvous_ack_tagged).
    pub fn rendezvous_ack(&mut self, from: usize, round: u64) {
        self.rendezvous_ack_tagged(from, Tag::untagged(round));
    }

    /// Ack a tagged rendezvous publish (the engine's per-operation path).
    pub fn rendezvous_ack_tagged(&mut self, from: usize, tag: Tag) {
        let _ = self.ack_txs[from].send(tag);
    }

    /// Hand back a consumed [`Payload`], whichever tier it traveled:
    /// pooled buffers return to the sender's pool, rendezvous payloads
    /// are acked. Epoch-0 form of [`complete_tagged`]
    /// (Endpoint::complete_tagged).
    pub fn complete(&mut self, from: usize, round: u64, payload: Payload<E>) {
        self.complete_tagged(from, Tag::untagged(round), payload);
    }

    /// [`complete`](Endpoint::complete) for a tagged operation.
    pub fn complete_tagged(&mut self, from: usize, tag: Tag, payload: Payload<E>) {
        match payload {
            Payload::Copied(v) => self.release(from, v),
            Payload::Remote(_) => self.rendezvous_ack_tagged(from, tag),
        }
    }

    /// Stash an unsolicited arrival — unless it carries a **stale
    /// generation**. Every frame that was not the one a receive was
    /// blocking on enters the stash through here, so this is the single
    /// choke point where pre-recovery traffic is counted and dropped:
    /// after a reconfiguration bumps [`Transport::set_generation`], a
    /// frame whose epoch belongs to an older generation can never be
    /// delivered into a post-recovery operation. Epoch-0 (legacy
    /// untagged) frames and frames from a *newer* generation — a peer
    /// that finished reconfiguring before us — pass through untouched.
    /// Dropped payloads are completed (pool return / rendezvous ack),
    /// not leaked, so a straggling old-generation sender is unstranded.
    fn stash_arrival(&mut self, from: usize, tag: Tag, payload: Payload<E>) {
        if tag.op != 0 && generation_of(tag.op) < self.generation {
            self.stale_frames += 1;
            self.complete_tagged(from, tag, payload);
            return;
        }
        self.stash.insert((from, tag), payload);
    }

    /// Drop the ack for `tag` from the pending set if present.
    fn remove_pending(&mut self, tag: Tag) {
        if let Some(i) = self.pending_acks.iter().position(|&t| t == tag) {
            self.pending_acks.swap_remove(i);
        }
        // Acks for tags not in the set are stale leftovers from aborted
        // rounds (error paths) and are dropped silently — exactly the old
        // single-op behavior for acks older than the awaited round.
    }

    /// Pull every already-delivered ack off the channel (non-blocking).
    fn drain_acks(&mut self) {
        while let Ok(tag) = self.ack_rx.try_recv() {
            self.remove_pending(tag);
        }
    }

    /// Block until every pending ack matching `wait_on` has arrived.
    fn finish_where(&mut self, wait_on: impl Fn(Tag) -> bool) -> Result<(), TransportError> {
        self.drain_acks();
        while let Some(&tag) = self.pending_acks.iter().find(|&&t| wait_on(t)) {
            match self.ack_rx.recv_timeout(self.timeout) {
                Ok(t) => self.remove_pending(t),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::AckTimeout { rank: self.rank, round: tag.round })
                }
                // Unreachable in practice: every endpoint holds a clone of
                // its own ack sender (ack_txs[rank]), so the channel can't
                // disconnect while we're alive to poll it. Mapped to
                // AckTimeout defensively rather than panicking.
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::AckTimeout { rank: self.rank, round: tag.round })
                }
            }
        }
        Ok(())
    }

    /// Block until every outstanding rendezvous publish (any epoch) has
    /// been acked by its receiver. Callers of [`sendrecv_slices`]
    /// (Endpoint::sendrecv_slices) MUST call this before mutating or
    /// freeing the published slices — i.e. at the end of every round.
    /// No-op when nothing was published.
    pub fn finish_round(&mut self) -> Result<(), TransportError> {
        self.finish_where(|_| true)
    }

    /// Block until no publish of operation epoch `op` is outstanding —
    /// the engine's per-operation quiesce (other interleaved operations'
    /// publishes are left pending).
    pub fn finish_op(&mut self, op: u64) -> Result<(), TransportError> {
        self.finish_where(move |t| t.op == op)
    }

    /// Non-blocking ack poll: drain delivered acks and report whether the
    /// publish tagged `tag` (if any) has completed. `true` means the
    /// caller may mutate/free the slices it published under `tag`.
    pub fn try_finish(&mut self, tag: Tag) -> bool {
        self.drain_acks();
        !self.pending_acks.contains(&tag)
    }

    /// Whether any rendezvous publish of operation epoch `op` is still
    /// un-acked (after draining delivered acks). When a quiesce
    /// ([`finish_op`](Endpoint::finish_op)) has *timed out*, the publish
    /// contract is void: a live peer may still hold descriptors into the
    /// published buffer, so freeing it would be a use-after-free on the
    /// peer's side — the engine's failure paths use this predicate to
    /// quarantine such buffers instead of dropping them.
    pub fn op_has_pending_publish(&mut self, op: u64) -> bool {
        self.drain_acks();
        self.pending_acks.iter().any(|t| t.op == op)
    }

    /// Discard every artifact of operation epoch `op` from this endpoint:
    /// stashed payloads of that epoch are *completed* (pooled buffers
    /// return to their sender's pool, rendezvous publishes are acked —
    /// acking without reading is always safe and unblocks the sender) and
    /// its pending-ack entries are dropped (later acks for them are
    /// ignored as stale). Engine workers call this when an op fails so a
    /// long-lived endpoint does not accumulate stranded buffers from
    /// aborted operations. Returns the number of stashed payloads
    /// discarded. Messages of the epoch still in flight when this runs
    /// (a peer that fails later than us) are bounded by that op's
    /// remaining rounds and stay in the stash — rare-failure residue, not
    /// steady-state growth.
    pub fn forget_op(&mut self, op: u64) -> usize {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash_arrival(msg.from, msg.tag, msg.payload);
        }
        let keys: Vec<(usize, Tag)> =
            self.stash.keys().filter(|(_, t)| t.op == op).copied().collect();
        let discarded = keys.len();
        for (from, tag) in keys {
            if let Some(payload) = self.stash.remove(&(from, tag)) {
                self.complete_tagged(from, tag, payload);
            }
        }
        self.drain_acks();
        self.pending_acks.retain(|t| t.op != op);
        discarded
    }

    /// The paper's combined `Send(..) ‖ Recv(..)` primitive, borrow-pack
    /// form: `send` is `(to, head, tail)` — up to two slices (a circular
    /// block range resolves to at most two; pass `&[]` for an absent
    /// tail). The transport gathers them into a pooled buffer, so the
    /// caller neither copies into scratch nor allocates.
    ///
    /// Either side may be `None` (tree rounds). Returns the received
    /// payload if `recv_from` was given; the caller must hand it back via
    /// [`release`](Endpoint::release) once consumed to keep the sender's
    /// pool warm. This entry point never publishes rendezvous descriptors
    /// and panics if the *peer* published some (mixed-protocol misuse);
    /// the schedule executor uses [`sendrecv_slices`]
    /// (Endpoint::sendrecv_slices) instead.
    pub fn sendrecv(
        &mut self,
        send: Option<(usize, &[E], &[E])>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<E>>, TransportError> {
        let send = send.map(|(to, head, tail)| SendSlices { to, head, tail, rendezvous: false });
        let payload = self.sendrecv_slices(send, recv_from, round)?;
        Ok(payload.map(|pl| {
            let from = recv_from.expect("payload implies recv_from");
            pl.expect_copied(self.rank, from)
        }))
    }

    /// Tier-aware sendrecv used by the schedule executor: gathers into a
    /// pooled buffer (tier 2), or — when `send.rendezvous` is set, this
    /// endpoint opted in and the payload is non-empty — publishes
    /// zero-copy descriptors of the slices (tier 1). After a tier-1
    /// publish the caller MUST call [`finish_round`]
    /// (Endpoint::finish_round) before mutating or freeing the slices.
    ///
    /// The returned [`Payload`] (when `recv_from` is given) must be handed
    /// back via [`complete`](Endpoint::complete).
    pub fn sendrecv_slices(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Payload<E>>, TransportError> {
        self.sendrecv_slices_tagged(send, recv_from, Tag::untagged(round))
    }

    /// [`sendrecv_slices`](Endpoint::sendrecv_slices) with a full
    /// operation [`Tag`] — the entry point the per-operation executor
    /// drivers use so several collectives can be in flight on one
    /// endpoint without cross-matching (see the module docs, "Op tags").
    pub fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError> {
        self.counters.sendrecv_rounds += 1;
        if let Some(s) = send {
            debug_assert!(s.to < self.p && s.to != self.rank, "bad send target {}", s.to);
            let publish = s.rendezvous
                && self.rendezvous
                && rendezvous_env_enabled()
                && !s.is_empty()
                && s.len() >= self.rendezvous_min_elems;
            let payload = if publish {
                debug_assert!(
                    !self.pending_acks.contains(&tag),
                    "rendezvous publish for {tag:?} already outstanding"
                );
                self.counters.rendezvous_hits += 1;
                Payload::Remote(RemoteSlices::new(s.head, s.tail))
            } else {
                let mut buf = self.acquire(s.to, s.len());
                buf.extend_from_slice(s.head);
                buf.extend_from_slice(s.tail);
                self.counters.bytes_copied += (std::mem::size_of::<E>() * buf.len()) as u64;
                Payload::Copied(buf)
            };
            self.send_msg(s.to, tag, payload)?;
            // Arm the ack wait only once the publish is actually in
            // flight — a failed send must not leave finish_round parked
            // for an ack nobody can ever deliver.
            if publish {
                self.pending_acks.push(tag);
            }
        }
        match recv_from {
            None => Ok(None),
            Some(from) => self.recv_payload(from, tag).map(Some),
        }
    }

    /// Ownership-transfer variant of [`sendrecv`](Endpoint::sendrecv) for
    /// payloads that are built rather than gathered (the framed, growing
    /// all-to-all messages) — tier 3. Pair with
    /// [`acquire`](Endpoint::acquire) to keep this path pooled too.
    pub fn sendrecv_owned(
        &mut self,
        send: Option<(usize, Vec<E>)>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<E>>, TransportError> {
        let tag = Tag::untagged(round);
        self.counters.sendrecv_rounds += 1;
        if let Some((to, payload)) = send {
            debug_assert!(to < self.p && to != self.rank, "bad send target {to}");
            self.counters.bytes_copied += (std::mem::size_of::<E>() * payload.len()) as u64;
            self.send_msg(to, tag, Payload::Copied(payload))?;
        }
        match recv_from {
            None => Ok(None),
            Some(from) => {
                let payload = self.recv_payload(from, tag)?;
                Ok(Some(payload.expect_copied(self.rank, from)))
            }
        }
    }

    fn send_msg(&mut self, to: usize, tag: Tag, payload: Payload<E>) -> Result<(), TransportError> {
        self.counters.msgs_sent += 1;
        self.counters.elems_sent += payload.len() as u64;
        self.txs[to]
            .send(Msg { from: self.rank, tag, payload })
            .map_err(|_| TransportError::Disconnected { rank: self.rank, to })
    }

    /// Blocking receive of the payload tagged `(from, tag)`, with volume
    /// accounting; stashes out-of-order arrivals from other peers/tags.
    pub fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        let payload = self.recv_tagged(from, tag)?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Ok(payload)
    }

    /// Non-blocking receive: drain whatever has already arrived into the
    /// stash, then take the payload tagged `(from, tag)` if present. The
    /// engine's worker loop polls this so one thread can interleave
    /// several in-flight operations without parking on any single one.
    pub fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>> {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash_arrival(msg.from, msg.tag, msg.payload);
        }
        let payload = self.stash.remove(&(from, tag))?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Some(payload)
    }

    /// Receive the message tagged `(from, tag)`, stashing out-of-order
    /// arrivals from other peers/tags.
    fn recv_tagged(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        if let Some(payload) = self.stash.remove(&(from, tag)) {
            return Ok(payload);
        }
        loop {
            match self.rx.recv_timeout(self.timeout) {
                Ok(msg) => {
                    if msg.from == from && msg.tag == tag {
                        return Ok(msg.payload);
                    }
                    self.stash_arrival(msg.from, msg.tag, msg.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { rank: self.rank, from, round: tag.round })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Disconnected { rank: self.rank, to: from })
                }
            }
        }
    }

    /// Raw one-directional send (used by the coordinator's control plane).
    pub fn send_to(&mut self, to: usize, round: u64, payload: Vec<E>) -> Result<(), TransportError> {
        self.send_msg(to, Tag::untagged(round), Payload::Copied(payload))
    }

    /// Raw one-directional receive.
    pub fn recv_from(&mut self, from: usize, round: u64) -> Result<Vec<E>, TransportError> {
        let payload = self.recv_payload(from, Tag::untagged(round))?;
        Ok(payload.expect_copied(self.rank, from))
    }
}

pub mod fault;
pub mod uds;

/// Capability flags of one transport backend. The executor consults these
/// instead of assuming the thread transport's behavior: a backend that
/// cannot honor the rendezvous publish contract (no shared address space)
/// reports `supports_rendezvous: false`, and every rendezvous-eligible
/// send falls back to the pooled/framed copy tier on that backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransportCaps {
    /// The backend can deliver zero-copy [`RemoteSlices`] descriptors and
    /// honor the publish/ack contract (tier 1).
    pub supports_rendezvous: bool,
    /// [`Transport::release`] actually recycles consumed buffers back to
    /// a pool (tier 2); `false` means release is a plain drop.
    pub supports_loaned_buffers: bool,
    /// Largest payload (bytes) one send moves eagerly; `usize::MAX` means
    /// unbounded (both built-in backends — channels and stream sockets —
    /// have no inline limit).
    pub max_inline_bytes: usize,
}

/// The registered transport backends, selected by the `transport.backend`
/// config key / `CCOLL_TRANSPORT` env knob (loud-parsed by
/// [`crate::env_knobs`]: unknown names abort with the enumerated valid
/// set, same diagnostic grammar as `run.algorithm`/`run.dtype`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportBackend {
    /// In-process channel transport ([`ThreadTransport`]): ranks are OS
    /// threads sharing one address space — the default, and the semantics
    /// oracle every other backend is tested against.
    #[default]
    Thread,
    /// Unix-domain-socket transport ([`uds::UdsTransport`]): ranks are OS
    /// processes on one machine (`ccoll launch --backend uds`).
    Uds,
}

impl TransportBackend {
    /// Accepted names, for diagnostics.
    pub const NAMES_HELP: &'static str = "thread|uds";

    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "thread" => Some(Self::Thread),
            "uds" => Some(Self::Uds),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Thread => "thread",
            Self::Uds => "uds",
        }
    }

    /// The capability flags a transport of this backend reports.
    pub fn caps(&self) -> TransportCaps {
        match self {
            Self::Thread => TransportCaps {
                supports_rendezvous: true,
                supports_loaned_buffers: true,
                max_inline_bytes: usize::MAX,
            },
            Self::Uds => TransportCaps {
                supports_rendezvous: false,
                supports_loaned_buffers: true,
                max_inline_bytes: usize::MAX,
            },
        }
    }
}

/// Every registered backend, for enumerating diagnostics (`ccoll info`
/// prints this table with each backend's capability flags).
pub fn backends() -> &'static [TransportBackend] {
    &[TransportBackend::Thread, TransportBackend::Uds]
}

/// The communication surface the schedule executor
/// ([`crate::collectives::exec::OpCursor`]) and the engine worker loop
/// actually consume, extracted from [`Endpoint`] so the same cursor state
/// machine runs over any backend — threads today, Unix-domain sockets
/// ([`uds::UdsTransport`]), shared memory or RDMA tomorrow.
///
/// Contract notes, backend-independent:
///
/// * all wire artifacts are keyed by [`Tag`] (see the module docs);
/// * a send whose [`SendSlices::rendezvous`] verdict is `true` may only
///   publish descriptors when [`Transport::caps`] reports
///   `supports_rendezvous` — otherwise it must travel a copy tier, and
///   the quiesce family (`finish_*`, `op_has_pending_publish`) degrades
///   to no-ops that report "nothing pending";
/// * **all** payload-byte crediting flows through
///   [`Transport::credit_copied`] / the backend's own send paths into
///   [`Counters::bytes_copied`], so no backend can silently under-report
///   copy volume (the `perf_hotpath` ablation asserts non-zero on the
///   pooled tier).
pub trait Transport<E: Elem> {
    /// This endpoint's rank in `0..p`.
    fn rank(&self) -> usize;
    /// World size.
    fn p(&self) -> usize;
    /// Capability flags of this backend (fixed per backend).
    fn caps(&self) -> TransportCaps;

    /// The paper's one-ported simultaneous send/receive over up to two
    /// working-vector slices, tagged. See
    /// [`Endpoint::sendrecv_slices_tagged`] for tier semantics.
    fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError>;

    /// Blocking receive of the payload tagged `(from, tag)`.
    fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError>;

    /// Non-blocking receive; `None` when nothing matching has arrived.
    fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>>;

    /// Hand back a consumed payload, whichever tier it traveled.
    fn complete_tagged(&mut self, from: usize, tag: Tag, payload: Payload<E>);

    /// Check out an empty buffer of at least `need` capacity for a
    /// message to `to` (pool-recycled where the backend supports it).
    fn acquire(&mut self, to: usize, need: usize) -> Vec<E>;

    /// Return a consumed buffer toward whoever can reuse it.
    fn release(&mut self, from: usize, payload: Vec<E>);

    /// Block until every outstanding publish (any epoch) is acked.
    fn finish_round(&mut self) -> Result<(), TransportError>;

    /// Block until no publish of epoch `op` is outstanding.
    fn finish_op(&mut self, op: u64) -> Result<(), TransportError>;

    /// Non-blocking: `true` when no publish tagged `tag` is outstanding.
    fn try_finish(&mut self, tag: Tag) -> bool;

    /// Whether any publish of epoch `op` is still un-acked.
    fn op_has_pending_publish(&mut self, op: u64) -> bool;

    /// Discard every artifact of epoch `op`; returns payloads discarded.
    fn forget_op(&mut self, op: u64) -> usize;

    /// Volume counters (read side).
    fn counters(&self) -> &Counters;

    /// Volume counters (credit side — plan hits etc.).
    fn counters_mut(&mut self) -> &mut Counters;

    /// Credit `bytes` of physical payload copy to this transport. The
    /// executor routes its `Store` scatter accounting through this, so
    /// copy-volume reporting is uniform across backends.
    fn credit_copied(&mut self, bytes: u64) {
        self.counters_mut().bytes_copied += bytes;
    }

    /// Per-peer liveness as seen by this endpoint: `status[r]` is `true`
    /// while peer `r` is believed alive. Backends with no failure
    /// detector (the in-process thread transport — a thread cannot
    /// vanish without the whole process going with it) report all-up;
    /// the UDS backend flips a peer's bit the moment its reader thread
    /// observes EOF or an IO error, and [`fault::FaultTransport`]
    /// flips them on injected kills. One's own slot is always `true`.
    fn peer_status(&self) -> Vec<bool> {
        vec![true; self.p()]
    }

    /// Failure detail for a down peer (`None` while the peer is up) —
    /// the `detail` a [`TransportError::PeerDown`] for that peer would
    /// carry. Default: no peer is ever down.
    fn peer_down(&self, _peer: usize) -> Option<String> {
        None
    }

    /// Receive/ack timeout currently in force.
    fn timeout(&self) -> Duration;
    fn set_timeout(&mut self, timeout: Duration);

    /// Opt in/out of the rendezvous tier. No-op on backends whose caps
    /// report `supports_rendezvous: false`.
    fn set_rendezvous(&mut self, on: bool);

    /// Minimum payload (elements) for a rendezvous publish. No-op on
    /// non-rendezvous backends.
    fn set_rendezvous_min_elems(&mut self, min: usize);

    /// Retry policy for *transient* transport errors (interrupted /
    /// would-block socket writes): up to `attempts` retries with
    /// `base_ms` backoff doubling per attempt. No-op on backends with
    /// nothing transient (in-process channels either deliver or the
    /// process is gone). Defaults come from `CCOLL_RETRY_ATTEMPTS` /
    /// `CCOLL_RETRY_BASE_MS`; the engine applies its `engine.retry.*`
    /// config through this.
    fn set_retry(&mut self, _attempts: usize, _base_ms: u64) {}

    /// Recovery generation this endpoint currently accepts frames for
    /// (see [`compose_op`]). Backends without generation awareness are
    /// permanently at 0 — exactly the pre-recovery wire behavior.
    fn generation(&self) -> u64 {
        0
    }

    /// Move this endpoint to generation `gen`: from now on an arrival
    /// tagged with any *older* generation is counted and dropped at the
    /// stash boundary instead of ever being delivered. Arrivals from a
    /// *newer* generation (a peer that reconfigured first) are kept.
    /// No-op on backends with no generation state.
    fn set_generation(&mut self, _gen: u64) {}

    /// Frames dropped so far for carrying a stale generation.
    fn stale_frames_dropped(&self) -> u64 {
        0
    }
}

/// The default in-process backend: [`Endpoint`] under its trait name. All
/// PR 1–5 entry points construct it directly ([`network_typed`]) and its
/// counters semantics are unchanged — it is the oracle the cross-backend
/// bit-identity suite compares every other backend against.
pub type ThreadTransport<E = f32> = Endpoint<E>;

impl<E: Elem> Transport<E> for Endpoint<E> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn p(&self) -> usize {
        self.p
    }

    fn caps(&self) -> TransportCaps {
        TransportBackend::Thread.caps()
    }

    fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError> {
        Endpoint::sendrecv_slices_tagged(self, send, recv_from, tag)
    }

    fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        Endpoint::recv_payload(self, from, tag)
    }

    fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>> {
        Endpoint::try_recv_payload(self, from, tag)
    }

    fn complete_tagged(&mut self, from: usize, tag: Tag, payload: Payload<E>) {
        Endpoint::complete_tagged(self, from, tag, payload)
    }

    fn acquire(&mut self, to: usize, need: usize) -> Vec<E> {
        Endpoint::acquire(self, to, need)
    }

    fn release(&mut self, from: usize, payload: Vec<E>) {
        Endpoint::release(self, from, payload)
    }

    fn finish_round(&mut self) -> Result<(), TransportError> {
        Endpoint::finish_round(self)
    }

    fn finish_op(&mut self, op: u64) -> Result<(), TransportError> {
        Endpoint::finish_op(self, op)
    }

    fn try_finish(&mut self, tag: Tag) -> bool {
        Endpoint::try_finish(self, tag)
    }

    fn op_has_pending_publish(&mut self, op: u64) -> bool {
        Endpoint::op_has_pending_publish(self, op)
    }

    fn forget_op(&mut self, op: u64) -> usize {
        Endpoint::forget_op(self, op)
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn timeout(&self) -> Duration {
        self.timeout
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_rendezvous(&mut self, on: bool) {
        self.rendezvous = on;
    }

    fn set_rendezvous_min_elems(&mut self, min: usize) {
        self.rendezvous_min_elems = min;
    }

    fn generation(&self) -> u64 {
        self.generation
    }

    fn set_generation(&mut self, gen: u64) {
        self.generation = gen;
    }

    fn stale_frames_dropped(&self) -> u64 {
        self.stale_frames
    }
}

/// A dense-rank remapping decorator: presents a contiguous `0..p'` rank
/// space over a backend whose peers live in a (possibly sparser)
/// *physical* rank space. Constructed as the identity over the full
/// network, it is transparent; after a recovery reconfiguration the
/// engine shrinks its map to the survivor set, and every schedule-facing
/// surface — `rank()`, `p()`, peer indices on sends/receives,
/// `peer_status()` — speaks dense survivor ranks while the wrapped
/// backend keeps addressing its original sockets/channels. This is what
/// lets the rebuilt p′ circulant plans run unchanged over the survivors:
/// the plans are pure functions of the dense world size.
pub struct Remap<E: Elem, T> {
    inner: T,
    /// `map[dense] = physical` — strictly increasing after a recovery
    /// (survivors keep their relative order), identity at construction.
    map: Vec<usize>,
    /// Cached dense rank (position of `inner.rank()` in `map`).
    rank: usize,
    _elem: std::marker::PhantomData<E>,
}

impl<E: Elem, T: Transport<E>> Remap<E, T> {
    /// Identity wrapper over the backend's full rank space.
    pub fn new(inner: T) -> Self {
        let map: Vec<usize> = (0..inner.p()).collect();
        let rank = inner.rank();
        Self { inner, map, rank, _elem: std::marker::PhantomData }
    }

    /// Install a new dense→physical map (the survivor set, in physical
    /// order). Panics if the map excludes this endpoint's own physical
    /// rank — a survivor cannot remap itself out of the world.
    pub fn set_map(&mut self, map: Vec<usize>) {
        let physical = self.inner.rank();
        self.rank = map
            .iter()
            .position(|&ph| ph == physical)
            .unwrap_or_else(|| panic!("remap {map:?} excludes own physical rank {physical}"));
        self.map = map;
    }

    /// The dense→physical map currently in force.
    pub fn map(&self) -> &[usize] {
        &self.map
    }

    /// The wrapped backend's own (physical) rank, independent of any
    /// remapping.
    pub fn physical_rank(&self) -> usize {
        self.inner.rank()
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut T {
        &mut self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    fn phys(&self, dense: usize) -> usize {
        self.map[dense]
    }
}

impl<E: Elem, T: Transport<E>> Transport<E> for Remap<E, T> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn p(&self) -> usize {
        self.map.len()
    }

    fn caps(&self) -> TransportCaps {
        self.inner.caps()
    }

    fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError> {
        let send = send.map(|s| SendSlices { to: self.phys(s.to), ..s });
        let recv_from = recv_from.map(|f| self.phys(f));
        self.inner.sendrecv_slices_tagged(send, recv_from, tag)
    }

    fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        self.inner.recv_payload(self.phys(from), tag)
    }

    fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>> {
        self.inner.try_recv_payload(self.phys(from), tag)
    }

    fn complete_tagged(&mut self, from: usize, tag: Tag, payload: Payload<E>) {
        let from = self.phys(from);
        self.inner.complete_tagged(from, tag, payload)
    }

    fn acquire(&mut self, to: usize, need: usize) -> Vec<E> {
        let to = self.phys(to);
        self.inner.acquire(to, need)
    }

    fn release(&mut self, from: usize, payload: Vec<E>) {
        let from = self.phys(from);
        self.inner.release(from, payload)
    }

    fn finish_round(&mut self) -> Result<(), TransportError> {
        self.inner.finish_round()
    }

    fn finish_op(&mut self, op: u64) -> Result<(), TransportError> {
        self.inner.finish_op(op)
    }

    fn try_finish(&mut self, tag: Tag) -> bool {
        self.inner.try_finish(tag)
    }

    fn op_has_pending_publish(&mut self, op: u64) -> bool {
        self.inner.op_has_pending_publish(op)
    }

    fn forget_op(&mut self, op: u64) -> usize {
        self.inner.forget_op(op)
    }

    fn counters(&self) -> &Counters {
        self.inner.counters()
    }

    fn counters_mut(&mut self) -> &mut Counters {
        self.inner.counters_mut()
    }

    fn peer_status(&self) -> Vec<bool> {
        let inner = self.inner.peer_status();
        self.map.iter().map(|&ph| inner[ph]).collect()
    }

    fn peer_down(&self, peer: usize) -> Option<String> {
        self.inner.peer_down(self.phys(peer))
    }

    fn timeout(&self) -> Duration {
        self.inner.timeout()
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.inner.set_timeout(timeout)
    }

    fn set_rendezvous(&mut self, on: bool) {
        self.inner.set_rendezvous(on)
    }

    fn set_rendezvous_min_elems(&mut self, min: usize) {
        self.inner.set_rendezvous_min_elems(min)
    }

    fn set_retry(&mut self, attempts: usize, base_ms: u64) {
        self.inner.set_retry(attempts, base_ms)
    }

    fn generation(&self) -> u64 {
        self.inner.generation()
    }

    fn set_generation(&mut self, gen: u64) {
        self.inner.set_generation(gen)
    }

    fn stale_frames_dropped(&self) -> u64 {
        self.inner.stale_frames_dropped()
    }
}

/// Run `f(rank, endpoint)` on `p` threads over an **f32** network, one per
/// rank, and collect the per-rank results in rank order. Panics in any
/// rank are propagated. See [`run_ranks_typed`] for other dtypes.
pub fn run_ranks<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint) -> T + Send + Sync + 'static,
{
    run_ranks_typed::<f32, T, F>(p, f)
}

/// [`run_ranks`] over a network of any element type.
pub fn run_ranks_typed<E: Elem, T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint<E>) -> T + Send + Sync + 'static,
{
    run_ranks_inputs_typed::<E, (), T, _>(vec![(); p], move |rank, ep, ()| f(rank, ep))
}

/// Like [`run_ranks`] but moves one element of `inputs` into each rank's
/// closure (rank r gets `inputs[r]`) — per-rank working vectors travel by
/// move through the spawn, with no shared `Mutex` hand-off. f32 network;
/// see [`run_ranks_inputs_typed`] for other dtypes.
pub fn run_ranks_inputs<I, T, F>(inputs: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint, I) -> T + Send + Sync + 'static,
{
    run_ranks_inputs_typed::<f32, I, T, F>(inputs, f)
}

/// [`run_ranks_inputs`] over a network of any element type.
pub fn run_ranks_inputs_typed<E: Elem, I, T, F>(inputs: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint<E>, I) -> T + Send + Sync + 'static,
{
    let p = inputs.len();
    let endpoints = network_typed::<E>(p);
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::with_capacity(p);
    for ((rank, mut ep), input) in endpoints.into_iter().enumerate().zip(inputs) {
        let f = f.clone();
        note_rank_thread_spawn();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(8 << 20)
                .spawn(move || f(rank, &mut ep, input))
                .expect("spawn rank thread"),
        );
    }
    handles
        .into_iter()
        .enumerate()
        .map(|(rank, h)| h.join().unwrap_or_else(|e| std::panic::resume_unwind(Box::new(format!("rank {rank} panicked: {e:?}")))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_sendrecv_roundtrip() {
        let out = run_ranks(4, |rank, ep| {
            let to = (rank + 1) % 4;
            let from = (rank + 3) % 4;
            let got = ep
                .sendrecv(Some((to, &[rank as f32], &[])), Some(from), 0)
                .unwrap()
                .unwrap();
            got[0]
        });
        assert_eq!(out, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn borrow_pack_gathers_two_slices() {
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            let head = [rank as f32, 10.0];
            let tail = [20.0];
            ep.sendrecv(Some((peer, &head, &tail)), Some(peer), 0).unwrap().unwrap()
        });
        assert_eq!(out[0], vec![1.0, 10.0, 20.0]);
        assert_eq!(out[1], vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn out_of_order_rounds_are_stashed() {
        // Rank 1 sends rounds 0 and 1 immediately; rank 0 consumes round 1
        // first, then round 0 — the stash must reorder.
        let out = run_ranks(2, |rank, ep| {
            if rank == 1 {
                ep.send_to(0, 0, vec![10.0]).unwrap();
                ep.send_to(0, 1, vec![11.0]).unwrap();
                vec![]
            } else {
                let b = ep.recv_from(1, 1).unwrap();
                let a = ep.recv_from(1, 0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[0], vec![10.0, 11.0]);
    }

    #[test]
    fn counters_track_volume() {
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            ep.sendrecv(Some((peer, &[0.0; 7], &[])), Some(peer), 0).unwrap();
            ep.counters.clone()
        });
        for c in out {
            assert_eq!(c.msgs_sent, 1);
            assert_eq!(c.msgs_recv, 1);
            assert_eq!(c.elems_sent, 7);
            assert_eq!(c.elems_recv, 7);
            // pooled gather copies every payload byte; no rendezvous
            assert_eq!(c.bytes_copied, 7 * 4);
            assert_eq!(c.rendezvous_hits, 0);
        }
    }

    #[test]
    fn typed_network_counts_bytes_at_the_element_size() {
        // Same exchange as above but over i64: copy volume must be
        // accounted at 8 bytes/element.
        let out = run_ranks_typed::<i64, _, _>(2, |rank, ep| {
            let peer = 1 - rank;
            let data = [rank as i64; 7];
            let got = ep.sendrecv(Some((peer, &data, &[])), Some(peer), 0).unwrap().unwrap();
            (got, ep.counters.clone())
        });
        for (rank, (got, c)) in out.iter().enumerate() {
            assert_eq!(got, &vec![(1 - rank) as i64; 7]);
            assert_eq!(c.elems_sent, 7);
            assert_eq!(c.bytes_copied, 7 * 8, "i64 gather must count 8 bytes/elem");
        }
    }

    #[test]
    fn timeout_detects_missing_peer() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.timeout = Duration::from_millis(50);
                ep.sendrecv(None, Some(1), 7).map(|_| ()).is_err()
            } else {
                true // rank 1 never sends
            }
        });
        assert!(out[0], "rank 0 should have timed out");
    }

    #[test]
    fn sendrecv_with_only_send_side() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.sendrecv(Some((1, &[5.0], &[])), None, 0).unwrap();
                0.0
            } else {
                ep.sendrecv(None, Some(0), 0).unwrap().unwrap()[0]
            }
        });
        assert_eq!(out[1], 5.0);
    }

    #[test]
    fn released_buffers_return_to_the_senders_pool() {
        // Lock-step ping-pong: after the first exchange returns buffers,
        // every later acquire must be a pool hit on both ranks.
        let rounds = 16u64;
        let out = run_ranks(2, move |rank, ep| {
            let peer = 1 - rank;
            let data = [rank as f32; 32];
            for round in 0..rounds {
                let got = ep.sendrecv(Some((peer, &data, &[])), Some(peer), round).unwrap().unwrap();
                assert_eq!(got.len(), 32);
                ep.release(peer, got);
            }
            ep.counters.clone()
        });
        for (rank, c) in out.iter().enumerate() {
            assert_eq!(c.pool_hits + c.pool_misses, rounds, "rank {rank}");
            // First acquire (or two, depending on interleaving) may miss;
            // once a buffer circulates the pool must serve every acquire.
            assert!(c.pool_misses <= 2, "rank {rank}: {} misses", c.pool_misses);
            assert!(c.bufs_recycled > 0, "rank {rank}: nothing recycled");
        }
    }

    #[test]
    fn acquire_prefers_buffer_with_sufficient_capacity() {
        let mut eps = network(2);
        let ep = &mut eps[0];
        // Seed the pool for peer 1 with a small and a big buffer.
        ep.pools[1].free.push(Vec::with_capacity(4));
        ep.pools[1].free.push(Vec::with_capacity(64));
        let buf = ep.acquire(1, 32);
        assert!(buf.capacity() >= 32, "picked the too-small buffer");
        assert_eq!(ep.counters.pool_hits, 1);
        // A request no pooled buffer can hold is a miss — the undersized
        // buffer stays in the pool rather than being handed out to regrow
        // (a hit must never hide a heap allocation).
        let big = ep.acquire(1, 1000);
        assert!(big.capacity() >= 1000);
        assert_eq!(ep.counters.pool_misses, 1);
        // The remaining (small) buffer still serves a small request.
        let buf2 = ep.acquire(1, 2);
        assert!(buf2.capacity() >= 2);
        assert_eq!(ep.counters.pool_hits, 2);
        // Now everything is checked out: next acquire is a miss.
        ep.acquire(1, 8);
        assert_eq!(ep.counters.pool_misses, 2);
    }

    #[test]
    fn rendezvous_publish_reads_senders_memory_zero_copy() {
        if !rendezvous_env_enabled() {
            return; // kill-switch active: the publish path is off by design
        }
        // Ring of 3: each rank publishes its buffer, the receiver reads it
        // directly and acks; finish_round releases the sender.
        let out = run_ranks(3, |rank, ep| {
            ep.rendezvous = true;
            ep.rendezvous_min_elems = 0;
            let data = [rank as f32, 100.0 + rank as f32];
            let to = (rank + 1) % 3;
            let from = (rank + 2) % 3;
            let send = SendSlices { to, head: &data[..1], tail: &data[1..], rendezvous: true };
            let payload = ep.sendrecv_slices(Some(send), Some(from), 0).unwrap().unwrap();
            let got = match &payload {
                Payload::Remote(r) => {
                    assert_eq!(r.elem_bytes(), 4, "f32 descriptors are 4 bytes/elem");
                    let (h, t) = unsafe { r.slices() };
                    vec![h[0], t[0]]
                }
                Payload::Copied(_) => panic!("expected a rendezvous payload"),
            };
            ep.complete(from, 0, payload);
            ep.finish_round().unwrap();
            (got, ep.counters.clone())
        });
        for (rank, (got, c)) in out.iter().enumerate() {
            let from = (rank + 2) % 3;
            assert_eq!(got, &vec![from as f32, 100.0 + from as f32]);
            assert_eq!(c.rendezvous_hits, 1, "rank {rank}");
            assert_eq!(c.bytes_copied, 0, "rank {rank}: rendezvous must copy nothing");
            assert_eq!(c.pool_hits + c.pool_misses, 0, "rank {rank}: no pool traffic");
        }
    }

    #[test]
    fn rendezvous_disabled_endpoint_falls_back_to_pooled() {
        // Caller says rendezvous is safe, but the endpoint never opted in:
        // the payload must travel the pooled tier.
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            let data = [rank as f32; 4];
            let send = SendSlices { to: peer, head: &data, tail: &[], rendezvous: true };
            let payload = ep.sendrecv_slices(Some(send), Some(peer), 0).unwrap().unwrap();
            let ok = matches!(payload, Payload::Copied(_));
            ep.complete(peer, 0, payload);
            ep.finish_round().unwrap(); // no-op: nothing published
            (ok, ep.counters.rendezvous_hits)
        });
        for (ok, hits) in out {
            assert!(ok, "payload should have been pooled");
            assert_eq!(hits, 0);
        }
    }

    #[test]
    fn finish_round_times_out_when_receiver_never_acks() {
        if !rendezvous_env_enabled() {
            return; // kill-switch active: nothing is ever published
        }
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.rendezvous = true;
                ep.rendezvous_min_elems = 0;
                ep.timeout = Duration::from_millis(50);
                let data = [1.0f32; 8];
                let send = SendSlices { to: 1, head: &data, tail: &[], rendezvous: true };
                ep.sendrecv_slices(Some(send), None, 0).unwrap();
                matches!(ep.finish_round(), Err(TransportError::AckTimeout { .. }))
            } else {
                // rank 1 receives the descriptors but never acks
                let _payload = ep.sendrecv_slices(None, Some(0), 0).unwrap();
                true
            }
        });
        assert!(out[0], "sender should time out awaiting the ack");
    }

    #[test]
    fn empty_publish_skips_rendezvous() {
        let mut eps = network(2);
        let ep = &mut eps[0];
        ep.rendezvous = true;
        ep.rendezvous_min_elems = 0;
        let send = SendSlices { to: 1, head: &[], tail: &[], rendezvous: true };
        ep.sendrecv_slices(Some(send), None, 0).unwrap();
        assert_eq!(ep.counters.rendezvous_hits, 0, "empty payloads stay pooled");
        ep.finish_round().unwrap();
    }

    #[test]
    fn small_payloads_stay_pooled_below_the_threshold() {
        if !rendezvous_env_enabled() {
            return; // kill-switch active: nothing is ever published
        }
        let mut eps = network(2);
        let ep = &mut eps[0];
        ep.rendezvous = true;
        ep.rendezvous_min_elems = 8;
        let data = [1.0f32; 4]; // below the threshold
        let send = SendSlices { to: 1, head: &data, tail: &[], rendezvous: true };
        ep.sendrecv_slices(Some(send), None, 0).unwrap();
        assert_eq!(ep.counters.rendezvous_hits, 0);
        assert_eq!(ep.counters.bytes_copied, 16, "gathered via the pooled tier");
        // at the threshold it publishes
        let data = [1.0f32; 8];
        let send = SendSlices { to: 1, head: &data, tail: &[], rendezvous: true };
        ep.sendrecv_slices(Some(send), None, 1).unwrap();
        assert_eq!(ep.counters.rendezvous_hits, 1);
        // quiesce: nobody will ack, so clear the pending publish by hand
        // (unit-test only; eps[1] never ran)
        ep.timeout = Duration::from_millis(20);
        assert!(ep.finish_round().is_err());
    }

    #[test]
    fn op_tags_do_not_cross_match() {
        // Two interleaved "operations" use the same round numbers in
        // different epochs: matching must key on (op, round), not round
        // alone — the concurrent-collectives wire discipline.
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            let a = Tag::new(1, 0);
            let b = Tag::new(2, 0);
            let pay_a = [100.0 + rank as f32];
            let pay_b = [200.0 + rank as f32];
            // Send op 2's round 0 first…
            ep.sendrecv_slices_tagged(
                Some(SendSlices { to: peer, head: &pay_b, tail: &[], rendezvous: false }),
                None,
                b,
            )
            .unwrap();
            ep.sendrecv_slices_tagged(
                Some(SendSlices { to: peer, head: &pay_a, tail: &[], rendezvous: false }),
                None,
                a,
            )
            .unwrap();
            // …but consume op 1's first: the stash must hold them apart.
            let got_a = ep.recv_payload(peer, a).unwrap();
            let got_b = ep.recv_payload(peer, b).unwrap();
            let va = got_a.expect_copied(rank, peer);
            let vb = got_b.expect_copied(rank, peer);
            (va[0], vb[0])
        });
        for (rank, &(va, vb)) in out.iter().enumerate() {
            let peer = (1 - rank) as f32;
            assert_eq!(va, 100.0 + peer, "rank {rank}: op-1 payload");
            assert_eq!(vb, 200.0 + peer, "rank {rank}: op-2 payload");
        }
    }

    #[test]
    fn try_recv_and_try_finish_poll_without_blocking() {
        let mut eps = network(2);
        // Nothing sent yet: polling must return None, not park.
        assert!(eps[0].try_recv_payload(1, Tag::untagged(0)).is_none());
        eps[1].send_to(0, 5, vec![42.0]).unwrap();
        // The message is in flight on an in-process channel; drain + take.
        let got = eps[0]
            .try_recv_payload(1, Tag::untagged(5))
            .expect("message already delivered")
            .expect_copied(0, 1);
        assert_eq!(got, vec![42.0]);
        assert_eq!(eps[0].counters.msgs_recv, 1);
    }

    #[test]
    fn try_finish_tracks_per_op_publishes() {
        if !rendezvous_env_enabled() {
            return; // kill-switch active: nothing is ever published
        }
        let mut eps = network(2);
        eps[0].rendezvous = true;
        eps[0].rendezvous_min_elems = 0;
        let data = [1.0f32; 4];
        let t1 = Tag::new(1, 0);
        let t2 = Tag::new(2, 0);
        let send = |to| SendSlices { to, head: &data, tail: &[], rendezvous: true };
        eps[0].sendrecv_slices_tagged(Some(send(1)), None, t1).unwrap();
        eps[0].sendrecv_slices_tagged(Some(send(1)), None, t2).unwrap();
        assert!(!eps[0].try_finish(t1), "op 1 publish still outstanding");
        assert!(!eps[0].try_finish(t2), "op 2 publish still outstanding");
        // Receiver acks op 2 only: op 1 must stay pending.
        eps[1].rendezvous_ack_tagged(0, t2);
        assert!(eps[0].try_finish(t2), "op 2 acked");
        assert!(!eps[0].try_finish(t1), "op 1 must not be released by op 2's ack");
        eps[1].rendezvous_ack_tagged(0, t1);
        assert!(eps[0].try_finish(t1));
        // finish_op on a quiesced epoch is a no-op.
        eps[0].finish_op(1).unwrap();
        eps[0].finish_round().unwrap();
    }

    #[test]
    fn forget_op_discards_only_that_epochs_artifacts() {
        let mut eps = network(2);
        let data = [1.0f32; 4];
        let send = |to| SendSlices { to, head: &data[..], tail: &[][..], rendezvous: false };
        // Two payloads of epoch 9 and one of epoch 3 arrive at rank 0.
        eps[1].sendrecv_slices_tagged(Some(send(0)), None, Tag::new(9, 0)).unwrap();
        eps[1].sendrecv_slices_tagged(Some(send(0)), None, Tag::new(9, 1)).unwrap();
        eps[1].sendrecv_slices_tagged(Some(send(0)), None, Tag::new(3, 0)).unwrap();
        assert_eq!(eps[0].forget_op(9), 2, "both epoch-9 payloads discarded");
        // Epoch 3 is untouched and still receivable.
        let got =
            eps[0].recv_payload(1, Tag::new(3, 0)).unwrap().expect_copied(0, 1);
        assert_eq!(got, vec![1.0; 4]);
        // A pending publish of a forgotten epoch is dropped too, so no
        // later wait can park on an ack that will never be matched.
        if rendezvous_env_enabled() {
            eps[0].rendezvous = true;
            eps[0].rendezvous_min_elems = 0;
            let s = SendSlices { to: 1, head: &data[..], tail: &[][..], rendezvous: true };
            eps[0].sendrecv_slices_tagged(Some(s), None, Tag::new(9, 2)).unwrap();
            assert!(!eps[0].try_finish(Tag::new(9, 2)));
            eps[0].forget_op(9);
            assert!(eps[0].try_finish(Tag::new(9, 2)));
            eps[0].finish_round().unwrap();
        }
    }

    #[test]
    fn backend_registry_parses_and_reports_caps() {
        assert_eq!(TransportBackend::parse("thread"), Some(TransportBackend::Thread));
        assert_eq!(TransportBackend::parse("uds"), Some(TransportBackend::Uds));
        assert_eq!(TransportBackend::parse("tcp"), None);
        assert_eq!(TransportBackend::default(), TransportBackend::Thread);
        assert!(TransportBackend::Thread.caps().supports_rendezvous);
        assert!(!TransportBackend::Uds.caps().supports_rendezvous);
        // Every registered backend round-trips through parse(name()).
        for b in backends() {
            assert_eq!(TransportBackend::parse(b.name()), Some(*b));
            assert!(TransportBackend::NAMES_HELP.contains(b.name()));
        }
    }

    #[test]
    fn endpoint_implements_the_transport_trait_with_identical_semantics() {
        // Drive a 2-rank exchange purely through the trait surface: the
        // ThreadTransport impl must delegate to the inherent methods, so
        // counters and payloads match the concrete-API tests exactly.
        fn exchange<C: Transport<f32>>(ep: &mut C, peer: usize) -> Vec<f32> {
            let data = [ep.rank() as f32; 7];
            let send =
                SendSlices { to: peer, head: &data, tail: &[], rendezvous: false };
            let payload = ep
                .sendrecv_slices_tagged(Some(send), Some(peer), Tag::untagged(0))
                .unwrap()
                .unwrap();
            let got = match &payload {
                Payload::Copied(v) => v.clone(),
                Payload::Remote(_) => panic!("non-rendezvous send published"),
            };
            ep.complete_tagged(peer, Tag::untagged(0), payload);
            ep.finish_round().unwrap();
            got
        }
        let out = run_ranks(2, |rank, ep| {
            assert_eq!(Transport::<f32>::rank(ep), rank);
            assert_eq!(Transport::<f32>::p(ep), 2);
            let got = exchange(ep, 1 - rank);
            (got, ep.counters.clone())
        });
        for (rank, (got, c)) in out.iter().enumerate() {
            assert_eq!(got, &vec![(1 - rank) as f32; 7]);
            assert_eq!(c.bytes_copied, 7 * 4, "trait path must credit the gather");
        }
    }

    #[test]
    fn typed_rendezvous_roundtrip_i64() {
        if !rendezvous_env_enabled() {
            return;
        }
        // The zero-copy tier over a non-f32 dtype: descriptors carry the
        // 8-byte element size, payloads arrive bit-exact, nothing copies.
        let out = run_ranks_typed::<i64, _, _>(2, |rank, ep| {
            ep.rendezvous = true;
            ep.rendezvous_min_elems = 0;
            let peer = 1 - rank;
            let data = [rank as i64 - 5, i64::MAX - rank as i64];
            let send = SendSlices { to: peer, head: &data[..1], tail: &data[1..], rendezvous: true };
            let payload = ep.sendrecv_slices(Some(send), Some(peer), 0).unwrap().unwrap();
            let got = match &payload {
                Payload::Remote(r) => {
                    assert_eq!(r.elem_bytes(), 8);
                    let (h, t) = unsafe { r.slices() };
                    vec![h[0], t[0]]
                }
                Payload::Copied(_) => panic!("expected a rendezvous payload"),
            };
            ep.complete(peer, 0, payload);
            ep.finish_round().unwrap();
            (got, ep.counters.bytes_copied)
        });
        for (rank, (got, bytes)) in out.iter().enumerate() {
            let peer = 1 - rank;
            assert_eq!(got, &vec![peer as i64 - 5, i64::MAX - peer as i64]);
            assert_eq!(*bytes, 0, "rank {rank}: rendezvous must copy nothing");
        }
    }
}
