//! In-process message-passing substrate with pooled, recycled payloads.
//!
//! Substitutes for the paper's MPI cluster (DESIGN.md §2): `p` ranks run as
//! OS threads; each rank owns an [`Endpoint`] supporting the paper's
//! communication primitive — a *one-ported simultaneous send/receive*
//! (MPI_Sendrecv): in one operation a rank sends one message to one peer
//! and receives one message from a possibly different peer.
//!
//! Messages are tagged `(from, round)` and stashed on arrival, so the
//! rendezvous is insensitive to thread scheduling while still enforcing the
//! round structure (a message for round `k` can only be consumed by the
//! round-`k` sendrecv). Per-endpoint counters record rounds, messages and
//! element volume for the Theorem 1/2 benches.
//!
//! # The pooled buffer protocol
//!
//! The paper's algorithms move exactly `p−1` blocks per processor
//! (Theorem 1); the transport must not add memory traffic on top. Payload
//! buffers are therefore *loaned, not allocated*:
//!
//!   1. A sender [`acquire`](Endpoint::acquire)s a `Vec<f32>` from its
//!      per-peer [`BufferPool`] (falling back to any peer's pool, then to a
//!      fresh allocation — a *pool miss*).
//!   2. The borrow-pack [`sendrecv`](Endpoint::sendrecv) gathers the
//!      caller's (≤ 2) slices straight into that pooled buffer and ships
//!      it; the caller never owns or allocates the message.
//!   3. The receiver consumes the payload (combine/store) and
//!      [`release`](Endpoint::release)s it: the buffer travels back to the
//!      *sender's* pool over a dedicated return channel and is reused for a
//!      later round.
//!
//! After a warm-up pass every acquire is a pool hit and the steady-state
//! hot path performs **zero payload allocations per round**
//! (`Counters::pool_hits` / `pool_misses` expose the rate; the Perf bench
//! has the ablation). One caveat: a released buffer races the owner's
//! next acquire, and supply only grows on a miss — so a handful of
//! misses bounded by the number of (peer, capacity) classes can occur at
//! any point, but misses never scale with rounds. Send-only rounds
//! recycle identically — the loan protocol does not care whether the
//! round also received. This pool is
//! also the seam where a future shared-memory or RDMA-style transport
//! plugs in: registered buffers replace heap `Vec`s with no executor
//! change.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// A message between ranks: payload plus matching tag. The payload buffer
/// is on loan from the sender's pool (see the module docs).
#[derive(Debug)]
pub struct Msg {
    pub from: usize,
    pub round: u64,
    pub payload: Vec<f32>,
}

/// Transport-level errors (used by failure-injection tests).
#[derive(Debug, thiserror::Error)]
pub enum TransportError {
    #[error("rank {rank}: timeout waiting for round {round} message from {from}")]
    Timeout { rank: usize, from: usize, round: u64 },
    #[error("rank {rank}: peer {to} disconnected")]
    Disconnected { rank: usize, to: usize },
}

/// Volume counters for one endpoint.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Counters {
    pub sendrecv_rounds: u64,
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    pub elems_sent: u64,
    pub elems_recv: u64,
    /// Acquires served allocation-free from a pool (a recycled buffer
    /// with sufficient capacity, ours or another peer's).
    pub pool_hits: u64,
    /// Acquires that had to heap-allocate (no pooled buffer was big
    /// enough) — zero per round in steady state.
    pub pool_misses: u64,
    /// Buffers that came back over the return channel.
    pub bufs_recycled: u64,
}

/// Recycled payload buffers destined for one peer.
#[derive(Debug, Default)]
struct BufferPool {
    free: Vec<Vec<f32>>,
}

/// One rank's communication handle.
pub struct Endpoint {
    pub rank: usize,
    pub p: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Return path: `(returning peer, buffer)` flowing back to this owner.
    ret_txs: Vec<Sender<(usize, Vec<f32>)>>,
    ret_rx: Receiver<(usize, Vec<f32>)>,
    /// `pools[peer]` holds recycled buffers last used for messages to
    /// `peer` (affinity keeps capacities matched to that link's payloads).
    pools: Vec<BufferPool>,
    /// Early arrivals keyed by (from, round).
    stash: HashMap<(usize, u64), Vec<f32>>,
    pub counters: Counters,
    /// Receive timeout — deadlock detection in tests; generous default.
    pub timeout: Duration,
}

/// Build a fully-connected network of `p` endpoints (one per rank).
pub fn network(p: usize) -> Vec<Endpoint> {
    assert!(p >= 1);
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    let mut ret_txs = Vec::with_capacity(p);
    let mut ret_rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
        let (rtx, rrx) = channel::<(usize, Vec<f32>)>();
        ret_txs.push(rtx);
        ret_rxs.push(rrx);
    }
    rxs.into_iter()
        .zip(ret_rxs)
        .enumerate()
        .map(|(rank, (rx, ret_rx))| Endpoint {
            rank,
            p,
            txs: txs.clone(),
            rx,
            ret_txs: ret_txs.clone(),
            ret_rx,
            pools: (0..p).map(|_| BufferPool::default()).collect(),
            stash: HashMap::new(),
            counters: Counters::default(),
            timeout: Duration::from_secs(30),
        })
        .collect()
}

impl Endpoint {
    /// Pull every returned buffer off the return channel into its pool.
    fn drain_returns(&mut self) {
        while let Ok((peer, buf)) = self.ret_rx.try_recv() {
            self.counters.bufs_recycled += 1;
            self.pools[peer].free.push(buf);
        }
    }

    /// Take a buffer with at least `need` capacity from `free`, if one
    /// exists. Undersized buffers are never handed out: a *hit* must mean
    /// the acquire performs no heap allocation (the zero-alloc regression
    /// tests and the perf ablation rely on that counter being honest).
    fn take_from(free: &mut Vec<Vec<f32>>, need: usize) -> Option<Vec<f32>> {
        let i = free.iter().position(|b| b.capacity() >= need)?;
        let mut buf = free.swap_remove(i);
        buf.clear();
        Some(buf)
    }

    /// Check out an empty buffer of at least `need` capacity for a message
    /// to `to`, recycling returned payloads when possible (per-peer
    /// affinity first, then any pool, then — a pool miss — a fresh
    /// allocation). Undersized pooled buffers stay put; they keep serving
    /// the smaller payloads of later rounds.
    ///
    /// `need == 0` (zero-length transfers on degenerate partitions)
    /// bypasses the pool and the hit/miss counters entirely: an empty
    /// `Vec` allocates nothing, and pulling a real buffer out of
    /// circulation for it would starve the payload-carrying rounds.
    pub fn acquire(&mut self, to: usize, need: usize) -> Vec<f32> {
        if need == 0 {
            return Vec::new();
        }
        self.drain_returns();
        if let Some(buf) = Self::take_from(&mut self.pools[to].free, need) {
            self.counters.pool_hits += 1;
            return buf;
        }
        for peer in 0..self.p {
            if peer == to {
                continue;
            }
            if let Some(buf) = Self::take_from(&mut self.pools[peer].free, need) {
                self.counters.pool_hits += 1;
                return buf;
            }
        }
        self.counters.pool_misses += 1;
        Vec::with_capacity(need)
    }

    /// Hand a consumed payload back to the rank that sent it (the buffer's
    /// owner). Best-effort: if the owner already exited, the buffer is
    /// simply dropped.
    pub fn release(&mut self, from: usize, payload: Vec<f32>) {
        if payload.capacity() == 0 || from == self.rank {
            return; // nothing worth shipping back
        }
        let _ = self.ret_txs[from].send((self.rank, payload));
    }

    /// The paper's combined `Send(..) ‖ Recv(..)` primitive, borrow-pack
    /// form: `send` is `(to, head, tail)` — up to two slices (a circular
    /// block range resolves to at most two; pass `&[]` for an absent
    /// tail). The transport gathers them into a pooled buffer, so the
    /// caller neither copies into scratch nor allocates.
    ///
    /// Either side may be `None` (tree rounds). Returns the received
    /// payload if `recv_from` was given; the caller must hand it back via
    /// [`release`](Endpoint::release) once consumed to keep the sender's
    /// pool warm.
    pub fn sendrecv(
        &mut self,
        send: Option<(usize, &[f32], &[f32])>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<f32>>, TransportError> {
        self.counters.sendrecv_rounds += 1;
        if let Some((to, head, tail)) = send {
            debug_assert!(to < self.p && to != self.rank, "bad send target {to}");
            let mut payload = self.acquire(to, head.len() + tail.len());
            payload.extend_from_slice(head);
            payload.extend_from_slice(tail);
            self.send_msg(to, round, payload)?;
        }
        self.recv_side(recv_from, round)
    }

    /// Ownership-transfer variant of [`sendrecv`](Endpoint::sendrecv) for
    /// payloads that are built rather than gathered (the framed, growing
    /// all-to-all messages). Pair with [`acquire`](Endpoint::acquire) to
    /// keep this path pooled too.
    pub fn sendrecv_owned(
        &mut self,
        send: Option<(usize, Vec<f32>)>,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<f32>>, TransportError> {
        self.counters.sendrecv_rounds += 1;
        if let Some((to, payload)) = send {
            debug_assert!(to < self.p && to != self.rank, "bad send target {to}");
            self.send_msg(to, round, payload)?;
        }
        self.recv_side(recv_from, round)
    }

    fn send_msg(&mut self, to: usize, round: u64, payload: Vec<f32>) -> Result<(), TransportError> {
        self.counters.msgs_sent += 1;
        self.counters.elems_sent += payload.len() as u64;
        self.txs[to]
            .send(Msg { from: self.rank, round, payload })
            .map_err(|_| TransportError::Disconnected { rank: self.rank, to })
    }

    fn recv_side(
        &mut self,
        recv_from: Option<usize>,
        round: u64,
    ) -> Result<Option<Vec<f32>>, TransportError> {
        match recv_from {
            None => Ok(None),
            Some(from) => {
                let payload = self.recv_tagged(from, round)?;
                self.counters.msgs_recv += 1;
                self.counters.elems_recv += payload.len() as u64;
                Ok(Some(payload))
            }
        }
    }

    /// Receive the message tagged `(from, round)`, stashing out-of-order
    /// arrivals from other peers/rounds.
    fn recv_tagged(&mut self, from: usize, round: u64) -> Result<Vec<f32>, TransportError> {
        if let Some(payload) = self.stash.remove(&(from, round)) {
            return Ok(payload);
        }
        loop {
            match self.rx.recv_timeout(self.timeout) {
                Ok(msg) => {
                    if msg.from == from && msg.round == round {
                        return Ok(msg.payload);
                    }
                    self.stash.insert((msg.from, msg.round), msg.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { rank: self.rank, from, round })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TransportError::Disconnected { rank: self.rank, to: from })
                }
            }
        }
    }

    /// Raw one-directional send (used by the coordinator's control plane).
    pub fn send_to(&mut self, to: usize, round: u64, payload: Vec<f32>) -> Result<(), TransportError> {
        self.send_msg(to, round, payload)
    }

    /// Raw one-directional receive.
    pub fn recv_from(&mut self, from: usize, round: u64) -> Result<Vec<f32>, TransportError> {
        let payload = self.recv_tagged(from, round)?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Ok(payload)
    }
}

/// Run `f(rank, endpoint)` on `p` threads, one per rank, and collect the
/// per-rank results in rank order. Panics in any rank are propagated.
pub fn run_ranks<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize, &mut Endpoint) -> T + Send + Sync + 'static,
{
    let endpoints = network(p);
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::with_capacity(p);
    for (rank, mut ep) in endpoints.into_iter().enumerate() {
        let f = f.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(8 << 20)
                .spawn(move || f(rank, &mut ep))
                .expect("spawn rank thread"),
        );
    }
    handles
        .into_iter()
        .enumerate()
        .map(|(rank, h)| h.join().unwrap_or_else(|e| std::panic::resume_unwind(Box::new(format!("rank {rank} panicked: {e:?}")))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_sendrecv_roundtrip() {
        let out = run_ranks(4, |rank, ep| {
            let to = (rank + 1) % 4;
            let from = (rank + 3) % 4;
            let got = ep
                .sendrecv(Some((to, &[rank as f32], &[])), Some(from), 0)
                .unwrap()
                .unwrap();
            got[0]
        });
        assert_eq!(out, vec![3.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn borrow_pack_gathers_two_slices() {
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            let head = [rank as f32, 10.0];
            let tail = [20.0];
            ep.sendrecv(Some((peer, &head, &tail)), Some(peer), 0).unwrap().unwrap()
        });
        assert_eq!(out[0], vec![1.0, 10.0, 20.0]);
        assert_eq!(out[1], vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn out_of_order_rounds_are_stashed() {
        // Rank 1 sends rounds 0 and 1 immediately; rank 0 consumes round 1
        // first, then round 0 — the stash must reorder.
        let out = run_ranks(2, |rank, ep| {
            if rank == 1 {
                ep.send_to(0, 0, vec![10.0]).unwrap();
                ep.send_to(0, 1, vec![11.0]).unwrap();
                vec![]
            } else {
                let b = ep.recv_from(1, 1).unwrap();
                let a = ep.recv_from(1, 0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[0], vec![10.0, 11.0]);
    }

    #[test]
    fn counters_track_volume() {
        let out = run_ranks(2, |rank, ep| {
            let peer = 1 - rank;
            ep.sendrecv(Some((peer, &[0.0; 7], &[])), Some(peer), 0).unwrap();
            ep.counters.clone()
        });
        for c in out {
            assert_eq!(c.msgs_sent, 1);
            assert_eq!(c.msgs_recv, 1);
            assert_eq!(c.elems_sent, 7);
            assert_eq!(c.elems_recv, 7);
        }
    }

    #[test]
    fn timeout_detects_missing_peer() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.timeout = Duration::from_millis(50);
                ep.sendrecv(None, Some(1), 7).map(|_| ()).is_err()
            } else {
                true // rank 1 never sends
            }
        });
        assert!(out[0], "rank 0 should have timed out");
    }

    #[test]
    fn sendrecv_with_only_send_side() {
        let out = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.sendrecv(Some((1, &[5.0], &[])), None, 0).unwrap();
                0.0
            } else {
                ep.sendrecv(None, Some(0), 0).unwrap().unwrap()[0]
            }
        });
        assert_eq!(out[1], 5.0);
    }

    #[test]
    fn released_buffers_return_to_the_senders_pool() {
        // Lock-step ping-pong: after the first exchange returns buffers,
        // every later acquire must be a pool hit on both ranks.
        let rounds = 16u64;
        let out = run_ranks(2, move |rank, ep| {
            let peer = 1 - rank;
            let data = [rank as f32; 32];
            for round in 0..rounds {
                let got = ep.sendrecv(Some((peer, &data, &[])), Some(peer), round).unwrap().unwrap();
                assert_eq!(got.len(), 32);
                ep.release(peer, got);
            }
            ep.counters.clone()
        });
        for (rank, c) in out.iter().enumerate() {
            assert_eq!(c.pool_hits + c.pool_misses, rounds, "rank {rank}");
            // First acquire (or two, depending on interleaving) may miss;
            // once a buffer circulates the pool must serve every acquire.
            assert!(c.pool_misses <= 2, "rank {rank}: {} misses", c.pool_misses);
            assert!(c.bufs_recycled > 0, "rank {rank}: nothing recycled");
        }
    }

    #[test]
    fn acquire_prefers_buffer_with_sufficient_capacity() {
        let mut eps = network(2);
        let ep = &mut eps[0];
        // Seed the pool for peer 1 with a small and a big buffer.
        ep.pools[1].free.push(Vec::with_capacity(4));
        ep.pools[1].free.push(Vec::with_capacity(64));
        let buf = ep.acquire(1, 32);
        assert!(buf.capacity() >= 32, "picked the too-small buffer");
        assert_eq!(ep.counters.pool_hits, 1);
        // A request no pooled buffer can hold is a miss — the undersized
        // buffer stays in the pool rather than being handed out to regrow
        // (a hit must never hide a heap allocation).
        let big = ep.acquire(1, 1000);
        assert!(big.capacity() >= 1000);
        assert_eq!(ep.counters.pool_misses, 1);
        // The remaining (small) buffer still serves a small request.
        let buf2 = ep.acquire(1, 2);
        assert!(buf2.capacity() >= 2);
        assert_eq!(ep.counters.pool_hits, 2);
        // Now everything is checked out: next acquire is a miss.
        ep.acquire(1, 8);
        assert_eq!(ep.counters.pool_misses, 2);
    }
}
