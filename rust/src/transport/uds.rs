//! Unix-domain-socket transport backend: the circulant engine across OS
//! processes.
//!
//! [`UdsTransport`] implements [`Transport`] over a fully-connected mesh
//! of `SOCK_STREAM` Unix-domain sockets, so `p` ranks can be `p` separate
//! processes on one machine (`ccoll launch --backend uds --launch.rank R
//! --launch.world p`). Messages are length-prefixed [`Tag`]-framed:
//!
//! ```text
//! [from: u32 LE][op: u64 LE][round: u64 LE][len(elems): u64 LE][payload]
//! ```
//!
//! a fixed 28-byte header followed by `len * size_of::<E>()` payload bytes
//! in **native** endianness — a Unix socket never leaves the machine, so
//! sender and receiver always agree on byte order and element layout.
//!
//! # Capability profile (vs the thread backend)
//!
//! * **Rendezvous: unsupported** (`caps().supports_rendezvous == false`).
//!   There is no shared address space to publish [`RemoteSlices`]
//!   (super::RemoteSlices) into, so every send travels the framed copy
//!   tier; the executor's capability check makes rendezvous-safe rounds
//!   fall back automatically, and the whole quiesce family
//!   ([`Transport::finish_op`] & co.) trivially reports "nothing pending".
//! * **Pooled recv buffers: supported.** Each peer connection is serviced
//!   by one reader thread that receives into buffers recycled from
//!   [`Transport::release`] via a per-peer free-list channel, so the
//!   steady state performs no per-round payload allocation
//!   (`Counters::pool_hits` / `pool_misses` count reader-side reuse).
//! * **Copy accounting.** Every send credits `Counters::bytes_copied`
//!   with the framed payload bytes — the socket write is a physical copy —
//!   so cross-backend ablations compare real volume and no backend
//!   under-reports (the trait-level crediting contract).
//!
//! # Bootstrap (deadlock-free mesh)
//!
//! Every rank **binds** its listener socket first, then **connects** to
//! all lower ranks (retrying until their listeners appear), then
//! **accepts** from all higher ranks; each connector identifies itself
//! with an 8-byte `[rank: u32 LE][generation: u32 LE]` handshake, and a
//! generation mismatch is refused loudly — a revived process can never
//! splice itself into a mesh from a different recovery generation.
//! Because binds strictly precede connects and connects retry, any
//! interleaving of process start-up converges. Socket names are
//! **generation-namespaced**: generation 0 (a cold start) uses
//! `<dir>/rank-<r>.sock` — byte-identical to the pre-recovery layout —
//! while generation g > 0 uses `<dir>/gen-<g>/rank-<r>.sock`, so a
//! post-recovery re-bootstrap can never collide with stale gen-0 socket
//! files (see [`socket_path_gen`]). [`uds_network_typed`] wraps the
//! gen-0 bootstrap for same-process tests.
//!
//! # Liveness and recovery hooks
//!
//! * **Stale-generation drop.** After [`Transport::set_generation`]
//!   moves the endpoint to a new recovery generation, any frame whose
//!   [`Tag::op`] carries an older generation is counted
//!   ([`Transport::stale_frames_dropped`]) and dropped at the stash
//!   boundary — pre-failure traffic can never be delivered into a
//!   post-recovery operation.
//! * **Heartbeats** (`CCOLL_HEARTBEAT_MS`, default 0 = off). When on,
//!   the owner thread piggy-backs an empty probe frame (`op ==
//!   u64::MAX`) to every live peer at most once per interval on its
//!   normal send/receive path, and tracks the last probe *seen* from
//!   each peer; a peer silent for `4×` the interval reads as down in
//!   [`Transport::peer_status`] even though its socket never EOF'd —
//!   distinguishing a *hung* peer from a merely idle one.
//! * **Reconnect-with-backoff** (`CCOLL_RECONNECT_ATTEMPTS`, default 0
//!   = off). When on, a send that finds the peer's connection dead
//!   attempts a bounded reconnect to the peer's generation-namespaced
//!   listener path before surfacing [`TransportError::PeerDown`] — the
//!   transient-disconnect path for a peer that re-bound its listener
//!   within the deadline (no generation bump). A peer that is truly
//!   gone has no listener, so every attempt fails fast and the send
//!   degrades to today's PeerDown behavior.
//!
//! Reader threads are I/O plumbing, not rank workers: they do **not**
//! count toward [`super::rank_threads_spawned`], so the engine's
//! spawn-once assertions hold per process on this backend too.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::datatypes::Elem;

use super::{
    Counters, Payload, SendSlices, Tag, Transport, TransportBackend, TransportCaps,
    TransportError,
};

/// Framed-message header size: from(u32) + op(u64) + round(u64) + len(u64).
const HEADER_BYTES: usize = 28;

/// How long the bootstrap retries a connect to a peer whose listener has
/// not appeared yet, and how long it waits in accept for higher ranks.
const DEFAULT_BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(30);

/// What a reader thread feeds the owner's inbox: a decoded frame, or the
/// positive observation that the peer's connection died.
enum Inbound<E: Elem> {
    /// One decoded inbound message.
    Msg {
        from: usize,
        tag: Tag,
        buf: Vec<E>,
        /// The reader received into a recycled buffer (owner credits a
        /// pool hit) rather than a fresh allocation (a miss).
        reused: bool,
    },
    /// The peer's connection EOF'd or errored: the peer process is gone.
    /// The owner flips the peer's health bit and fails waiters with
    /// [`TransportError::PeerDown`] instead of burning its timeout.
    PeerGone { peer: usize, detail: String },
}

/// View a primitive-element slice as raw bytes for a socket write.
///
/// SAFETY: `E: Elem` is one of the five built-in primitives (f32/f64/
/// i32/i64/u64) — plain-old-data with no padding, no invalid bit
/// patterns and no drop glue — and the peer decodes at the same width on
/// the same machine (native endianness).
fn as_bytes<E: Elem>(s: &[E]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

/// Operation epoch reserved for heartbeat probe frames. Never allocated
/// by the engine (generations are 16 bits, sequences 48 — the composed
/// epoch cannot be all-ones), so a probe can never collide with a
/// collective's traffic.
pub const HEARTBEAT_OP: u64 = u64::MAX;

/// Socket path of `rank`'s listener inside the rendezvous directory
/// (generation 0 — the cold-start layout).
pub fn socket_path(dir: &Path, rank: usize) -> PathBuf {
    socket_path_gen(dir, rank, 0)
}

/// Generation-namespaced socket path: generation 0 keeps the flat
/// `rank-<r>.sock` layout (cold starts are byte-identical to the
/// pre-recovery scheme); generation g > 0 lives under a `gen-<g>/`
/// subdirectory so a recovery re-bootstrap can never collide with stale
/// gen-0 socket files left by the failed mesh.
pub fn socket_path_gen(dir: &Path, rank: usize, gen: u64) -> PathBuf {
    if gen == 0 {
        dir.join(format!("rank-{rank}.sock"))
    } else {
        dir.join(format!("gen-{gen}")).join(format!("rank-{rank}.sock"))
    }
}

fn io_disconnected(rank: usize, to: usize) -> TransportError {
    TransportError::Disconnected { rank, to }
}

/// Reader loop for one peer connection: decode frames, receive into
/// recycled buffers when one fits, forward to the owner's inbox. Exits
/// when the peer closes its write half or the owner drops its inbox —
/// and in the former case reports the death as a first-class
/// [`Inbound::PeerGone`] event first, so the owner can fail fast
/// instead of hanging until its liveness timeout.
fn reader_loop<E: Elem>(
    owner: usize,
    peer: usize,
    mut stream: UnixStream,
    inbox: Sender<Inbound<E>>,
    free_rx: Receiver<Vec<E>>,
) {
    let esz = std::mem::size_of::<E>();
    let mut free: Vec<Vec<E>> = Vec::new();
    let mut hdr = [0u8; HEADER_BYTES];
    loop {
        if let Err(e) = stream.read_exact(&mut hdr) {
            // Peer closed (normal teardown) or died. Either way the link
            // is dead: tell the owner, which decides whether anything
            // still needed this peer. Best-effort — the owner may
            // already be gone itself.
            let detail = if e.kind() == std::io::ErrorKind::UnexpectedEof {
                "connection closed (EOF)".to_string()
            } else {
                format!("read error: {e}")
            };
            let _ = inbox.send(Inbound::PeerGone { peer, detail });
            return;
        }
        let from = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let op = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
        let round = u64::from_le_bytes(hdr[12..20].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[20..28].try_into().unwrap()) as usize;
        debug_assert_eq!(from, peer, "rank {owner}: frame claims from={from} on link to {peer}");
        // Recycle: drain the free-list, then take the first buffer that
        // can hold the payload without regrowing (a hit must never hide a
        // heap allocation — same honesty rule as the thread pool).
        while let Ok(b) = free_rx.try_recv() {
            free.push(b);
        }
        let (mut buf, reused) = match free.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = free.swap_remove(i);
                b.clear();
                (b, true)
            }
            None => (Vec::with_capacity(len), false),
        };
        if len > 0 {
            // SAFETY: `buf` has at least `len` elements of capacity; E is
            // POD (see `as_bytes`), so filling its storage from the wire
            // and then claiming `len` initialized elements is sound.
            let ok = unsafe {
                let dst = std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len * esz);
                let ok = stream.read_exact(dst).is_ok();
                if ok {
                    buf.set_len(len);
                }
                ok
            };
            if !ok {
                // Truncated frame: peer died mid-message.
                let _ = inbox.send(Inbound::PeerGone {
                    peer,
                    detail: format!(
                        "connection died mid-frame (op {op} round {round}, \
                         expected {len} elems)"
                    ),
                });
                return;
            }
        }
        let msg = Inbound::Msg { from: peer, tag: Tag::new(op, round), buf, reused };
        if inbox.send(msg).is_err() {
            return; // owner dropped its transport
        }
    }
}

/// One rank's Unix-domain-socket communication handle. See the module
/// docs for the wire format, capability profile and bootstrap protocol.
pub struct UdsTransport<E: Elem> {
    rank: usize,
    p: usize,
    /// Write halves, one per peer (`None` at `rank` itself). Reads happen
    /// on per-peer reader threads holding clones of the same sockets.
    writers: Vec<Option<UnixStream>>,
    /// All reader threads feed this single inbox.
    rx: Receiver<Inbound<E>>,
    /// Free-list senders, one per peer reader: `release(from, buf)` ships
    /// consumed buffers back so the `from`-link reader receives into them.
    free_txs: Vec<Option<Sender<Vec<E>>>>,
    /// Early arrivals keyed by `(from, tag)`, exactly like the thread
    /// backend's stash.
    stash: HashMap<(usize, Tag), Payload<E>>,
    readers: Vec<std::thread::JoinHandle<()>>,
    counters: Counters,
    timeout: Duration,
    /// Health bitmap: `peer_down[r]` holds the failure detail once peer
    /// `r`'s connection was positively observed dead (reader EOF/IO
    /// error, or a failed write on our side). Updated whenever the inbox
    /// is drained; read through [`Transport::peer_status`].
    peer_down: Vec<Option<String>>,
    /// Transient-write retry policy: attempts and base backoff (doubling
    /// per attempt). From `CCOLL_RETRY_*` by default; the engine applies
    /// its `engine.retry.*` config through [`Transport::set_retry`].
    retry_attempts: usize,
    retry_base_ms: u64,
    /// Rendezvous directory this mesh bootstrapped in — the reconnect
    /// path re-derives peers' generation-namespaced listener paths from
    /// it.
    dir: PathBuf,
    /// Recovery generation this endpoint accepts frames for; arrivals
    /// tagged with an older generation are counted and dropped.
    generation: u64,
    /// Frames dropped for carrying a stale generation.
    stale_frames: u64,
    /// Kept alive so reconnect-spawned readers can feed the same inbox.
    inbox_tx: Sender<Inbound<E>>,
    /// Heartbeat interval (`CCOLL_HEARTBEAT_MS`; 0 = probes off).
    heartbeat_ms: u64,
    /// When this endpoint last broadcast a probe.
    last_hb_sent: Instant,
    /// Last probe *seen* from each peer (`None` until its first one) —
    /// the silent-hang detector consulted by `peer_status`.
    last_seen: Vec<Option<Instant>>,
    /// Bounded reconnect policy for dead connections
    /// (`CCOLL_RECONNECT_ATTEMPTS` / `CCOLL_RECONNECT_BASE_MS`; 0
    /// attempts = today's fail-fast PeerDown behavior).
    reconnect_attempts: usize,
    reconnect_base_ms: u64,
}

impl<E: Elem> UdsTransport<E> {
    /// Join the `p`-rank mesh rendezvoused in `dir` as `rank`, blocking
    /// until every pairwise connection is up (bounded by the bootstrap
    /// timeout). Each process calls this exactly once for its own rank.
    pub fn connect(rank: usize, p: usize, dir: &Path) -> std::io::Result<Self> {
        Self::connect_with_timeout(rank, p, dir, DEFAULT_BOOTSTRAP_TIMEOUT)
    }

    /// [`connect`](UdsTransport::connect) with an explicit bootstrap
    /// timeout (tests shrink it for failure injection).
    pub fn connect_with_timeout(
        rank: usize,
        p: usize,
        dir: &Path,
        bootstrap: Duration,
    ) -> std::io::Result<Self> {
        Self::connect_gen(rank, p, dir, 0, bootstrap)
    }

    /// Join (or re-form) the mesh of recovery generation `gen` in `dir`:
    /// socket names are generation-namespaced and the handshake carries
    /// the generation, so a survivor set re-bootstrapping after a rank
    /// death can never cross-wire with the failed generation's sockets
    /// or with a stale process still speaking an older generation.
    pub fn connect_gen(
        rank: usize,
        p: usize,
        dir: &Path,
        gen: u64,
        bootstrap: Duration,
    ) -> std::io::Result<Self> {
        assert!(p >= 1 && rank < p, "rank {rank} out of range for world {p}");
        assert!(gen < (1 << 16), "generation {gen} overflows the 16-bit tag field");
        let deadline = Instant::now() + bootstrap;
        // 1. Bind our own listener FIRST — lower ranks' connects retry
        //    until it exists, so bind-before-connect makes the mesh
        //    convergent under any process start order.
        let own = socket_path_gen(dir, rank, gen);
        if let Some(parent) = own.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let _ = std::fs::remove_file(&own); // stale socket from a dead run
        let listener = UnixListener::bind(&own)?;
        listener.set_nonblocking(true)?;

        let mut streams: Vec<Option<UnixStream>> = (0..p).map(|_| None).collect();
        // 2. Connect to every lower rank, retrying until its listener
        //    appears; identify ourselves with an 8-byte rank+generation
        //    handshake.
        for peer in 0..rank {
            let path = socket_path_gen(dir, peer, gen);
            let stream = loop {
                match UnixStream::connect(&path) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() >= deadline {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                format!(
                                    "rank {rank}: bootstrap deadline ({:.1}s) expired — \
                                     missing rank {peer}, which never bound {} ({e})",
                                    bootstrap.as_secs_f64(),
                                    path.display()
                                ),
                            ));
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            };
            let mut s = stream;
            let mut hs = [0u8; 8];
            hs[0..4].copy_from_slice(&(rank as u32).to_le_bytes());
            hs[4..8].copy_from_slice(&(gen as u32).to_le_bytes());
            s.write_all(&hs)?;
            streams[peer] = Some(s);
        }
        // 3. Accept one connection from every higher rank; the handshake
        //    says which — and which generation it believes it is joining.
        let mut accepted = 0usize;
        while accepted < p - 1 - rank {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    let mut hs = [0u8; 8];
                    s.read_exact(&mut hs)?;
                    let peer = u32::from_le_bytes(hs[0..4].try_into().unwrap()) as usize;
                    let peer_gen = u32::from_le_bytes(hs[4..8].try_into().unwrap()) as u64;
                    if peer_gen != gen {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!(
                                "rank {rank}: handshake from rank {peer} carries generation \
                                 {peer_gen}, this mesh is generation {gen} — a stale process \
                                 is trying to join a reconfigured mesh"
                            ),
                        ));
                    }
                    if peer <= rank || peer >= p || streams[peer].is_some() {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!("rank {rank}: bogus handshake from \"rank {peer}\""),
                        ));
                    }
                    streams[peer] = Some(s);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        let missing: Vec<String> = (rank + 1..p)
                            .filter(|&r| streams[r].is_none())
                            .map(|r| r.to_string())
                            .collect();
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!(
                                "rank {rank}: bootstrap deadline ({:.1}s) expired with only \
                                 {accepted}/{} higher ranks connected — missing rank(s) {} \
                                 (did those processes start?)",
                                bootstrap.as_secs_f64(),
                                p - 1 - rank,
                                missing.join(", "),
                            ),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e),
            }
        }
        drop(listener);
        let _ = std::fs::remove_file(&own); // mesh is up; the name is done

        // 4. Split each connection: a clone for our writes, the original
        //    to a reader thread (plain I/O plumbing — deliberately NOT
        //    counted by note_rank_thread_spawn, so spawn-once assertions
        //    see only true rank workers).
        let (inbox_tx, inbox_rx) = channel::<Inbound<E>>();
        let mut writers: Vec<Option<UnixStream>> = (0..p).map(|_| None).collect();
        let mut free_txs: Vec<Option<Sender<Vec<E>>>> = (0..p).map(|_| None).collect();
        let mut readers = Vec::with_capacity(p.saturating_sub(1));
        for (peer, slot) in streams.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            writers[peer] = Some(stream.try_clone()?);
            let (ftx, frx) = channel::<Vec<E>>();
            free_txs[peer] = Some(ftx);
            let tx = inbox_tx.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("uds-reader-{rank}-{peer}"))
                    .spawn(move || reader_loop::<E>(rank, peer, stream, tx, frx))
                    .expect("spawn uds reader thread"),
            );
        }
        let knobs = crate::env_knobs::knobs();
        Ok(Self {
            rank,
            p,
            writers,
            rx: inbox_rx,
            free_txs,
            stash: HashMap::new(),
            readers,
            counters: Counters::default(),
            timeout: Duration::from_secs(30),
            peer_down: (0..p).map(|_| None).collect(),
            retry_attempts: knobs.retry_attempts,
            retry_base_ms: knobs.retry_base_ms,
            dir: dir.to_path_buf(),
            generation: gen,
            stale_frames: 0,
            inbox_tx,
            heartbeat_ms: knobs.heartbeat_ms,
            last_hb_sent: Instant::now(),
            last_seen: (0..p).map(|_| None).collect(),
            reconnect_attempts: knobs.reconnect_attempts,
            reconnect_base_ms: knobs.reconnect_base_ms,
        })
    }

    /// Preflight a rendezvous directory before a fresh `ccoll launch`
    /// run: a leftover `rank-<r>.sock` from a **crashed** previous run is
    /// removed (nothing is listening on it), but a socket with a *live*
    /// listener means another process is already serving that rank in
    /// this directory — refuse loudly rather than corrupt its mesh.
    pub fn preflight_socket(dir: &Path, rank: usize) -> std::io::Result<()> {
        Self::preflight_socket_gen(dir, rank, 0)
    }

    /// Generation-aware preflight: checks the socket path of the
    /// generation actually being joined, so a revived rank
    /// re-bootstrapping into generation g is never refused because of a
    /// *different* generation's leftover listener (the old preflight
    /// assumed a cold start and only ever looked at the gen-0 path —
    /// which a recovered mesh legitimately leaves behind).
    pub fn preflight_socket_gen(dir: &Path, rank: usize, gen: u64) -> std::io::Result<()> {
        let path = socket_path_gen(dir, rank, gen);
        if !path.exists() {
            return Ok(());
        }
        match UnixStream::connect(&path) {
            Ok(_) => Err(std::io::Error::new(
                std::io::ErrorKind::AddrInUse,
                format!(
                    "rank {rank}: {} already has a live listener — another process is \
                     serving this rank at generation {gen} in this directory (pick a \
                     fresh --dir, or stop it)",
                    path.display()
                ),
            )),
            Err(_) => {
                // Stale: bound by a process that died without unlinking.
                std::fs::remove_file(&path)?;
                eprintln!(
                    "ccoll: removed stale socket {} left by a crashed previous run",
                    path.display()
                );
                Ok(())
            }
        }
    }

    /// Frame and write one tagged payload (up to two slices) to `to`.
    /// The socket write is the backend's physical copy: credited to
    /// `bytes_copied` so framed sends can never under-report volume.
    ///
    /// Never panics: a write to a dead or never-connected peer returns
    /// [`TransportError::PeerDown`] (and records the death in the health
    /// bitmap), so one killed rank degrades to typed errors instead of
    /// taking its peers down with it.
    fn send_frame(
        &mut self,
        to: usize,
        tag: Tag,
        head: &[E],
        tail: &[E],
    ) -> Result<(), TransportError> {
        debug_assert!(to < self.p && to != self.rank, "bad send target {to}");
        let rank = self.rank;
        if let Some(detail) = self.peer_down[to].clone() {
            // Transient-disconnect path: a bounded reconnect may clear
            // the down mark before we refuse (no-op unless the knob is
            // set and the peer re-bound its listener).
            if !self.try_reconnect(to) {
                return Err(TransportError::PeerDown { rank, peer: to, detail });
            }
        }
        let len = head.len() + tail.len();
        let mut hdr = [0u8; HEADER_BYTES];
        hdr[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
        hdr[4..12].copy_from_slice(&tag.op.to_le_bytes());
        hdr[12..20].copy_from_slice(&tag.round.to_le_bytes());
        hdr[20..28].copy_from_slice(&(len as u64).to_le_bytes());
        let (attempts, base_ms) = (self.retry_attempts, self.retry_base_ms);
        let mut outcome = match self.writers[to].as_mut() {
            None => Err("no connection to this peer (bootstrap never linked it)".to_string()),
            Some(w) => write_frame(w, &hdr, as_bytes(head), as_bytes(tail), attempts, base_ms),
        };
        if outcome.is_err() {
            // The write found a dead connection mid-frame. A reconnect
            // gets a *fresh* stream, so resending the whole frame cannot
            // duplicate bytes the peer already consumed on the old one
            // (the old connection is gone with whatever it had).
            self.peer_down[to] = outcome.clone().err();
            if self.try_reconnect(to) {
                outcome = match self.writers[to].as_mut() {
                    None => outcome,
                    Some(w) => {
                        write_frame(w, &hdr, as_bytes(head), as_bytes(tail), attempts, base_ms)
                    }
                };
            }
        }
        if let Err(detail) = outcome {
            self.peer_down[to] = Some(detail.clone());
            return Err(TransportError::PeerDown { rank, peer: to, detail });
        }
        self.counters.msgs_sent += 1;
        self.counters.elems_sent += len as u64;
        self.counters.bytes_copied += (std::mem::size_of::<E>() * len) as u64;
        Ok(())
    }

    /// Override the reconnect policy (tests; production reads
    /// `CCOLL_RECONNECT_*`). 0 attempts = fail-fast, today's behavior.
    pub fn set_reconnect(&mut self, attempts: usize, base_ms: u64) {
        self.reconnect_attempts = attempts;
        self.reconnect_base_ms = base_ms;
    }

    /// Override the heartbeat interval (tests; production reads
    /// `CCOLL_HEARTBEAT_MS`). 0 = probes off.
    pub fn set_heartbeat_ms(&mut self, ms: u64) {
        self.heartbeat_ms = ms;
    }

    /// Bounded reconnect-with-backoff to `peer`'s generation-namespaced
    /// listener path: the *transiently disconnected* arm of the failure
    /// model. Succeeds only if the peer re-bound its listener (a process
    /// that is actually dead has none, so every attempt fails fast and
    /// the caller degrades to the PeerDown path). On success the dead
    /// writer is replaced, a fresh reader thread feeds the same inbox,
    /// and the peer's health bit is cleared — with **no** generation
    /// bump: the mesh was never reconfigured. Off by default
    /// (`CCOLL_RECONNECT_ATTEMPTS=0` preserves fail-fast semantics).
    fn try_reconnect(&mut self, peer: usize) -> bool {
        if self.reconnect_attempts == 0 || peer == self.rank {
            return false;
        }
        let path = socket_path_gen(&self.dir, peer, self.generation);
        for attempt in 1..=self.reconnect_attempts {
            match UnixStream::connect(&path) {
                Ok(mut s) => {
                    let mut hs = [0u8; 8];
                    hs[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
                    hs[4..8].copy_from_slice(&(self.generation as u32).to_le_bytes());
                    if s.write_all(&hs).is_err() {
                        continue;
                    }
                    let reader = match s.try_clone() {
                        Ok(r) => r,
                        Err(_) => continue,
                    };
                    let (ftx, frx) = channel::<Vec<E>>();
                    let tx = self.inbox_tx.clone();
                    let (rank, p) = (self.rank, peer);
                    self.readers.push(
                        std::thread::Builder::new()
                            .name(format!("uds-reader-{rank}-{p}-r"))
                            .spawn(move || reader_loop::<E>(rank, p, reader, tx, frx))
                            .expect("spawn uds reconnect reader thread"),
                    );
                    self.writers[peer] = Some(s);
                    self.free_txs[peer] = Some(ftx);
                    self.peer_down[peer] = None;
                    self.last_seen[peer] = Some(Instant::now());
                    return true;
                }
                Err(_) => {
                    if attempt < self.reconnect_attempts {
                        std::thread::sleep(Duration::from_millis(
                            self.reconnect_base_ms << (attempt - 1).min(6),
                        ));
                    }
                }
            }
        }
        false
    }

    /// Piggy-backed liveness probe: at most once per heartbeat interval,
    /// broadcast an empty `HEARTBEAT_OP` frame to every currently-live
    /// peer. Runs on the owner thread's normal send/receive path — no
    /// extra sender thread, so probe bytes can never interleave inside a
    /// data frame. No-op while the knob is off.
    fn maybe_heartbeat(&mut self) {
        if self.heartbeat_ms == 0 {
            return;
        }
        if self.last_hb_sent.elapsed() < Duration::from_millis(self.heartbeat_ms) {
            return;
        }
        self.last_hb_sent = Instant::now();
        let mut hdr = [0u8; HEADER_BYTES];
        hdr[0..4].copy_from_slice(&(self.rank as u32).to_le_bytes());
        hdr[4..12].copy_from_slice(&HEARTBEAT_OP.to_le_bytes());
        hdr[12..20].copy_from_slice(&0u64.to_le_bytes());
        hdr[20..28].copy_from_slice(&0u64.to_le_bytes());
        for peer in 0..self.p {
            if peer == self.rank || self.peer_down[peer].is_some() {
                continue;
            }
            if let Some(w) = self.writers[peer].as_mut() {
                // Best-effort: a failed probe write is the link dying,
                // which the next data send or the reader will surface.
                let _ = write_frame(w, &hdr, &[], &[], 0, 0);
            }
        }
    }

    /// Whether the silent-hang detector considers `peer` down: probes
    /// are on, we have heard at least one probe from it, and then
    /// nothing for 4× the interval. Requiring one observed probe first
    /// keeps a peer with probes *off* from reading as dead.
    fn heartbeat_lapsed(&self, peer: usize) -> bool {
        if self.heartbeat_ms == 0 || peer == self.rank {
            return false;
        }
        match self.last_seen[peer] {
            Some(seen) => seen.elapsed() > Duration::from_millis(self.heartbeat_ms * 4),
            None => false,
        }
    }

    /// Stash an arrival unless it carries a **stale generation** — the
    /// UDS twin of the thread backend's filter: after
    /// [`Transport::set_generation`], a frame tagged with an older
    /// generation is counted and dropped (its buffer recycled to the
    /// reader's free-list), never delivered. Epoch-0 frames and frames
    /// from a newer generation pass through.
    fn stash_arrival(&mut self, key: (usize, Tag), payload: Payload<E>) {
        if key.1.op != 0 && key.1.op != HEARTBEAT_OP && super::generation_of(key.1.op) < self.generation
        {
            self.stale_frames += 1;
            Transport::complete_tagged(self, key.0, key.1, payload);
            return;
        }
        self.stash.insert(key, payload);
    }

    /// Account one consumed inbound event. A decoded frame becomes a
    /// stash-keyed payload; a [`Inbound::PeerGone`] notice flips the
    /// peer's health bit and yields nothing.
    fn accept_inbound(&mut self, msg: Inbound<E>) -> Option<((usize, Tag), Payload<E>)> {
        match msg {
            Inbound::Msg { from, tag, buf, reused } => {
                if tag.op == HEARTBEAT_OP {
                    // Liveness probe: stamp the sender alive, never
                    // deliver. (Probe frames are empty; the buffer is
                    // dropped, not worth recycling.)
                    self.last_seen[from] = Some(Instant::now());
                    return None;
                }
                if reused {
                    self.counters.pool_hits += 1;
                } else {
                    self.counters.pool_misses += 1;
                }
                Some(((from, tag), Payload::Copied(buf)))
            }
            Inbound::PeerGone { peer, detail } => {
                // First observation wins (it names the root cause; a
                // later write failure would just echo the broken pipe).
                if self.peer_down[peer].is_none() {
                    self.peer_down[peer] = Some(detail);
                }
                None
            }
        }
    }

    /// Receive the payload tagged `(from, tag)`, stashing out-of-order
    /// arrivals — the socket-backed twin of the thread backend's
    /// `recv_tagged`, plus positive failure detection: a peer observed
    /// dead fails the receive with [`TransportError::PeerDown`]
    /// *immediately*, not after burning the liveness timeout. (Frames
    /// that arrived before the death are still consumable: per-sender
    /// channel order guarantees every frame precedes its link's
    /// `PeerGone` notice, and the stash is checked first.)
    fn recv_tagged(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        if let Some(payload) = self.stash.remove(&(from, tag)) {
            return Ok(payload);
        }
        if let Some(detail) = self.peer_down[from].clone() {
            return Err(TransportError::PeerDown { rank: self.rank, peer: from, detail });
        }
        loop {
            match self.rx.recv_timeout(self.timeout) {
                Ok(msg) => {
                    let Some((key, payload)) = self.accept_inbound(msg) else {
                        // A death notice. Fail fast if it was the peer we
                        // are waiting on; other deaths are recorded for
                        // their own waiters.
                        if let Some(detail) = self.peer_down[from].clone() {
                            return Err(TransportError::PeerDown {
                                rank: self.rank,
                                peer: from,
                                detail,
                            });
                        }
                        continue;
                    };
                    if key == (from, tag) {
                        return Ok(payload);
                    }
                    self.stash_arrival(key, payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout {
                        rank: self.rank,
                        from,
                        round: tag.round,
                    })
                }
                // All reader threads exited: every peer hung up.
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(io_disconnected(self.rank, from))
                }
            }
        }
    }

    /// Drain everything already decoded into the stash (non-blocking);
    /// death notices update the health bitmap as a side effect.
    fn drain_inbox(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            if let Some((key, payload)) = self.accept_inbound(msg) {
                self.stash_arrival(key, payload);
            }
        }
    }
}

/// Write one frame (header + ≤ 2 payload segments) to a stream, retrying
/// transient errors (`WouldBlock`) with doubling backoff **from the byte
/// offset reached** — never from the frame start, so a retry can never
/// duplicate wire bytes. `Interrupted` writes wrote nothing and are
/// retried unconditionally. Returns a human-readable failure detail.
fn write_frame(
    w: &mut UnixStream,
    hdr: &[u8],
    head: &[u8],
    tail: &[u8],
    attempts: usize,
    base_ms: u64,
) -> Result<(), String> {
    let mut attempt = 0usize;
    for seg in [hdr, head, tail] {
        let mut off = 0usize;
        while off < seg.len() {
            match w.write(&seg[off..]) {
                Ok(0) => return Err("write returned 0 bytes (socket closed)".to_string()),
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock && attempt < attempts => {
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(base_ms << (attempt - 1).min(6)));
                }
                Err(e) => return Err(format!("write failed: {e}")),
            }
        }
    }
    Ok(())
}

impl<E: Elem> Transport<E> for UdsTransport<E> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn p(&self) -> usize {
        self.p
    }

    fn caps(&self) -> TransportCaps {
        TransportBackend::Uds.caps()
    }

    fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError> {
        self.counters.sendrecv_rounds += 1;
        self.maybe_heartbeat();
        if let Some(s) = send {
            // Rendezvous is unsupported on this backend: whatever the
            // caller's safety verdict, the payload travels the framed
            // copy tier (the executor's caps check normally prevents the
            // verdict from even being set).
            self.send_frame(s.to, tag, s.head, s.tail)?;
        }
        match recv_from {
            None => Ok(None),
            Some(from) => Transport::recv_payload(self, from, tag).map(Some),
        }
    }

    fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        let payload = self.recv_tagged(from, tag)?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Ok(payload)
    }

    fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>> {
        self.maybe_heartbeat();
        self.drain_inbox();
        let payload = self.stash.remove(&(from, tag))?;
        self.counters.msgs_recv += 1;
        self.counters.elems_recv += payload.len() as u64;
        Some(payload)
    }

    fn complete_tagged(&mut self, from: usize, _tag: Tag, payload: Payload<E>) {
        match payload {
            Payload::Copied(v) => Transport::release(self, from, v),
            // Unreachable: this backend never constructs Remote payloads.
            Payload::Remote(_) => unreachable!(
                "rank {}: rendezvous payload on the UDS backend (caps forbid publishes)",
                self.rank
            ),
        }
    }

    fn acquire(&mut self, _to: usize, need: usize) -> Vec<E> {
        // Sends write working-vector slices straight to the socket, so
        // there is no sender-side staging pool to recycle from; the
        // backend's pooling lives on the receive side (reader free-lists).
        Vec::with_capacity(need)
    }

    fn release(&mut self, from: usize, payload: Vec<E>) {
        if payload.capacity() == 0 || from == self.rank {
            return;
        }
        if let Some(ftx) = &self.free_txs[from] {
            if ftx.send(payload).is_ok() {
                self.counters.bufs_recycled += 1;
            }
        }
    }

    // No publish can ever be outstanding: the quiesce family is trivial.
    fn finish_round(&mut self) -> Result<(), TransportError> {
        Ok(())
    }

    fn finish_op(&mut self, _op: u64) -> Result<(), TransportError> {
        Ok(())
    }

    fn try_finish(&mut self, _tag: Tag) -> bool {
        true
    }

    fn op_has_pending_publish(&mut self, _op: u64) -> bool {
        false
    }

    fn forget_op(&mut self, op: u64) -> usize {
        self.drain_inbox();
        let keys: Vec<(usize, Tag)> =
            self.stash.keys().filter(|(_, t)| t.op == op).copied().collect();
        let discarded = keys.len();
        for (from, tag) in keys {
            if let Some(payload) = self.stash.remove(&(from, tag)) {
                self.complete_tagged(from, tag, payload);
            }
        }
        discarded
    }

    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    fn peer_status(&self) -> Vec<bool> {
        (0..self.p)
            .map(|r| self.peer_down[r].is_none() && !self.heartbeat_lapsed(r))
            .collect()
    }

    fn peer_down(&self, peer: usize) -> Option<String> {
        if let Some(d) = self.peer_down[peer].clone() {
            return Some(d);
        }
        if self.heartbeat_lapsed(peer) {
            return Some(format!(
                "no heartbeat from rank {peer} for over {} ms (interval {} ms) — peer hung",
                self.heartbeat_ms * 4,
                self.heartbeat_ms
            ));
        }
        None
    }

    fn timeout(&self) -> Duration {
        self.timeout
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_rendezvous(&mut self, _on: bool) {
        // Capability-gated off: nothing to opt into.
    }

    fn set_rendezvous_min_elems(&mut self, _min: usize) {}

    fn set_retry(&mut self, attempts: usize, base_ms: u64) {
        self.retry_attempts = attempts;
        self.retry_base_ms = base_ms;
    }

    fn generation(&self) -> u64 {
        self.generation
    }

    fn set_generation(&mut self, gen: u64) {
        self.generation = gen;
    }

    fn stale_frames_dropped(&self) -> u64 {
        self.stale_frames
    }
}

impl<E: Elem> Drop for UdsTransport<E> {
    fn drop(&mut self) {
        // Closing our socket halves EOFs every peer's reader for this
        // link; buffered data already written is still delivered first
        // (AF_UNIX stream semantics), so a peer mid-collective finishes
        // reading what we sent. Dropping the free-list senders unblocks
        // nothing (readers only try_recv them) but lets readers observe
        // the hang-up through their own read side.
        for w in self.writers.iter_mut().flatten() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        self.free_txs.clear();
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build a `p`-rank UDS mesh **inside one process** (one bootstrap thread
/// per rank, joined before returning) — the cross-backend test harness.
/// Production multi-process use calls [`UdsTransport::connect`] once per
/// process instead (`ccoll launch`).
pub fn uds_network_typed<E: Elem>(p: usize, dir: &Path) -> std::io::Result<Vec<UdsTransport<E>>> {
    let handles: Vec<_> = (0..p)
        .map(|rank| {
            let dir = dir.to_path_buf();
            std::thread::Builder::new()
                .name(format!("uds-bootstrap-{rank}"))
                .spawn(move || UdsTransport::<E>::connect(rank, p, &dir))
                .expect("spawn uds bootstrap thread")
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("uds bootstrap thread panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fresh rendezvous dir under the target tmpdir, unique per test.
    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ccoll-uds-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("create scratch dir");
        dir
    }

    fn run_mesh<E: Elem, T, F>(p: usize, dir: &Path, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, &mut UdsTransport<E>) -> T + Send + Sync + 'static,
    {
        let transports = uds_network_typed::<E>(p, dir).expect("mesh bootstrap");
        let f = std::sync::Arc::new(f);
        let handles: Vec<_> = transports
            .into_iter()
            .enumerate()
            .map(|(rank, mut t)| {
                let f = f.clone();
                std::thread::spawn(move || f(rank, &mut t))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("mesh rank panicked")).collect()
    }

    #[test]
    fn ring_sendrecv_roundtrip_over_sockets() {
        let dir = scratch_dir("ring");
        let out = run_mesh::<i64, _, _>(4, &dir, |rank, t| {
            let to = (rank + 1) % 4;
            let from = (rank + 3) % 4;
            let data = [rank as i64, 100 + rank as i64];
            let send = SendSlices { to, head: &data[..1], tail: &data[1..], rendezvous: false };
            let payload = t
                .sendrecv_slices_tagged(Some(send), Some(from), Tag::untagged(0))
                .unwrap()
                .unwrap();
            let got = match &payload {
                Payload::Copied(v) => v.clone(),
                Payload::Remote(_) => unreachable!(),
            };
            t.complete_tagged(from, Tag::untagged(0), payload);
            (got, t.counters().clone())
        });
        for (rank, (got, c)) in out.iter().enumerate() {
            let from = (rank + 3) % 4;
            assert_eq!(got, &vec![from as i64, 100 + from as i64]);
            assert_eq!(c.msgs_sent, 1);
            assert_eq!(c.msgs_recv, 1);
            assert_eq!(c.elems_sent, 2);
            assert_eq!(c.elems_recv, 2);
            assert_eq!(c.bytes_copied, 2 * 8, "framed i64 send copies 8 B/elem");
            assert_eq!(c.rendezvous_hits, 0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rendezvous_verdict_is_ignored_and_payload_travels_framed() {
        // Even a caller that (wrongly) claims rendezvous safety must get a
        // Copied payload: the backend cannot publish.
        let dir = scratch_dir("no-rdv");
        let out = run_mesh::<f32, _, _>(2, &dir, |rank, t| {
            assert!(!t.caps().supports_rendezvous);
            t.set_rendezvous(true); // must be a no-op
            let peer = 1 - rank;
            let data = [rank as f32; 300]; // above any min-elems threshold
            let send = SendSlices { to: peer, head: &data, tail: &[], rendezvous: true };
            let payload = t
                .sendrecv_slices_tagged(Some(send), Some(peer), Tag::untagged(0))
                .unwrap()
                .unwrap();
            let copied = matches!(payload, Payload::Copied(_));
            t.complete_tagged(peer, Tag::untagged(0), payload);
            t.finish_round().unwrap(); // trivial: nothing ever pends
            (copied, t.counters().rendezvous_hits)
        });
        for (copied, hits) in out {
            assert!(copied, "UDS payloads must always be framed copies");
            assert_eq!(hits, 0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let dir = scratch_dir("stash");
        let out = run_mesh::<i64, _, _>(2, &dir, |rank, t| {
            if rank == 1 {
                for (op, val) in [(7u64, 70i64), (9, 90)] {
                    let data = [val];
                    let send =
                        SendSlices { to: 0, head: &data, tail: &[], rendezvous: false };
                    t.sendrecv_slices_tagged(Some(send), None, Tag::new(op, 0)).unwrap();
                }
                vec![]
            } else {
                // Consume epoch 9 before epoch 7: the stash must reorder.
                let b = Transport::recv_payload(t, 1, Tag::new(9, 0)).unwrap();
                let a = Transport::recv_payload(t, 1, Tag::new(7, 0)).unwrap();
                let read = |p: &Payload<i64>| match p {
                    Payload::Copied(v) => v[0],
                    Payload::Remote(_) => unreachable!(),
                };
                let out = vec![read(&a), read(&b)];
                t.complete_tagged(1, Tag::new(7, 0), a);
                t.complete_tagged(1, Tag::new(9, 0), b);
                out
            }
        });
        assert_eq!(out[0], vec![70, 90]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn released_buffers_are_reused_by_the_reader() {
        // Lock-step ping-pong with releases: after warm-up the reader must
        // serve from recycled buffers (pool hits), not fresh allocations.
        let dir = scratch_dir("recycle");
        let rounds = 16u64;
        let out = run_mesh::<f64, _, _>(2, &dir, move |rank, t| {
            let peer = 1 - rank;
            let data = [rank as f64; 32];
            for round in 0..rounds {
                let send =
                    SendSlices { to: peer, head: &data, tail: &[], rendezvous: false };
                let payload = t
                    .sendrecv_slices_tagged(Some(send), Some(peer), Tag::untagged(round))
                    .unwrap()
                    .unwrap();
                assert_eq!(payload.len(), 32);
                t.complete_tagged(peer, Tag::untagged(round), payload);
            }
            t.counters().clone()
        });
        for (rank, c) in out.iter().enumerate() {
            assert_eq!(c.pool_hits + c.pool_misses, rounds, "rank {rank}");
            // The free-list hand-off races the next recv, so early rounds
            // may miss; steady state must hit (same bound family as the
            // thread pool's warm-up caveat).
            assert!(
                c.pool_hits >= rounds - 4,
                "rank {rank}: only {} hits in {rounds} rounds — recv buffers \
                 are not being recycled",
                c.pool_hits
            );
            assert!(c.bufs_recycled > 0, "rank {rank}: release never recycled");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timeout_detects_missing_peer_message() {
        let dir = scratch_dir("timeout");
        let out = run_mesh::<f32, _, _>(2, &dir, |rank, t| {
            if rank == 0 {
                t.set_timeout(Duration::from_millis(50));
                matches!(
                    Transport::recv_payload(t, 1, Tag::untagged(3)),
                    Err(TransportError::Timeout { .. })
                )
            } else {
                true // rank 1 never sends
            }
        });
        assert!(out[0], "rank 0 should have timed out");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dropped_peer_is_detected_as_peer_down_not_timeout() {
        // Rank 1 sends one frame then drops its transport entirely (the
        // "process died" analogue in-process). Rank 0 must (a) still be
        // able to consume the pre-death frame, (b) fail a later receive
        // with PeerDown — positively and immediately, with a timeout far
        // longer than the test budget — and (c) see the death in the
        // health bitmap and get a typed error (not a panic) from a send.
        let dir = scratch_dir("peerdown");
        let out = run_mesh::<i64, _, _>(2, &dir, |rank, t| {
            if rank == 1 {
                let data = [42i64; 3];
                let send = SendSlices { to: 0, head: &data, tail: &[], rendezvous: false };
                t.sendrecv_slices_tagged(Some(send), None, Tag::new(1, 0)).unwrap();
                true // drop on return: closes the sockets
            } else {
                t.set_timeout(Duration::from_secs(300)); // a hang would be loud
                let pre = Transport::recv_payload(t, 1, Tag::new(1, 0)).unwrap();
                assert_eq!(pre.len(), 3, "pre-death frame must be consumable");
                t.complete_tagged(1, Tag::new(1, 0), pre);
                let start = Instant::now();
                let err = Transport::recv_payload(t, 1, Tag::new(1, 1)).unwrap_err();
                assert!(
                    matches!(err, TransportError::PeerDown { peer: 1, .. }),
                    "want PeerDown, got {err}"
                );
                assert!(
                    start.elapsed() < Duration::from_secs(30),
                    "PeerDown must beat the liveness timeout"
                );
                assert_eq!(t.peer_status(), vec![true, false], "health bitmap");
                assert!(Transport::peer_down(t, 1).is_some());
                // Writes to the dead peer: typed error, no panic. (The
                // first write may land in the socket buffer before the
                // kernel reports the hang-up, so allow one success.)
                let data = [7i64; 2];
                let mut saw_err = false;
                for round in 0..32 {
                    let send =
                        SendSlices { to: 1, head: &data, tail: &[], rendezvous: false };
                    match t.sendrecv_slices_tagged(Some(send), None, Tag::new(2, round)) {
                        Ok(_) => std::thread::sleep(Duration::from_millis(5)),
                        Err(TransportError::PeerDown { peer: 1, .. }) => {
                            saw_err = true;
                            break;
                        }
                        Err(e) => panic!("want PeerDown from a dead-peer send, got {e}"),
                    }
                }
                saw_err
            }
        });
        assert!(out[0], "sends to the dead peer never surfaced PeerDown");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn socket_paths_are_generation_namespaced() {
        let dir = PathBuf::from("/tmp/x");
        assert_eq!(socket_path_gen(&dir, 3, 0), dir.join("rank-3.sock"));
        assert_eq!(socket_path(&dir, 3), socket_path_gen(&dir, 3, 0), "gen 0 = legacy layout");
        assert_eq!(socket_path_gen(&dir, 3, 2), dir.join("gen-2").join("rank-3.sock"));
    }

    #[test]
    fn gen1_mesh_bootstraps_in_its_own_namespace() {
        // A generation-1 re-bootstrap must converge even with stale gen-0
        // socket files sitting in the directory (the failed mesh's
        // leftovers) — the whole point of the namespace.
        let dir = scratch_dir("gen1");
        std::fs::write(socket_path(&dir, 0), b"stale").unwrap();
        std::fs::write(socket_path(&dir, 1), b"stale").unwrap();
        let p = 2usize;
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let dir = dir.clone();
                std::thread::spawn(move || {
                    UdsTransport::<i64>::connect_gen(rank, p, &dir, 1, Duration::from_secs(10))
                })
            })
            .collect();
        let mut mesh: Vec<UdsTransport<i64>> =
            handles.into_iter().map(|h| h.join().unwrap().expect("gen-1 bootstrap")).collect();
        assert!(mesh.iter().all(|t| t.generation() == 1));
        // And the gen-1 mesh carries traffic.
        let data = [11i64; 2];
        let tag = Tag::new(super::super::compose_op(1, 1), 0);
        let (a, b) = {
            let (l, r) = mesh.split_at_mut(1);
            (&mut l[0], &mut r[0])
        };
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            tag,
        )
        .unwrap();
        assert_eq!(Transport::recv_payload(b, 0, tag).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_generation_frames_are_dropped_and_counted() {
        let dir = scratch_dir("stalegen");
        let out = run_mesh::<i64, _, _>(2, &dir, |rank, t| {
            if rank == 1 {
                // One frame from "generation 0" (plain epoch 5), one from
                // generation 1.
                for op in [5u64, super::super::compose_op(1, 5)] {
                    let data = [3i64; 2];
                    let send = SendSlices { to: 0, head: &data, tail: &[], rendezvous: false };
                    t.sendrecv_slices_tagged(Some(send), None, Tag::new(op, 0)).unwrap();
                }
                (0, 0)
            } else {
                // Receiver has moved on to generation 1: the gen-0 frame
                // must be counted and dropped, the gen-1 frame delivered.
                t.set_generation(1);
                let tag = Tag::new(super::super::compose_op(1, 5), 0);
                let payload = Transport::recv_payload(t, 1, tag).unwrap();
                assert_eq!(payload.len(), 2);
                t.complete_tagged(1, tag, payload);
                // The stale frame arrived before or with the gen-1 frame
                // (same sender, ordered stream), so it has been drained.
                let stale = t.stale_frames_dropped();
                let delivered =
                    t.try_recv_payload(1, Tag::new(5, 0)).map(|p| p.len()).unwrap_or(0);
                (stale, delivered)
            }
        });
        assert_eq!(out[0], (1, 0), "stale frame must be counted once and never delivered");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forget_op_discards_only_that_epoch() {
        let dir = scratch_dir("forget");
        let out = run_mesh::<i64, _, _>(2, &dir, |rank, t| {
            if rank == 1 {
                for tag in [Tag::new(9, 0), Tag::new(9, 1), Tag::new(3, 0)] {
                    let data = [5i64; 4];
                    let send =
                        SendSlices { to: 0, head: &data, tail: &[], rendezvous: false };
                    t.sendrecv_slices_tagged(Some(send), None, tag).unwrap();
                }
                0
            } else {
                // Wait until all three frames are decodable, then forget.
                let deadline = Instant::now() + Duration::from_secs(10);
                loop {
                    t.drain_inbox();
                    if t.stash.len() == 3 {
                        break;
                    }
                    assert!(Instant::now() < deadline, "frames never arrived");
                    std::thread::sleep(Duration::from_millis(2));
                }
                let discarded = t.forget_op(9);
                let rest = Transport::recv_payload(t, 1, Tag::new(3, 0)).unwrap();
                assert_eq!(rest.len(), 4);
                t.complete_tagged(1, Tag::new(3, 0), rest);
                discarded
            }
        });
        assert_eq!(out[0], 2, "exactly the two epoch-9 payloads discarded");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
