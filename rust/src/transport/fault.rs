//! Deterministic fault injection over any [`Transport`] backend.
//!
//! The paper's circulant schedules fix the communication pattern per
//! round, so "rank 2's round-3 message to rank 0 is dropped" is a
//! *well-defined, reproducible* event — this module turns that into a
//! test harness. A [`FaultTransport`] wraps any backend (thread or UDS)
//! and applies a seeded, declarative [`FaultPlan`] on the send side:
//!
//! * **drop** — the message is silently black-holed (the receiver sees
//!   nothing and its liveness timeout eventually fires);
//! * **delay** — the send is stalled for a fixed duration (must stay
//!   under the consumer's `op_timeout` to be survivable);
//! * **duplicate** — the frame is sent twice (the stash keys arrivals by
//!   `(from, tag)`, so the duplicate must be absorbed harmlessly);
//! * **truncate** — only a prefix of the payload is sent (the executor's
//!   length validation must reject it, not corrupt the result);
//! * **kill** — from a given operation epoch onward the named rank is
//!   dead: its own sends/receives fail with
//!   [`TransportError::PeerDown`], and every *other* rank's wrapper
//!   reports it down through [`Transport::peer_status`] — the same
//!   signal a real process death produces on the UDS backend, so the
//!   engine's fast-fail path is exercised identically in-process;
//! * **flap** — a kill with a bounded window: the rank is dead for
//!   epochs `[from_op, from_op + down_ops)` and then *revives* — the
//!   deterministic model of a transient disconnect that reconnects
//!   within the recovery deadline, so no-generation-bump recovery is
//!   testable with the same seeded discipline.
//!
//! Rules are keyed by `(rank, op, round)` — any field wildcardable — or
//! fire probabilistically under a [`SplitMix64`] stream seeded per rank
//! (`seed ^ rank`), so a chaos soak is bit-reproducible from its seed
//! alone. All injected sends travel the copy tier (rendezvous is forced
//! off for the affected message): injecting faults into a zero-copy
//! publish would violate the publish/ack contract rather than test it.
//!
//! Kill triggers are **epoch-based, not wall-clock**: every wrapper
//! tracks the highest operation epoch it has touched, and a
//! `kill_rank(r).from_op(n)` rule engages on each wrapper independently
//! once its own epoch watermark reaches `n`. Engine op tags are
//! allocated monotonically and fan out to every rank, so all wrappers
//! observe the trigger at the same point in the op stream — no shared
//! state, no racy clock.

use std::marker::PhantomData;
use std::time::Duration;

use crate::datatypes::Elem;
use crate::util::rng::SplitMix64;

use super::{
    Counters, Payload, SendSlices, Tag, Transport, TransportCaps, TransportError,
};

/// What to do to a matched message (or rank).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// Black-hole the send; the receiver times out (or fast-fails if a
    /// kill also marked the sender down).
    Drop,
    /// Stall the send for this long, then deliver normally.
    Delay(Duration),
    /// Send the frame twice under the same tag.
    Duplicate,
    /// Send only the first `keep` elements of the payload.
    Truncate(usize),
}

/// One declarative message rule: `action` applies when every present
/// key field matches and the per-rank probability draw passes.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRule {
    pub action: FaultAction,
    /// Acting rank (the wrapper whose send is affected); `None` = any.
    pub rank: Option<usize>,
    /// Destination peer of the send; `None` = any.
    pub to: Option<usize>,
    /// Operation epoch; `None` = any.
    pub op: Option<u64>,
    /// Round within the operation; `None` = any.
    pub round: Option<u64>,
    /// Probability in `[0, 1]` that a key-matched send is affected
    /// (1.0 = always). Drawn from the wrapper's seeded stream.
    pub probability: f64,
}

impl FaultRule {
    pub fn new(action: FaultAction) -> Self {
        Self { action, rank: None, to: None, op: None, round: None, probability: 1.0 }
    }

    pub fn on_rank(mut self, rank: usize) -> Self {
        self.rank = Some(rank);
        self
    }

    pub fn to_peer(mut self, to: usize) -> Self {
        self.to = Some(to);
        self
    }

    pub fn at_op(mut self, op: u64) -> Self {
        self.op = Some(op);
        self
    }

    pub fn at_round(mut self, round: u64) -> Self {
        self.round = Some(round);
        self
    }

    pub fn with_probability(mut self, probability: f64) -> Self {
        assert!((0.0..=1.0).contains(&probability), "probability {probability} not in [0, 1]");
        self.probability = probability;
        self
    }

    fn matches(&self, rank: usize, to: usize, tag: Tag) -> bool {
        self.rank.is_none_or(|r| r == rank)
            && self.to.is_none_or(|t| t == to)
            && self.op.is_none_or(|o| o == tag.op)
            && self.round.is_none_or(|r| r == tag.round)
    }
}

/// A rank death: from operation epoch `from_op` onward, `rank` is dead
/// as far as every wrapper sharing the plan is concerned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillRule {
    pub rank: usize,
    pub from_op: u64,
}

/// A transient rank death ("flap"): `rank` behaves exactly like a
/// [`KillRule`] kill while the epoch watermark is in
/// `[from_op, from_op + down_ops)`, then **revives** — sends/receives
/// succeed again and [`Transport::peer_status`] reports it back up.
/// Same epoch-watermark trigger discipline as `KillRule`, so a flap is
/// bit-reproducible from the plan alone: this is the deterministic
/// model of a peer that disconnects and reconnects within the recovery
/// deadline (no generation bump, no reconfiguration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlapRule {
    pub rank: usize,
    /// First epoch at which the rank is down.
    pub from_op: u64,
    /// Width of the outage window in epochs; the rank is back up once
    /// the watermark reaches `from_op + down_ops`.
    pub down_ops: u64,
}

/// The full declarative fault schedule one chaos run executes. Clone it
/// into every rank's [`FaultTransport`]; determinism comes from the
/// seed, not from shared state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
    pub kills: Vec<KillRule>,
    pub flaps: Vec<FlapRule>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self { seed, rules: Vec::new(), kills: Vec::new(), flaps: Vec::new() }
    }

    /// Add a message rule.
    pub fn rule(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }

    /// Kill `rank` from operation epoch `from_op` onward.
    pub fn kill_rank(mut self, rank: usize, from_op: u64) -> Self {
        self.kills.push(KillRule { rank, from_op });
        self
    }

    /// Take `rank` down for epochs `[from_op, from_op + down_ops)`, then
    /// revive it (deterministic kill-then-revive).
    pub fn flap_rank(mut self, rank: usize, from_op: u64, down_ops: u64) -> Self {
        self.flaps.push(FlapRule { rank, from_op, down_ops });
        self
    }

    /// Shorthand: drop rank `rank`'s round-`round` send of epoch `op`.
    pub fn drop_at(self, rank: usize, op: u64, round: u64) -> Self {
        self.rule(FaultRule::new(FaultAction::Drop).on_rank(rank).at_op(op).at_round(round))
    }

    /// Shorthand: delay rank `rank`'s round-`round` send of epoch `op`.
    pub fn delay_at(self, rank: usize, op: u64, round: u64, by: Duration) -> Self {
        self.rule(FaultRule::new(FaultAction::Delay(by)).on_rank(rank).at_op(op).at_round(round))
    }

    /// Whether any rule, kill or flap exists at all (an empty plan is a
    /// transparent wrapper).
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty() && self.kills.is_empty() && self.flaps.is_empty()
    }
}

/// Counts of faults actually injected by one wrapper — chaos runs
/// report these so "nothing happened" soaks are distinguishable from
/// "the plan never fired".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub drops: u64,
    pub delays: u64,
    pub duplicates: u64,
    pub truncations: u64,
    /// Sends/receives refused because a kill rule had engaged (self or
    /// the peer dead).
    pub dead_refusals: u64,
}

/// A [`Transport`] decorator applying a [`FaultPlan`] — see the module
/// docs. All non-send surfaces (pools, quiesce, counters) delegate
/// untouched, so cleanup paths (`forget_op`) keep working even on a
/// "dead" rank: death here models the *wire* going dark, not the local
/// process memory.
pub struct FaultTransport<E: Elem, T: Transport<E>> {
    inner: T,
    plan: FaultPlan,
    rng: SplitMix64,
    /// Highest operation epoch this wrapper has touched — the kill
    /// trigger watermark.
    max_op_seen: u64,
    stats: FaultStats,
    _elem: PhantomData<E>,
}

impl<E: Elem, T: Transport<E>> FaultTransport<E, T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        let rank = inner.rank() as u64;
        let seed = plan.seed ^ rank.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self { inner, plan, rng: SplitMix64::new(seed), max_op_seen: 0, stats: FaultStats::default(), _elem: PhantomData }
    }

    /// Injection counters so far.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// The wrapped backend (e.g. to read backend-specific state in
    /// tests).
    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut T {
        &mut self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    fn note_op(&mut self, op: u64) {
        if op > self.max_op_seen {
            self.max_op_seen = op;
        }
    }

    /// Kill detail for `rank` if a kill rule — or a flap rule still
    /// inside its outage window — has engaged at the current epoch
    /// watermark. A flap whose window the watermark has passed no longer
    /// matches: the rank has revived.
    fn killed(&self, rank: usize) -> Option<String> {
        if let Some(k) = self.plan.kills.iter().find(|k| k.rank == rank && self.max_op_seen >= k.from_op)
        {
            return Some(format!("fault-injected kill of rank {} from op {}", k.rank, k.from_op));
        }
        self.plan
            .flaps
            .iter()
            .find(|f| {
                f.rank == rank
                    && self.max_op_seen >= f.from_op
                    && self.max_op_seen < f.from_op.saturating_add(f.down_ops)
            })
            .map(|f| {
                format!(
                    "fault-injected flap of rank {}: down for ops [{}, {})",
                    f.rank,
                    f.from_op,
                    f.from_op.saturating_add(f.down_ops)
                )
            })
    }

    fn self_dead(&self) -> Option<TransportError> {
        self.killed(self.inner.rank()).map(|detail| TransportError::PeerDown {
            rank: self.inner.rank(),
            peer: self.inner.rank(),
            detail,
        })
    }

    /// First matching rule's action for a send, probability included.
    fn action_for(&mut self, to: usize, tag: Tag) -> Option<FaultAction> {
        let rank = self.inner.rank();
        for i in 0..self.plan.rules.len() {
            if !self.plan.rules[i].matches(rank, to, tag) {
                continue;
            }
            let p = self.plan.rules[i].probability;
            if p >= 1.0 || self.rng.next_f64() < p {
                return Some(self.plan.rules[i].action.clone());
            }
        }
        None
    }
}

impl<E: Elem, T: Transport<E>> Transport<E> for FaultTransport<E, T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn p(&self) -> usize {
        self.inner.p()
    }

    fn caps(&self) -> TransportCaps {
        // Rendezvous is reported unsupported: injected faults must act
        // on materialized frames, and a publish whose descriptors are
        // dropped/truncated would break the ack contract instead of
        // testing the failure path.
        TransportCaps { supports_rendezvous: false, ..self.inner.caps() }
    }

    fn sendrecv_slices_tagged(
        &mut self,
        send: Option<SendSlices<'_, E>>,
        recv_from: Option<usize>,
        tag: Tag,
    ) -> Result<Option<Payload<E>>, TransportError> {
        self.note_op(tag.op);
        if let Some(err) = self.self_dead() {
            self.stats.dead_refusals += 1;
            return Err(err);
        }
        let rank = self.inner.rank();
        let send = match send {
            None => None,
            Some(s) => {
                if let Some(detail) = self.killed(s.to) {
                    // A dead destination behaves like a dead socket:
                    // the write fails loudly, not silently.
                    self.stats.dead_refusals += 1;
                    return Err(TransportError::PeerDown { rank, peer: s.to, detail });
                }
                match self.action_for(s.to, tag) {
                    None => Some(SendSlices { rendezvous: false, ..s }),
                    Some(FaultAction::Drop) => {
                        self.stats.drops += 1;
                        None
                    }
                    Some(FaultAction::Delay(by)) => {
                        self.stats.delays += 1;
                        std::thread::sleep(by);
                        Some(SendSlices { rendezvous: false, ..s })
                    }
                    Some(FaultAction::Duplicate) => {
                        self.stats.duplicates += 1;
                        let dup = SendSlices {
                            to: s.to,
                            head: s.head,
                            tail: s.tail,
                            rendezvous: false,
                        };
                        self.inner.sendrecv_slices_tagged(Some(dup), None, tag)?;
                        Some(SendSlices { rendezvous: false, ..s })
                    }
                    Some(FaultAction::Truncate(keep)) => {
                        self.stats.truncations += 1;
                        let head_keep = keep.min(s.head.len());
                        let tail_keep = keep.saturating_sub(head_keep).min(s.tail.len());
                        Some(SendSlices {
                            to: s.to,
                            head: &s.head[..head_keep],
                            tail: &s.tail[..tail_keep],
                            rendezvous: false,
                        })
                    }
                }
            }
        };
        if let Some(from) = recv_from {
            if let Some(detail) = self.killed(from) {
                // Still push the (possibly faulted) send out so peers
                // that only needed our data can finish, then refuse the
                // receive: nothing will ever arrive from a dead peer.
                self.inner.sendrecv_slices_tagged(send, None, tag)?;
                self.stats.dead_refusals += 1;
                return Err(TransportError::PeerDown { rank, peer: from, detail });
            }
        }
        self.inner.sendrecv_slices_tagged(send, recv_from, tag)
    }

    fn recv_payload(&mut self, from: usize, tag: Tag) -> Result<Payload<E>, TransportError> {
        self.note_op(tag.op);
        if let Some(err) = self.self_dead() {
            self.stats.dead_refusals += 1;
            return Err(err);
        }
        if let Some(detail) = self.killed(from) {
            self.stats.dead_refusals += 1;
            return Err(TransportError::PeerDown { rank: self.inner.rank(), peer: from, detail });
        }
        self.inner.recv_payload(from, tag)
    }

    fn try_recv_payload(&mut self, from: usize, tag: Tag) -> Option<Payload<E>> {
        self.note_op(tag.op);
        if self.killed(self.inner.rank()).is_some() || self.killed(from).is_some() {
            // Poll-mode callers learn of the death through peer_status /
            // the blocking paths; a poll just never yields data.
            return None;
        }
        self.inner.try_recv_payload(from, tag)
    }

    fn complete_tagged(&mut self, from: usize, tag: Tag, payload: Payload<E>) {
        self.inner.complete_tagged(from, tag, payload)
    }

    fn acquire(&mut self, to: usize, need: usize) -> Vec<E> {
        self.inner.acquire(to, need)
    }

    fn release(&mut self, from: usize, payload: Vec<E>) {
        self.inner.release(from, payload)
    }

    fn finish_round(&mut self) -> Result<(), TransportError> {
        self.inner.finish_round()
    }

    fn finish_op(&mut self, op: u64) -> Result<(), TransportError> {
        self.note_op(op);
        self.inner.finish_op(op)
    }

    fn try_finish(&mut self, tag: Tag) -> bool {
        self.inner.try_finish(tag)
    }

    fn op_has_pending_publish(&mut self, op: u64) -> bool {
        self.inner.op_has_pending_publish(op)
    }

    fn forget_op(&mut self, op: u64) -> usize {
        self.inner.forget_op(op)
    }

    fn counters(&self) -> &Counters {
        self.inner.counters()
    }

    fn counters_mut(&mut self) -> &mut Counters {
        self.inner.counters_mut()
    }

    fn peer_status(&self) -> Vec<bool> {
        let mut status = self.inner.peer_status();
        for (r, up) in status.iter_mut().enumerate() {
            if r != self.inner.rank() && self.killed(r).is_some() {
                *up = false;
            }
        }
        status
    }

    fn peer_down(&self, peer: usize) -> Option<String> {
        if peer != self.inner.rank() {
            if let Some(detail) = self.killed(peer) {
                return Some(detail);
            }
        }
        self.inner.peer_down(peer)
    }

    fn timeout(&self) -> Duration {
        self.inner.timeout()
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.inner.set_timeout(timeout)
    }

    fn set_rendezvous(&mut self, on: bool) {
        self.inner.set_rendezvous(on)
    }

    fn set_rendezvous_min_elems(&mut self, min: usize) {
        self.inner.set_rendezvous_min_elems(min)
    }

    fn set_retry(&mut self, attempts: usize, base_ms: u64) {
        self.inner.set_retry(attempts, base_ms)
    }

    fn generation(&self) -> u64 {
        self.inner.generation()
    }

    fn set_generation(&mut self, gen: u64) {
        self.inner.set_generation(gen)
    }

    fn stale_frames_dropped(&self) -> u64 {
        self.inner.stale_frames_dropped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::network_typed;

    fn pair() -> Vec<crate::transport::Endpoint<i64>> {
        network_typed::<i64>(2)
    }

    #[test]
    fn empty_plan_is_transparent() {
        let mut eps = pair().into_iter();
        let mut a = FaultTransport::new(eps.next().unwrap(), FaultPlan::new(1));
        let mut b = eps.next().unwrap();
        let tag = Tag::new(7, 0);
        let data = [1i64, 2, 3];
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            tag,
        )
        .unwrap();
        let got = b.recv_payload(0, tag).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(a.stats(), FaultStats::default());
        assert!(a.peer_status().iter().all(|&up| up));
    }

    #[test]
    fn drop_rule_black_holes_the_send() {
        let mut eps = pair().into_iter();
        let plan = FaultPlan::new(2).drop_at(0, 7, 0);
        let mut a = FaultTransport::new(eps.next().unwrap(), plan);
        let mut b = eps.next().unwrap();
        b.timeout = Duration::from_millis(50);
        let data = [5i64; 4];
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            Tag::new(7, 0),
        )
        .unwrap();
        assert_eq!(a.stats().drops, 1);
        let err = b.recv_payload(0, Tag::new(7, 0)).unwrap_err();
        assert!(matches!(err, TransportError::Timeout { .. }), "{err}");
        // A different round of the same op is untouched.
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            Tag::new(7, 1),
        )
        .unwrap();
        assert_eq!(b.recv_payload(0, Tag::new(7, 1)).unwrap().len(), 4);
    }

    #[test]
    fn kill_marks_peer_down_everywhere_once_epoch_reached() {
        let mut eps = pair().into_iter();
        let plan = FaultPlan::new(3).kill_rank(1, 5);
        let mut a = FaultTransport::new(eps.next().unwrap(), plan.clone());
        let mut b = FaultTransport::new(eps.next().unwrap(), plan);
        // Before the trigger epoch everything flows.
        let data = [9i64; 2];
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            Tag::new(4, 0),
        )
        .unwrap();
        assert_eq!(b.recv_payload(0, Tag::new(4, 0)).unwrap().len(), 2);
        assert!(a.peer_status()[1]);
        // From epoch 5 on: rank 1 is dead to rank 0, and rank 1's own
        // operations refuse.
        let err = a
            .sendrecv_slices_tagged(
                Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
                None,
                Tag::new(5, 0),
            )
            .unwrap_err();
        assert!(matches!(err, TransportError::PeerDown { peer: 1, .. }), "{err}");
        assert!(!a.peer_status()[1], "health bitmap must reflect the kill");
        assert!(a.peer_down(1).is_some());
        let err = b.recv_payload(0, Tag::new(5, 0)).unwrap_err();
        assert!(matches!(err, TransportError::PeerDown { .. }), "{err}");
        assert!(b.peer_status()[1], "own slot stays up by contract");
    }

    #[test]
    fn flap_kills_then_revives_at_the_window_edge() {
        let mut eps = pair().into_iter();
        let plan = FaultPlan::new(9).flap_rank(1, 5, 3); // down for ops 5..8
        let mut a = FaultTransport::new(eps.next().unwrap(), plan.clone());
        let mut b = FaultTransport::new(eps.next().unwrap(), plan);
        let data = [2i64; 2];
        let send = |to: usize| SendSlices { to, head: &data, tail: &[], rendezvous: false };
        // Before the window: up.
        a.sendrecv_slices_tagged(Some(send(1)), None, Tag::new(4, 0)).unwrap();
        assert_eq!(b.recv_payload(0, Tag::new(4, 0)).unwrap().len(), 2);
        assert!(a.peer_status()[1]);
        // Inside the window: down, exactly like a kill.
        let err = a.sendrecv_slices_tagged(Some(send(1)), None, Tag::new(6, 0)).unwrap_err();
        assert!(matches!(err, TransportError::PeerDown { peer: 1, .. }), "{err}");
        assert!(!a.peer_status()[1], "flap window must read as down");
        assert!(a.peer_down(1).is_some());
        // Past the window: revived — sends flow and the bitmap is clean
        // again, with no generation bump anywhere (transport-level
        // recovery, not a reconfiguration).
        a.sendrecv_slices_tagged(Some(send(1)), None, Tag::new(8, 0)).unwrap();
        assert_eq!(b.recv_payload(0, Tag::new(8, 0)).unwrap().len(), 2);
        assert!(a.peer_status()[1], "rank must revive after the window");
        assert!(a.peer_down(1).is_none());
        assert_eq!(a.generation(), 0);
        assert_eq!(a.stats().dead_refusals, 1);
    }

    #[test]
    fn truncate_shortens_the_frame() {
        let mut eps = pair().into_iter();
        let plan =
            FaultPlan::new(4).rule(FaultRule::new(FaultAction::Truncate(2)).on_rank(0).at_op(9));
        let mut a = FaultTransport::new(eps.next().unwrap(), plan);
        let mut b = eps.next().unwrap();
        let data = [3i64; 6];
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            Tag::new(9, 0),
        )
        .unwrap();
        assert_eq!(a.stats().truncations, 1);
        assert_eq!(b.recv_payload(0, Tag::new(9, 0)).unwrap().len(), 2);
    }

    #[test]
    fn duplicate_sends_twice_and_stash_absorbs() {
        let mut eps = pair().into_iter();
        let plan = FaultPlan::new(5)
            .rule(FaultRule::new(FaultAction::Duplicate).on_rank(0).at_op(3).at_round(0));
        let mut a = FaultTransport::new(eps.next().unwrap(), plan);
        let mut b = eps.next().unwrap();
        let data = [7i64; 3];
        a.sendrecv_slices_tagged(
            Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
            None,
            Tag::new(3, 0),
        )
        .unwrap();
        assert_eq!(a.stats().duplicates, 1);
        // Both copies arrive; the tagged receive consumes one and the
        // stash (keyed by (from, tag)) absorbs the other harmlessly.
        assert_eq!(b.recv_payload(0, Tag::new(3, 0)).unwrap().len(), 3);
    }

    #[test]
    fn probability_stream_is_reproducible_from_the_seed() {
        let run = |seed: u64| -> u64 {
            let mut eps = pair().into_iter();
            let plan = FaultPlan::new(seed)
                .rule(FaultRule::new(FaultAction::Drop).on_rank(0).with_probability(0.5));
            let mut a = FaultTransport::new(eps.next().unwrap(), plan);
            let _b = eps.next().unwrap();
            let data = [1i64; 2];
            for round in 0..64 {
                let _ = a.sendrecv_slices_tagged(
                    Some(SendSlices { to: 1, head: &data, tail: &[], rendezvous: false }),
                    None,
                    Tag::new(1, round),
                );
            }
            a.stats().drops
        };
        let d1 = run(42);
        assert_eq!(d1, run(42), "same seed, same drops");
        assert!(d1 > 0 && d1 < 64, "p=0.5 over 64 sends should drop some, not all: {d1}");
    }
}
