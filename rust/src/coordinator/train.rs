//! End-to-end data-parallel training driver (the E2E experiment of
//! DESIGN.md §5).
//!
//! `p` simulated workers each hold a full replica of a small MLP regressor
//! (the Layer-2 JAX model, AOT-compiled to `mlp_loss_grad.hlo.txt`). Per
//! step, every worker:
//!   1. draws its own shard of a synthetic regression batch,
//!   2. computes `(loss, grad)` through PJRT (Layer 2/1 compute),
//!   3. **allreduces the flat gradient with Algorithm 2** (the paper's
//!      contribution, on the thread network, γ term through the AOT Pallas
//!      combine kernel when the PJRT backend is selected),
//!   4. applies an SGD step locally (replicas stay bit-identical because
//!      the allreduce result is identical on every rank).
//!
//! Reported: the loss curve, the collective counters (which must match
//! Theorem 2 per step), and wall-clock. Recorded in EXPERIMENTS.md §E2E.


use crate::coordinator::{Launcher, OpBackend};
use crate::runtime::{ComputeService, Manifest};
use crate::topology::skips::SkipScheme;
use crate::util::ceil_log2;
use crate::util::rng::SplitMix64;

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub workers: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Log every `log_every` steps (0 = silent).
    pub log_every: usize,
    /// Run the gradient allreduce γ term through PJRT (true) or native
    /// loops (false). Model fwd/bwd always runs through PJRT.
    pub pjrt_reduce: bool,
    pub scheme: SkipScheme,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            steps: 300,
            lr: 0.05,
            seed: 7,
            log_every: 20,
            pjrt_reduce: true,
            scheme: SkipScheme::HalvingUp,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// `(step, mean loss over workers)` at each logged step.
    pub losses: Vec<(usize, f32)>,
    pub first_loss: f32,
    pub final_loss: f32,
    pub wall_seconds: f64,
    pub params: usize,
    pub workers: usize,
    pub steps: usize,
    /// Per-step gradient elements allreduced per worker (2(p−1)/p·P).
    pub grad_elems_per_step: usize,
    /// Rounds per allreduce (must equal 2⌈log2 p⌉ — Theorem 2).
    pub rounds_per_allreduce: usize,
}

/// Deterministic teacher weights for the synthetic regression task.
fn teacher(d_in: usize, d_out: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed ^ 0x7eac_7eac);
    rng.normal_vec(d_in * d_out)
}

/// Draw a batch from the teacher: `y = tanh(x·W*)·0.5 + ε`.
fn draw_batch(
    rng: &mut SplitMix64,
    w: &[f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
) -> (Vec<f32>, Vec<f32>) {
    let x = rng.normal_vec(batch * d_in);
    let mut y = vec![0.0f32; batch * d_out];
    for b in 0..batch {
        for o in 0..d_out {
            let mut acc = 0.0f32;
            for i in 0..d_in {
                acc += x[b * d_in + i] * w[i * d_out + o];
            }
            y[b * d_out + o] = (acc as f64).tanh() as f32 * 0.5 + 0.01 * rng.next_normal_f32();
        }
    }
    (x, y)
}

/// Glorot-ish flat init (mirrors `model.mlp_init`'s scaling; exact values
/// differ — any common init works since all replicas share it).
fn init_params(meta: &crate::runtime::manifest::MlpMeta, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let (d, h, o) = (meta.d_in, meta.hidden, meta.d_out);
    let mut params = Vec::with_capacity(meta.params);
    let scaled = |rng: &mut SplitMix64, n: usize, scale: f32| -> Vec<f32> {
        rng.normal_vec(n).into_iter().map(|x| x * scale).collect()
    };
    params.extend(scaled(&mut rng, d * h, 1.0 / (d as f32).sqrt()));
    params.extend(std::iter::repeat_n(0.0, h));
    params.extend(scaled(&mut rng, h * h, 1.0 / (h as f32).sqrt()));
    params.extend(std::iter::repeat_n(0.0, h));
    params.extend(scaled(&mut rng, h * o, 1.0 / (h as f32).sqrt()));
    params.extend(std::iter::repeat_n(0.0, o));
    assert_eq!(params.len(), meta.params);
    params
}

/// Run the data-parallel training job over the thread network.
pub fn train(artifact_dir: &std::path::Path, cfg: &TrainConfig) -> anyhow::Result<TrainReport> {
    let manifest = Manifest::load(artifact_dir)?;
    let meta = manifest.mlp;
    let service = ComputeService::start(
        artifact_dir,
        vec!["sum".to_string()],
        false,
        true,
    )?;
    let handle = service.handle.clone();

    let p = cfg.workers;
    let backend = if cfg.pjrt_reduce {
        OpBackend::Pjrt(handle.clone())
    } else {
        OpBackend::Native
    };
    let cfg2 = cfg.clone();
    let t0 = std::time::Instant::now();
    let launcher = Launcher::new(p).scheme(cfg.scheme.clone()).backend(backend);

    let per_rank: Vec<(Vec<(usize, f32)>, u64, u64)> = launcher.run(move |mut comm| {
        let rank = comm.rank();
        let p = comm.size();
        let w_teacher = teacher(meta.d_in, meta.d_out, cfg2.seed);
        let mut params = init_params(&meta, cfg2.seed);
        let mut data_rng = SplitMix64::new(cfg2.seed * 1000 + rank as u64);
        let mut losses = Vec::new();
        for step in 0..cfg2.steps {
            let (x, y) = draw_batch(&mut data_rng, &w_teacher, meta.batch, meta.d_in, meta.d_out);
            let (loss, mut grad) = handle
                .mlp_loss_grad(params.clone(), x, y)
                .expect("mlp_loss_grad");
            // The paper's allreduce over the flat gradient.
            comm.allreduce(&mut grad, "sum").expect("allreduce grad");
            // Mean loss across workers for logging (tiny allreduce).
            let mut lbuf = vec![loss];
            comm.allreduce(&mut lbuf, "sum").expect("allreduce loss");
            let mean_loss = lbuf[0] / p as f32;
            let scale = cfg2.lr / p as f32;
            for (w, g) in params.iter_mut().zip(&grad) {
                *w -= scale * g;
            }
            if cfg2.log_every > 0 && (step % cfg2.log_every == 0 || step + 1 == cfg2.steps) {
                losses.push((step, mean_loss));
                if rank == 0 {
                    eprintln!("step {step:4}  loss {mean_loss:.6}");
                }
            }
        }
        let c = comm.counters();
        (losses, c.elems_sent, c.sendrecv_rounds)
    });

    let wall_seconds = t0.elapsed().as_secs_f64();
    let losses = per_rank[0].0.clone();
    let first_loss = losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN);
    let final_loss = losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
    // Per step each worker allreduces the gradient (P elems over p blocks)
    // and one scalar; volume per allreduce = 2·Σ_{g≠r} block_g ≈ 2(p−1)/p·P.
    let q = 2 * ceil_log2(p) as usize;
    Ok(TrainReport {
        losses,
        first_loss,
        final_loss,
        wall_seconds,
        params: meta.params,
        workers: p,
        steps: cfg.steps,
        grad_elems_per_step: (per_rank[0].1 / cfg.steps as u64) as usize,
        rounds_per_allreduce: if p > 1 { q } else { 0 },
    })
}
