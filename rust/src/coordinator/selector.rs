//! Algorithm auto-selection by cost model.
//!
//! The paper's Algorithm 2 is simultaneously round- and volume-optimal, so
//! in the pure α-β-γ model it dominates the classic baselines everywhere —
//! the interesting selection question (which the paper raises in §3) is
//! *within* the circulant family: which skip scheme, and whether the
//! degenerate single-block schedules should serve small reduce/bcast.
//! `select_allreduce` evaluates the closed forms and returns the winner
//! with its predicted time — used by the CLI's `--algorithm auto` and
//! exercised against DES results in tests.

use crate::collectives::Algorithm;
use crate::sim::{closed_form, CostModel};
use crate::topology::skips::SkipScheme;

/// Candidate set with closed-form predictors.
fn candidates() -> Vec<(Algorithm, fn(&CostModel, usize, usize) -> f64)> {
    vec![
        (
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
            closed_form::alg2_allreduce as fn(&CostModel, usize, usize) -> f64,
        ),
        (Algorithm::RingAllreduce, closed_form::ring_allreduce),
        (Algorithm::RecursiveDoublingAllreduce, closed_form::recursive_doubling_allreduce),
        (Algorithm::RabenseifnerAllreduce, closed_form::rabenseifner_allreduce),
        (Algorithm::BinomialAllreduce, closed_form::binomial_allreduce),
    ]
}

/// How the engine should *execute* a circulant allreduce of `m` elements
/// — the size-adaptive dispatch decision, grounded in the same closed
/// forms as the algorithm choice. (Fusion, the third tier, is a
/// multi-op batching decision the selector cannot see from one `(p, m)`
/// pair; the engine applies its byte budget upstream.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One plain run of the whole vector (Algorithm 2 as published).
    Plain,
    /// Chunked into `chunk_elems`-element wire epochs overlapping combine
    /// with communication.
    Pipelined,
}

/// Pick plain vs pipelined execution for a circulant allreduce of `m`
/// elements with `chunk_elems`-element chunks, returning the mode and its
/// predicted time. Pipelined is chosen only when the model says the
/// hidden combine time beats the extra per-chunk round latencies —
/// i.e. `pipelined_circulant_allreduce < alg2_allreduce` — so
/// `chunk_elems = 0` (tier disabled) or fewer than two whole chunks
/// always yields `Plain`.
pub fn select_execution_mode(
    model: &CostModel,
    p: usize,
    m: usize,
    chunk_elems: usize,
) -> (ExecMode, f64) {
    let plain = closed_form::alg2_allreduce(model, p, m);
    if closed_form::pipeline_num_chunks(m, chunk_elems) < 2 {
        return (ExecMode::Plain, plain);
    }
    let piped = closed_form::pipelined_circulant_allreduce(model, p, m, chunk_elems);
    if piped < plain {
        (ExecMode::Pipelined, piped)
    } else {
        (ExecMode::Plain, plain)
    }
}

/// Pick the fastest allreduce for `(p, m)` under `model`.
pub fn select_allreduce(model: &CostModel, p: usize, m: usize) -> (Algorithm, f64) {
    let mut best: Option<(Algorithm, f64)> = None;
    for (alg, f) in candidates() {
        // Rabenseifner is only considered for power-of-two p. Its non-pow2
        // closed form folds the extra ranks in with flat `α+βm(+γm)` terms,
        // but the actual schedule halves over *block groups* of uneven
        // size, so the formula is an approximation there — predicting with
        // it could hand a non-pow2 job to the schedule the model flattered
        // rather than the one that is actually fastest. (A previous guard
        // here filtered RecursiveHalvingReduceScatter, which is not an
        // allreduce and was never in the candidate set — dead code.)
        if matches!(alg, Algorithm::RabenseifnerAllreduce) && !p.is_power_of_two() {
            continue;
        }
        let t = f(model, p, m);
        if best.as_ref().is_none_or(|(_, bt)| t < *bt) {
            best = Some((alg, t));
        }
    }
    best.expect("non-empty candidate set")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_structure() {
        // Theorem 2 makes Algorithm 2 *volume*-optimal with 2⌈log2 p⌉
        // rounds; recursive doubling uses only ⌈log2 p⌉ rounds at m·log p
        // volume. Hence the honest crossover: rec-doubling may win for tiny
        // m (α regime), Algorithm 2 wins for large m (β/γ regime), and
        // Algorithm 2 dominates the ring everywhere (identical volume,
        // fewer rounds).
        let c = CostModel::cluster();
        for p in [3usize, 22, 100, 1000] {
            // large m: Algorithm 2 (or its power-of-two twin Rabenseifner)
            let m = 1 << 22;
            let (alg, t) = select_allreduce(&c, p, m);
            let circ = closed_form::alg2_allreduce(&c, p, m);
            assert!(
                matches!(alg, Algorithm::CirculantAllreduce(_)) || (t - circ).abs() < 1e-9,
                "p={p}: {} at {t}, alg2 {circ}",
                alg.name()
            );
            // always at least as good as the ring
            for m in [1usize, 1 << 10, 1 << 22] {
                assert!(
                    closed_form::alg2_allreduce(&c, p, m)
                        <= closed_form::ring_allreduce(&c, p, m) + 1e-12,
                    "p={p} m={m}"
                );
            }
        }
        // tiny m at large p: a ⌈log2 p⌉-round algorithm wins the α game
        let (alg, _) = select_allreduce(&CostModel::latency_bound(), 1000, 1);
        assert!(
            matches!(alg, Algorithm::RecursiveDoublingAllreduce | Algorithm::BinomialAllreduce),
            "expected a q-round algorithm for m=1, got {}",
            alg.name()
        );
    }

    #[test]
    fn rabenseifner_gated_to_powers_of_two() {
        // The non-pow2 guard must actually bite: across cost models and
        // regimes, selection at non-power-of-two p never returns
        // Rabenseifner (its closed form is only exact for pow2), while at
        // power-of-two p it stays a legal candidate (it ties Algorithm 2
        // there, and ties resolve to the earlier candidate, so we assert
        // legality via prediction equality rather than selection).
        for model in [CostModel::cluster(), CostModel::latency_bound()] {
            for p in [3usize, 5, 6, 7, 22, 100, 1000] {
                for m in [1usize, 1 << 10, 1 << 22] {
                    let (alg, _) = select_allreduce(&model, p, m);
                    assert!(
                        !matches!(alg, Algorithm::RabenseifnerAllreduce),
                        "p={p} m={m}: rabenseifner selected for non-pow2 p"
                    );
                }
            }
        }
        let c = CostModel::cluster();
        for p in [4usize, 64, 1024] {
            let twin = (closed_form::rabenseifner_allreduce(&c, p, 1 << 20)
                - closed_form::alg2_allreduce(&c, p, 1 << 20))
            .abs();
            assert!(twin < 1e-12, "p={p}: pow2 rabenseifner must tie alg2");
        }
    }

    #[test]
    fn predictions_are_positive_and_monotone_in_m() {
        let c = CostModel::cluster();
        let (_, t1) = select_allreduce(&c, 64, 1 << 10);
        let (_, t2) = select_allreduce(&c, 64, 1 << 20);
        assert!(0.0 < t1 && t1 < t2);
    }

    #[test]
    fn execution_mode_tracks_the_break_even() {
        let c = CostModel::cluster();
        let p = 8;
        let chunk = 1 << 15;
        // Large m: the hidden combine time wins.
        let (mode, t) = select_execution_mode(&c, p, 1 << 22, chunk);
        assert_eq!(mode, ExecMode::Pipelined);
        assert!(t < closed_form::alg2_allreduce(&c, p, 1 << 22));
        // Below two chunks the tier degenerates to plain — exactly the
        // engine's `pipeline_chunk_sizes` behavior.
        let (mode, t) = select_execution_mode(&c, p, chunk, chunk);
        assert_eq!(mode, ExecMode::Plain);
        assert!((t - closed_form::alg2_allreduce(&c, p, chunk)).abs() < 1e-9);
        // Disabled tier always yields plain.
        let (mode, _) = select_execution_mode(&c, p, 1 << 22, 0);
        assert_eq!(mode, ExecMode::Plain);
        // The model-derived break-even is respected: just below it the
        // selector stays plain only if the formula says so — consistency,
        // not a magic constant.
        if let Some(be) = closed_form::pipeline_break_even_elems(&c, p, chunk) {
            let (mode, _) = select_execution_mode(&c, p, be, chunk);
            assert_eq!(mode, ExecMode::Pipelined);
        }
    }
}
