//! Algorithm auto-selection by cost model.
//!
//! The paper's Algorithm 2 is simultaneously round- and volume-optimal, so
//! in the pure α-β-γ model it dominates the classic baselines everywhere —
//! the interesting selection question (which the paper raises in §3) is
//! *within* the circulant family: which skip scheme, and whether the
//! degenerate single-block schedules should serve small reduce/bcast.
//! `select_allreduce` evaluates the closed forms and returns the winner
//! with its predicted time — used by the CLI's `--algorithm auto` and
//! exercised against DES results in tests.

use crate::collectives::Algorithm;
use crate::sim::{closed_form, CostModel};
use crate::topology::skips::SkipScheme;

/// Candidate set with closed-form predictors.
fn candidates() -> Vec<(Algorithm, fn(&CostModel, usize, usize) -> f64)> {
    vec![
        (
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
            closed_form::alg2_allreduce as fn(&CostModel, usize, usize) -> f64,
        ),
        (Algorithm::RingAllreduce, closed_form::ring_allreduce),
        (Algorithm::RecursiveDoublingAllreduce, closed_form::recursive_doubling_allreduce),
        (Algorithm::RabenseifnerAllreduce, closed_form::rabenseifner_allreduce),
        (Algorithm::BinomialAllreduce, closed_form::binomial_allreduce),
    ]
}

/// Pick the fastest allreduce for `(p, m)` under `model`.
pub fn select_allreduce(model: &CostModel, p: usize, m: usize) -> (Algorithm, f64) {
    let mut best: Option<(Algorithm, f64)> = None;
    for (alg, f) in candidates() {
        if matches!(alg, Algorithm::RecursiveHalvingReduceScatter) && !p.is_power_of_two() {
            continue;
        }
        let t = f(model, p, m);
        if best.as_ref().is_none_or(|(_, bt)| t < *bt) {
            best = Some((alg, t));
        }
    }
    best.expect("non-empty candidate set")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_structure() {
        // Theorem 2 makes Algorithm 2 *volume*-optimal with 2⌈log2 p⌉
        // rounds; recursive doubling uses only ⌈log2 p⌉ rounds at m·log p
        // volume. Hence the honest crossover: rec-doubling may win for tiny
        // m (α regime), Algorithm 2 wins for large m (β/γ regime), and
        // Algorithm 2 dominates the ring everywhere (identical volume,
        // fewer rounds).
        let c = CostModel::cluster();
        for p in [3usize, 22, 100, 1000] {
            // large m: Algorithm 2 (or its power-of-two twin Rabenseifner)
            let m = 1 << 22;
            let (alg, t) = select_allreduce(&c, p, m);
            let circ = closed_form::alg2_allreduce(&c, p, m);
            assert!(
                matches!(alg, Algorithm::CirculantAllreduce(_)) || (t - circ).abs() < 1e-9,
                "p={p}: {} at {t}, alg2 {circ}",
                alg.name()
            );
            // always at least as good as the ring
            for m in [1usize, 1 << 10, 1 << 22] {
                assert!(
                    closed_form::alg2_allreduce(&c, p, m)
                        <= closed_form::ring_allreduce(&c, p, m) + 1e-12,
                    "p={p} m={m}"
                );
            }
        }
        // tiny m at large p: a ⌈log2 p⌉-round algorithm wins the α game
        let (alg, _) = select_allreduce(&CostModel::latency_bound(), 1000, 1);
        assert!(
            matches!(alg, Algorithm::RecursiveDoublingAllreduce | Algorithm::BinomialAllreduce),
            "expected a q-round algorithm for m=1, got {}",
            alg.name()
        );
    }

    #[test]
    fn predictions_are_positive_and_monotone_in_m() {
        let c = CostModel::cluster();
        let (_, t1) = select_allreduce(&c, 64, 1 << 10);
        let (_, t2) = select_allreduce(&c, 64, 1 << 20);
        assert!(0.0 < t1 && t1 < t2);
    }
}
