//! Metrics registry: per-rank counters aggregated by the launcher, dumped
//! as a table or JSON by the CLI.

use std::collections::BTreeMap;

use crate::transport::Counters;
use crate::util::json::Json;
use crate::util::table::Table;

/// Aggregated run metrics (one entry per rank plus wall-clock).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub algorithm: String,
    /// Element type the collective ran over (`run.dtype`).
    pub dtype: String,
    pub p: usize,
    pub m: usize,
    pub wall_seconds: f64,
    pub per_rank: Vec<Counters>,
}

impl RunMetrics {
    /// Max blocks/elements over ranks (the bound Theorems 1/2 state is
    /// per-processor, so the max is what must match).
    pub fn max_elems_sent(&self) -> u64 {
        self.per_rank.iter().map(|c| c.elems_sent).max().unwrap_or(0)
    }

    pub fn max_msgs_sent(&self) -> u64 {
        self.per_rank.iter().map(|c| c.msgs_sent).max().unwrap_or(0)
    }

    pub fn total_elems_sent(&self) -> u64 {
        self.per_rank.iter().map(|c| c.elems_sent).sum()
    }

    /// Rounds = max sendrecv invocations on any rank.
    pub fn rounds(&self) -> u64 {
        self.per_rank.iter().map(|c| c.sendrecv_rounds).max().unwrap_or(0)
    }

    /// Plan-cache hits across ranks (schedules served memoized — see
    /// `crate::schedule::PlanCache`).
    pub fn plan_hits(&self) -> u64 {
        self.per_rank.iter().map(|c| c.plan_hits).sum()
    }

    /// Plan-cache misses across ranks (schedules generated fresh).
    pub fn plan_misses(&self) -> u64 {
        self.per_rank.iter().map(|c| c.plan_misses).sum()
    }

    /// Aggregate throughput in elements moved per second (whole job).
    pub fn elems_per_second(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.total_elems_sent() as f64 / self.wall_seconds
    }

    /// Render as a one-row summary table.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            "run metrics",
            &["algorithm", "dtype", "p", "m", "rounds", "max elems/rank", "wall s", "elems/s"],
        );
        t.row(&[
            self.algorithm.clone(),
            self.dtype.clone(),
            self.p.to_string(),
            self.m.to_string(),
            self.rounds().to_string(),
            self.max_elems_sent().to_string(),
            format!("{:.6}", self.wall_seconds),
            crate::util::table::fmt_si(self.elems_per_second()),
        ]);
        t
    }

    /// JSON dump (for machine-readable bench logs).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("algorithm".into(), Json::Str(self.algorithm.clone()));
        obj.insert("dtype".into(), Json::Str(self.dtype.clone()));
        obj.insert("p".into(), Json::Num(self.p as f64));
        obj.insert("m".into(), Json::Num(self.m as f64));
        obj.insert("wall_seconds".into(), Json::Num(self.wall_seconds));
        obj.insert("rounds".into(), Json::Num(self.rounds() as f64));
        obj.insert("plan_hits".into(), Json::Num(self.plan_hits() as f64));
        obj.insert("plan_misses".into(), Json::Num(self.plan_misses() as f64));
        obj.insert(
            "per_rank_elems_sent".into(),
            Json::Arr(self.per_rank.iter().map(|c| Json::Num(c.elems_sent as f64)).collect()),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake() -> RunMetrics {
        RunMetrics {
            algorithm: "test".into(),
            dtype: "f32".into(),
            p: 2,
            m: 8,
            wall_seconds: 0.5,
            per_rank: vec![
                Counters {
                    sendrecv_rounds: 3,
                    msgs_sent: 3,
                    msgs_recv: 3,
                    elems_sent: 12,
                    elems_recv: 12,
                    ..Counters::default()
                },
                Counters {
                    sendrecv_rounds: 3,
                    msgs_sent: 2,
                    msgs_recv: 2,
                    elems_sent: 10,
                    elems_recv: 10,
                    ..Counters::default()
                },
            ],
        }
    }

    #[test]
    fn aggregations() {
        let m = fake();
        assert_eq!(m.max_elems_sent(), 12);
        assert_eq!(m.total_elems_sent(), 22);
        assert_eq!(m.rounds(), 3);
        assert_eq!(m.elems_per_second(), 44.0);
    }

    #[test]
    fn json_has_fields() {
        let j = fake().to_json();
        assert_eq!(j.req("p").as_usize(), Some(2));
        assert_eq!(j.req("dtype").as_str(), Some("f32"));
        assert_eq!(j.req("per_rank_elems_sent").as_arr().unwrap().len(), 2);
        assert_eq!(j.req("plan_hits").as_usize(), Some(0));
        assert_eq!(j.req("plan_misses").as_usize(), Some(0));
    }

    #[test]
    fn plan_counters_aggregate_across_ranks() {
        let mut m = fake();
        m.per_rank[0].plan_hits = 3;
        m.per_rank[0].plan_misses = 1;
        m.per_rank[1].plan_hits = 2;
        assert_eq!(m.plan_hits(), 5);
        assert_eq!(m.plan_misses(), 1);
        assert_eq!(m.to_json().req("plan_hits").as_usize(), Some(5));
    }
}
