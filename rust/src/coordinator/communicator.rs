//! The MPI-like communicator: the library's user-facing API, generic over
//! the element type.
//!
//! A [`Communicator<T>`] wraps one rank's endpoint plus the collective
//! configuration (skip scheme, ⊕ backend) and exposes the operations the
//! paper targets: `MPI_Reduce_scatter_block`, `MPI_Reduce_scatter`,
//! `MPI_Allreduce` (§3), plus the §4 derivations (`allgather`, `alltoall`,
//! `reduce`, `bcast`) and a `barrier`. The element type defaults to `f32`
//! (the pre-dtype API); [`Launcher::run_typed`] spawns communicators over
//! any [`Elem`] dtype — `run.dtype` on the CLI. Native ops serve every
//! dtype; the PJRT backend is f32-only (its AOT kernels are compiled for
//! f32) and reports unsupported dtypes as [`CollectiveError::UnknownOp`].
//!
//! Round tags advance monotonically per communicator, so collectives can
//! be issued back-to-back without cross-talk (the transport stashes
//! out-of-order arrivals by `(peer, tag)`). All communicator traffic runs
//! in op-epoch 0 of the transport's tag space; *concurrent* collectives
//! (several in flight at once) belong to [`crate::engine`], which
//! allocates a fresh epoch per operation.
//!
//! Schedules are resolved through a [`PlanCache`] (shared across all
//! ranks of a [`Launcher`] job): repeated collectives with the same
//! `(algorithm, p, partition, dtype)` reuse one built `Arc<Schedule>`
//! instead of regenerating it per call and per rank. Cache hits/misses
//! appear in each rank's transport counters (`plan_hits`/`plan_misses`)
//! and therefore in [`crate::coordinator::RunMetrics`].
//!
//! Buffer discipline: operations that cannot run in place on the caller's
//! buffers (reduce-scatter staging, scatter/gather assembly) stage through
//! one persistent per-communicator working vector — steady-state calls
//! reuse its capacity instead of allocating, matching the transport's
//! pooled zero-copy payload protocol.
//!
//! Communicators enable the transport's zero-copy **rendezvous** tier by
//! default (see the three-tier copy discipline in `crate::transport`):
//! rounds whose send/recv block ranges are disjoint and whose payloads
//! clear the small-message threshold
//! (`transport::DEFAULT_RENDEZVOUS_MIN_ELEMS` elements, tunable via
//! `CCOLL_RENDEZVOUS_MIN_ELEMS`) move payloads without any copy, and the
//! rest fall back to the pooled tier automatically. Opt out per
//! communicator with [`Communicator::set_rendezvous`], per launcher with
//! [`Launcher::rendezvous`], or process-wide with `CCOLL_NO_RENDEZVOUS`.

use std::sync::Arc;

use crate::collectives::alltoall::{alltoall_rank, receive_partition};
use crate::collectives::exec::{execute_rank, CollectiveError};
use crate::collectives::generators::{
    allgather_schedule, allreduce_schedule, reduce_scatter_schedule,
};
use crate::collectives::{Algorithm, CirculantPlans};
use crate::datatypes::{BlockPartition, Elem};
use crate::engine::{CollectiveEngine, EngineConfig};
use crate::ops::ReduceOp;
use crate::schedule::{Plan, PlanCache, PlanKey, Schedule};
use crate::topology::skips::SkipScheme;
use crate::transport::{Counters, Endpoint};

/// The three circulant schedule families a communicator plans for.
#[derive(Clone, Copy)]
enum CirculantFamily {
    Allreduce,
    ReduceScatter,
    Allgather,
}

/// Which ⊕ implementation executes the γ term.
#[derive(Clone)]
pub enum OpBackend {
    /// Native Rust loops (`crate::ops::native`) — every dtype.
    Native,
    /// The AOT Pallas kernel through the PJRT compute service — f32 only.
    Pjrt(crate::runtime::ServiceHandle),
}

impl OpBackend {
    /// Resolve an operator name to a boxed ⊕ for this backend and dtype.
    /// Returns `None` for unknown names and for `(backend, dtype)` pairs
    /// the backend cannot serve (PJRT × non-f32).
    pub fn resolve<T: Elem>(&self, op: &str) -> Option<Box<dyn ReduceOp<T>>> {
        match self {
            OpBackend::Native => crate::ops::parse_native_typed::<T>(op),
            OpBackend::Pjrt(handle) => T::service_op(handle.clone(), op),
        }
    }
}

/// One rank's communicator over element type `T` (default `f32`).
pub struct Communicator<T: Elem = f32> {
    ep: Endpoint<T>,
    scheme: SkipScheme,
    /// Precomputed circulant plan vocabulary (canonical names + validated
    /// skip sequence) for this `(scheme, p)` — shared derivation with the
    /// engine ([`CirculantPlans`]), so no collective call re-derives
    /// either and the two entry points key one plan space.
    vocab: CirculantPlans,
    backend: OpBackend,
    tag: u64,
    /// Persistent staging buffer for out-of-place collectives; capacity is
    /// retained across calls so steady-state traffic never allocates.
    work: Vec<T>,
    /// Memoized `(algorithm, p, partition, dtype) → plan` — repeated
    /// collectives on this communicator regenerate nothing. Private per
    /// communicator by default; [`Launcher`] shares one across all ranks
    /// (and with the engine when one is involved), so a plan is built
    /// once per *job*, not once per rank. Hits/misses are mirrored into
    /// this rank's transport counters (`plan_hits`/`plan_misses`).
    plans: Arc<PlanCache>,
}

impl<T: Elem> Communicator<T> {
    pub fn new(mut ep: Endpoint<T>, scheme: SkipScheme, backend: OpBackend) -> Self {
        // Default to the zero-copy hot path; the executor still falls back
        // to the pooled tier per round whenever the schedule's send/recv
        // ranges overlap (`CCOLL_NO_RENDEZVOUS=1` disables globally).
        ep.rendezvous = crate::transport::rendezvous_env_enabled();
        let vocab = CirculantPlans::new(&scheme, ep.p);
        Self {
            vocab,
            ep,
            scheme,
            backend,
            tag: 0,
            work: Vec::new(),
            plans: Arc::new(PlanCache::new()),
        }
    }

    /// Enable/disable the transport's zero-copy rendezvous tier for this
    /// communicator (on by default; see the module docs).
    pub fn set_rendezvous(&mut self, enabled: bool) {
        self.ep.rendezvous = enabled && crate::transport::rendezvous_env_enabled();
    }

    /// Replace this communicator's plan cache with a shared one (what the
    /// launcher/engine do so all ranks reuse one set of built plans).
    pub fn set_plan_cache(&mut self, plans: Arc<PlanCache>) {
        self.plans = plans;
    }

    /// This communicator's plan cache.
    pub fn plan_cache(&self) -> Arc<PlanCache> {
        self.plans.clone()
    }

    /// Resolve `(algorithm, partition)` through the plan cache, building
    /// via `build` on a miss, and mirror the outcome into this rank's
    /// transport counters.
    fn plan_with(
        &mut self,
        algorithm: Arc<str>,
        part: &BlockPartition,
        build: impl FnOnce() -> Schedule,
    ) -> Arc<Plan> {
        let key = PlanKey::new(algorithm, self.ep.p, part, T::DTYPE);
        let (plan, hit) = self.plans.get_or_build(key, part, build);
        if hit {
            self.ep.counters.plan_hits += 1;
        } else {
            self.ep.counters.plan_misses += 1;
        }
        plan
    }

    /// [`plan_with`](Self::plan_with) for the three circulant families:
    /// keys with the precomputed name (refcount bump, no allocation) and
    /// builds — only on a miss — from the cached skip sequence, so a
    /// cache-hit collective does no per-call derivation work at all.
    fn circulant_plan(&mut self, family: CirculantFamily, part: &BlockPartition) -> Arc<Plan> {
        let (name, gen): (Arc<str>, fn(usize, &[usize]) -> Schedule) = match family {
            CirculantFamily::Allreduce => (self.vocab.allreduce.clone(), allreduce_schedule),
            CirculantFamily::ReduceScatter => {
                (self.vocab.reduce_scatter.clone(), reduce_scatter_schedule)
            }
            CirculantFamily::Allgather => (self.vocab.allgather.clone(), allgather_schedule),
        };
        let p = self.ep.p;
        let skips = self.vocab.skips.clone();
        self.plan_with(name, part, move || gen(p, &skips))
    }

    /// Stage `src` into the working buffer (reusing its capacity).
    fn stage(&mut self, src: &[T]) {
        self.work.clear();
        self.work.extend_from_slice(src);
    }

    /// Resize the working buffer to `n` zeros (reusing its capacity).
    fn stage_zeros(&mut self, n: usize) {
        self.work.clear();
        self.work.resize(n, T::zero());
    }

    pub fn rank(&self) -> usize {
        self.ep.rank
    }

    pub fn size(&self) -> usize {
        self.ep.p
    }

    /// Transport counters accumulated so far (Theorem 1/2 measurements).
    pub fn counters(&self) -> Counters {
        self.ep.counters.clone()
    }

    /// This communicator's skip scheme.
    pub fn scheme(&self) -> &SkipScheme {
        &self.scheme
    }

    /// The cached skip sequence of this communicator's `(scheme, p)`.
    pub fn skips(&self) -> &[usize] {
        &self.vocab.skips
    }

    fn op(&self, op: &str) -> Result<Box<dyn ReduceOp<T>>, CollectiveError> {
        self.backend.resolve::<T>(op).ok_or_else(|| CollectiveError::UnknownOp {
            rank: self.ep.rank,
            name: op.to_string(),
            dtype: T::DTYPE.name(),
        })
    }

    /// Run a schedule with this communicator's tag discipline: the tag
    /// window for all of the schedule's rounds is reserved *before*
    /// execution, so a collective that errors midway can never leak its
    /// round tags into a retry — stale rendezvous acks or stashed
    /// payloads keyed by `(peer, round)` from the aborted collective
    /// would otherwise match the new one's rounds.
    fn run_exec(
        &mut self,
        sched: &crate::schedule::Schedule,
        part: &BlockPartition,
        op: &dyn ReduceOp<T>,
        buf: &mut [T],
    ) -> Result<(), CollectiveError> {
        let base = self.tag;
        self.tag += sched.rounds.len() as u64;
        execute_rank(&mut self.ep, sched, part, op, buf, base).map(|_| ())
    }

    /// [`run_exec`](Self::run_exec) on the persistent staging buffer: the
    /// buffer is lent out for the duration of execution and restored
    /// afterwards in one place, so its capacity survives every call path
    /// (the zero-steady-state-allocation property) and later
    /// `self.work[..]` reads always see the executed data.
    fn run_exec_on_work(
        &mut self,
        sched: &crate::schedule::Schedule,
        part: &BlockPartition,
        op: &dyn ReduceOp<T>,
    ) -> Result<(), CollectiveError> {
        let mut work = std::mem::take(&mut self.work);
        let res = self.run_exec(sched, part, op, &mut work);
        self.work = work;
        res
    }

    /// MPI_Reduce_scatter_block: every rank contributes `sendbuf`
    /// (`p·b` elements); `recvbuf` (`b` elements) receives block `rank` of
    /// the reduction. Algorithm 1 with this communicator's skip scheme.
    pub fn reduce_scatter_block(
        &mut self,
        sendbuf: &[T],
        recvbuf: &mut [T],
        op: &str,
    ) -> Result<(), CollectiveError> {
        let p = self.size();
        let b = recvbuf.len();
        if sendbuf.len() != p * b {
            return Err(CollectiveError::BadBuffer {
                rank: self.rank(),
                got: sendbuf.len(),
                want: p * b,
            });
        }
        let part = BlockPartition::uniform(p, b);
        let plan = self.circulant_plan(CirculantFamily::ReduceScatter, &part);
        let op = self.op(op)?;
        self.stage(sendbuf);
        self.run_exec_on_work(&plan.schedule, &plan.part, op.as_ref())?;
        recvbuf.copy_from_slice(&self.work[part.range(self.ep.rank)]);
        Ok(())
    }

    /// MPI_Reduce_scatter: per-block counts may differ (Corollary 3).
    /// `recvbuf` must have `counts[rank]` elements.
    pub fn reduce_scatter(
        &mut self,
        sendbuf: &[T],
        counts: &[usize],
        recvbuf: &mut [T],
        op: &str,
    ) -> Result<(), CollectiveError> {
        let p = self.size();
        if counts.len() != p {
            return Err(CollectiveError::BadBuffer { rank: self.rank(), got: counts.len(), want: p });
        }
        let part = BlockPartition::from_counts(counts);
        if sendbuf.len() != part.total() || recvbuf.len() != part.size(self.rank()) {
            return Err(CollectiveError::BadBuffer {
                rank: self.rank(),
                got: sendbuf.len(),
                want: part.total(),
            });
        }
        let plan = self.circulant_plan(CirculantFamily::ReduceScatter, &part);
        let op = self.op(op)?;
        self.stage(sendbuf);
        self.run_exec_on_work(&plan.schedule, &plan.part, op.as_ref())?;
        recvbuf.copy_from_slice(&self.work[part.range(self.ep.rank)]);
        Ok(())
    }

    /// MPI_Allreduce (in place): Algorithm 2. `buf` is both input and
    /// output (`m` elements, any `m ≥ 0`; blocks are split as evenly as
    /// possible).
    pub fn allreduce(&mut self, buf: &mut [T], op: &str) -> Result<(), CollectiveError> {
        let p = self.size();
        let part = BlockPartition::regular(p, buf.len());
        let plan = self.circulant_plan(CirculantFamily::Allreduce, &part);
        let op = self.op(op)?;
        self.run_exec(&plan.schedule, &plan.part, op.as_ref(), buf)?;
        Ok(())
    }

    /// MPI_Allgather: `sendblock` (this rank's contribution) is gathered
    /// into `recvbuf` (`p · sendblock.len()` elements, rank order).
    pub fn allgather(&mut self, sendblock: &[T], recvbuf: &mut [T]) -> Result<(), CollectiveError> {
        let p = self.size();
        let b = sendblock.len();
        if recvbuf.len() != p * b {
            return Err(CollectiveError::BadBuffer {
                rank: self.rank(),
                got: recvbuf.len(),
                want: p * b,
            });
        }
        let part = BlockPartition::uniform(p, b);
        recvbuf[part.range(self.rank())].copy_from_slice(sendblock);
        let plan = self.circulant_plan(CirculantFamily::Allgather, &part);
        // allgather performs no ⊕; use native sum as a placeholder operator
        let op = crate::ops::SumOp;
        self.run_exec(&plan.schedule, &plan.part, &op, recvbuf)?;
        Ok(())
    }

    /// MPI_Alltoall (regular): block `g` of `sendbuf` goes to rank `g`;
    /// returns the received row (block `g` from rank `g`). §4's
    /// concatenation reduce-scatter in `⌈log2 p⌉` rounds.
    pub fn alltoall(&mut self, sendbuf: &[T], block: usize) -> Result<Vec<T>, CollectiveError> {
        let p = self.size();
        let part = BlockPartition::uniform(p, block);
        // Reserve the tag window before executing (see run_exec).
        let base = self.tag;
        self.tag += self.vocab.skips.len() as u64;
        let out = alltoall_rank(&mut self.ep, &part, &self.vocab.skips, sendbuf, base)?;
        debug_assert_eq!(out.len(), receive_partition(&part, self.rank()).total());
        Ok(out)
    }

    /// MPI_Alltoallv: irregular all-to-all. `send_counts[g]` elements of
    /// `sendbuf` (concatenated rank order) go to rank `g`; the return
    /// value concatenates `recv_counts[g]` elements from each rank `g`.
    pub fn alltoallv(
        &mut self,
        sendbuf: &[T],
        send_counts: &[usize],
        recv_counts: &[usize],
    ) -> Result<Vec<T>, CollectiveError> {
        // Reserve the tag window before executing (see run_exec).
        let base = self.tag;
        self.tag += self.vocab.skips.len() as u64;
        let out = crate::collectives::alltoall::alltoallv_rank(
            &mut self.ep,
            send_counts,
            recv_counts,
            &self.vocab.skips,
            sendbuf,
            base,
        )?;
        Ok(out)
    }

    /// MPI_Reduce: full vector reduced to `root` (Corollary 3's degenerate
    /// single-block partition; attractive for small `m`).
    pub fn reduce(&mut self, buf: &mut [T], root: usize, op: &str) -> Result<(), CollectiveError> {
        let p = self.size();
        let part = BlockPartition::single_block(p, buf.len(), root);
        let plan = self.circulant_plan(CirculantFamily::ReduceScatter, &part);
        let op = self.op(op)?;
        self.run_exec(&plan.schedule, &plan.part, op.as_ref(), buf)?;
        Ok(())
    }

    /// MPI_Bcast from `root` (mirrored allgather on the degenerate
    /// partition).
    pub fn bcast(&mut self, buf: &mut [T], root: usize) -> Result<(), CollectiveError> {
        let p = self.size();
        let part = BlockPartition::single_block(p, buf.len(), root);
        let plan = self.circulant_plan(CirculantFamily::Allgather, &part);
        let op = crate::ops::SumOp;
        self.run_exec(&plan.schedule, &plan.part, &op, buf)?;
        Ok(())
    }

    /// MPI_Scatter: block `g` of `root`'s `sendbuf` (`p·b` elements) lands
    /// in `recvbuf` (`b` elements) at rank `g`. Binomial block tree
    /// (§4's rooted specialization), `⌈log2 p⌉` rounds.
    pub fn scatter(
        &mut self,
        sendbuf: Option<&[T]>,
        recvbuf: &mut [T],
        root: usize,
    ) -> Result<(), CollectiveError> {
        let p = self.size();
        let b = recvbuf.len();
        let part = BlockPartition::uniform(p, b);
        if self.rank() == root {
            let send = sendbuf.ok_or(CollectiveError::BadBuffer {
                rank: root,
                got: 0,
                want: part.total(),
            })?;
            if send.len() != part.total() {
                return Err(CollectiveError::BadBuffer {
                    rank: root,
                    got: send.len(),
                    want: part.total(),
                });
            }
            self.stage(send);
        } else {
            self.stage_zeros(part.total());
        }
        let plan = self.plan_with(format!("binomial-scatter:{root}").into(), &part, || {
            crate::collectives::baselines::binomial_scatter_schedule(p, root)
        });
        let op = crate::ops::SumOp;
        self.run_exec_on_work(&plan.schedule, &plan.part, &op)?;
        recvbuf.copy_from_slice(&self.work[part.range(self.ep.rank)]);
        Ok(())
    }

    /// MPI_Gather: every rank's `sendblock` (`b` elements) is collected in
    /// rank order into `recvbuf` (`p·b`, significant at `root` only).
    pub fn gather(
        &mut self,
        sendblock: &[T],
        recvbuf: Option<&mut [T]>,
        root: usize,
    ) -> Result<(), CollectiveError> {
        let p = self.size();
        let b = sendblock.len();
        let part = BlockPartition::uniform(p, b);
        self.stage_zeros(part.total());
        let range = part.range(self.rank());
        self.work[range].copy_from_slice(sendblock);
        let plan = self.plan_with(format!("binomial-gather:{root}").into(), &part, || {
            crate::collectives::baselines::binomial_gather_schedule(p, root)
        });
        let op = crate::ops::SumOp;
        self.run_exec_on_work(&plan.schedule, &plan.part, &op)?;
        if self.rank() == root {
            let out = recvbuf.ok_or(CollectiveError::BadBuffer {
                rank: root,
                got: 0,
                want: part.total(),
            })?;
            if out.len() != part.total() {
                return Err(CollectiveError::BadBuffer {
                    rank: root,
                    got: out.len(),
                    want: part.total(),
                });
            }
            out.copy_from_slice(&self.work);
        }
        Ok(())
    }

    /// Barrier: a zero-payload allreduce round trip.
    pub fn barrier(&mut self) -> Result<(), CollectiveError> {
        let mut empty: [T; 0] = [];
        // p blocks of 0 elements still walk the full schedule (all payloads
        // empty), synchronizing every rank with every other transitively.
        self.allreduce(&mut empty, "sum")
    }

    /// Run an arbitrary prebuilt schedule (expert API used by benches).
    pub fn run_schedule(
        &mut self,
        sched: &crate::schedule::Schedule,
        part: &BlockPartition,
        op: &str,
        buf: &mut [T],
    ) -> Result<(), CollectiveError> {
        let op = self.op(op)?;
        self.run_exec(sched, part, op.as_ref(), buf)?;
        Ok(())
    }
}

/// Launcher: the in-process stand-in for `mpiexec`, for **one-shot** jobs
/// — spawn, run `f(comm)` on every rank, join. Built on the persistent
/// engine's worker substrate: [`Launcher::run`] spawns a
/// [`CollectiveEngine`], runs the closure on its workers (each rank's
/// communicator sharing the engine's plan cache, so a schedule is built
/// once per job rather than once per rank), and shuts the engine down.
/// For *repeated* collectives, skip the wrapper and hold an engine
/// directly ([`Launcher::engine`] / [`Launcher::engine_typed`]): spawn
/// once, [`submit`](CollectiveEngine::submit) many — the `t8_engine`
/// bench measures the per-op amortization.
pub struct Launcher {
    pub p: usize,
    pub scheme: SkipScheme,
    pub backend: OpBackend,
    pub rendezvous: bool,
    /// Enable the engine's fusion tier on engines handed out by
    /// [`Launcher::engine`] (coalesce compatible small in-flight ops into
    /// one fused run — see `crate::engine::fusion`). Off by default; the
    /// one-shot `run`/`run_typed` paths never batch (their closures issue
    /// blocking collectives, not engine submissions).
    pub fusion: bool,
}

impl Launcher {
    pub fn new(p: usize) -> Self {
        Self {
            p,
            scheme: SkipScheme::HalvingUp,
            backend: OpBackend::Native,
            rendezvous: true,
            fusion: false,
        }
    }

    pub fn scheme(mut self, scheme: SkipScheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn backend(mut self, backend: OpBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Enable/disable the zero-copy rendezvous tier for every spawned
    /// communicator (on by default).
    pub fn rendezvous(mut self, enabled: bool) -> Self {
        self.rendezvous = enabled;
        self
    }

    /// Enable the fusion tier on engines from [`Launcher::engine`] /
    /// [`Launcher::engine_typed`] (off by default).
    pub fn fusion(mut self, enabled: bool) -> Self {
        self.fusion = enabled;
        self
    }

    /// Run `f(comm)` on every rank over **f32** communicators; returns
    /// per-rank results in rank order. See [`run_typed`](Launcher::run_typed)
    /// for other dtypes.
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Communicator) -> T + Send + Sync + 'static,
    {
        self.run_typed::<f32, T, F>(f)
    }

    /// A persistent [`CollectiveEngine`] with this launcher's
    /// configuration (f32). Spawn once, submit many; see the engine docs.
    pub fn engine(&self) -> CollectiveEngine {
        self.engine_typed::<f32>()
    }

    /// [`engine`](Launcher::engine) over any element type.
    pub fn engine_typed<E: Elem>(&self) -> CollectiveEngine<E> {
        CollectiveEngine::new(
            EngineConfig::new(self.p)
                .scheme(self.scheme.clone())
                .backend(self.backend.clone())
                .rendezvous(self.rendezvous)
                .fusion(self.fusion),
        )
    }

    /// Run `f(comm)` on every rank over communicators of element type `E`.
    ///
    /// Thin wrapper over the engine substrate: spawns an engine, runs the
    /// closure once on every worker (all rank communicators share the
    /// engine's plan cache), and shuts the engine down — one-shot
    /// semantics, persistent machinery.
    pub fn run_typed<E: Elem, T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Communicator<E>) -> T + Send + Sync + 'static,
    {
        let scheme = self.scheme.clone();
        let backend = self.backend.clone();
        let rendezvous = self.rendezvous;
        let mut engine = self.engine_typed::<E>();
        let plans = engine.plan_cache();
        let out = engine.run_closure(move |_rank, ep| {
            // The worker lends us &mut (remapped) Endpoint; move a
            // Communicator around an owned endpoint instead (the engine
            // is shut down right after, so the worker never touches the
            // placeholder).
            let owned = std::mem::replace(
                ep,
                // placeholder endpoint; never used after the swap
                crate::transport::Remap::new(crate::transport::network_typed::<E>(1).pop().unwrap()),
            );
            let mut comm =
                Communicator::<E>::new(owned.into_inner(), scheme.clone(), backend.clone());
            comm.set_plan_cache(plans.clone());
            comm.set_rendezvous(rendezvous);
            f(comm)
        });
        engine.shutdown();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_scatter_block_api() {
        let p = 6;
        let b = 4;
        let out = Launcher::new(p).run(move |mut comm| {
            let send: Vec<f32> = (0..p * b).map(|j| (comm.rank() * 100 + j) as f32).collect();
            let mut recv = vec![0.0f32; b];
            comm.reduce_scatter_block(&send, &mut recv, "sum").unwrap();
            recv
        });
        for (r, got) in out.iter().enumerate() {
            for j in 0..b {
                let want: f32 = (0..p).map(|src| (src * 100 + r * b + j) as f32).sum();
                assert_eq!(got[j], want, "r={r} j={j}");
            }
        }
    }

    #[test]
    fn allreduce_api_and_back_to_back_ops() {
        let p = 5;
        let m = 13;
        let out = Launcher::new(p).run(move |mut comm| {
            let mut buf: Vec<f32> = (0..m).map(|j| (comm.rank() + j) as f32).collect();
            comm.allreduce(&mut buf, "sum").unwrap();
            // immediately chain a second collective on the same comm
            let mut mx: Vec<f32> = vec![comm.rank() as f32];
            comm.allreduce(&mut mx, "max").unwrap();
            (buf, mx[0])
        });
        for (buf, mx) in &out {
            for j in 0..m {
                let want: f32 = (0..p).map(|r| (r + j) as f32).sum();
                assert_eq!(buf[j], want);
            }
            assert_eq!(*mx, (p - 1) as f32);
        }
    }

    #[test]
    fn typed_launcher_runs_i64_and_u64_communicators() {
        let p = 4;
        let m = 9;
        let out = Launcher::new(p).run_typed::<i64, _, _>(move |mut comm| {
            let mut buf: Vec<i64> = (0..m).map(|j| comm.rank() as i64 - j).collect();
            comm.allreduce(&mut buf, "sum").unwrap();
            buf
        });
        for buf in &out {
            for j in 0..m as usize {
                let want: i64 = (0..p as i64).map(|r| r - j as i64).sum();
                assert_eq!(buf[j], want);
            }
        }
        let out = Launcher::new(p).run_typed::<u64, _, _>(move |mut comm| {
            let mut buf: Vec<u64> = vec![comm.rank() as u64 + 1; 5];
            comm.allreduce(&mut buf, "prod").unwrap();
            buf
        });
        let want: u64 = (1..=p as u64).product();
        for buf in &out {
            assert!(buf.iter().all(|&x| x == want));
        }
    }

    #[test]
    fn unknown_op_is_a_typed_error() {
        let out = Launcher::new(2).run(move |mut comm| {
            let mut buf = vec![0.0f32; 4];
            match comm.allreduce(&mut buf, "xor") {
                Err(CollectiveError::UnknownOp { name, dtype, .. }) => {
                    name == "xor" && dtype == "f32"
                }
                _ => false,
            }
        });
        assert!(out.iter().all(|&ok| ok), "unknown op must surface as UnknownOp");
    }

    #[test]
    fn reduce_and_bcast() {
        let p = 7;
        let m = 9;
        let out = Launcher::new(p).run(move |mut comm| {
            let mut buf: Vec<f32> = vec![1.0; m];
            comm.reduce(&mut buf, 2, "sum").unwrap();
            let at_root = buf.clone();
            // root rescales, then broadcasts
            if comm.rank() == 2 {
                for x in buf.iter_mut() {
                    *x *= 10.0;
                }
            }
            comm.bcast(&mut buf, 2).unwrap();
            (at_root, buf)
        });
        assert!(out[2].0.iter().all(|&x| x == p as f32));
        for (_, bcasted) in &out {
            assert!(bcasted.iter().all(|&x| x == 10.0 * p as f32));
        }
    }

    #[test]
    fn alltoall_api() {
        let p = 4;
        let b = 2;
        let out = Launcher::new(p).run(move |mut comm| {
            let send: Vec<f32> =
                (0..p * b).map(|j| (comm.rank() * 1000 + j) as f32).collect();
            comm.alltoall(&send, b).unwrap()
        });
        for r in 0..p {
            for g in 0..p {
                for j in 0..b {
                    assert_eq!(out[r][g * b + j], (g * 1000 + r * b + j) as f32);
                }
            }
        }
    }

    #[test]
    fn allgather_and_barrier() {
        let p = 5;
        let out = Launcher::new(p).run(move |mut comm| {
            comm.barrier().unwrap();
            let mine = vec![comm.rank() as f32; 3];
            let mut all = vec![0.0f32; 3 * p];
            comm.allgather(&mine, &mut all).unwrap();
            comm.barrier().unwrap();
            all
        });
        for buf in &out {
            for r in 0..p {
                assert!(buf[3 * r..3 * (r + 1)].iter().all(|&x| x == r as f32));
            }
        }
    }

    #[test]
    fn scatter_and_gather_roundtrip() {
        let p = 7;
        let b = 3;
        let root = 2;
        let out = Launcher::new(p).run(move |mut comm| {
            // root scatters j+1 values; everyone gets its block…
            let send: Option<Vec<f32>> = (comm.rank() == root)
                .then(|| (0..p * b).map(|j| j as f32 + 1.0).collect());
            let mut mine = vec![0.0f32; b];
            comm.scatter(send.as_deref(), &mut mine, root).unwrap();
            // …transforms it…
            for x in mine.iter_mut() {
                *x *= 2.0;
            }
            // …and gathers back.
            let mut all = (comm.rank() == root).then(|| vec![0.0f32; p * b]);
            comm.gather(&mine, all.as_deref_mut(), root).unwrap();
            (mine, all)
        });
        for (r, (mine, _)) in out.iter().enumerate() {
            for i in 0..b {
                assert_eq!(mine[i], 2.0 * ((r * b + i) as f32 + 1.0), "scatter r={r}");
            }
        }
        let all = out[root].1.as_ref().unwrap();
        for j in 0..p * b {
            assert_eq!(all[j], 2.0 * (j as f32 + 1.0), "gather j={j}");
        }
    }

    #[test]
    fn repeated_collectives_hit_the_plan_cache() {
        let p = 4;
        let m = 24;
        let out = Launcher::new(p).run(move |mut comm| {
            let mut buf = vec![1.0f32; m];
            comm.allreduce(&mut buf, "sum").unwrap();
            comm.allreduce(&mut buf, "sum").unwrap(); // same plan again
            let mut small = vec![1.0f32; m / 2]; // different partition
            comm.allreduce(&mut small, "sum").unwrap();
            (buf[0], comm.counters())
        });
        for (rank, (x, c)) in out.iter().enumerate() {
            assert_eq!(*x, (p * p) as f32, "rank {rank}: double allreduce of ones");
            assert_eq!(c.plan_hits + c.plan_misses, 3, "rank {rank}: three plan lookups");
            // The second identical call is always a hit; the first and the
            // resized call may hit or miss per rank depending on who built
            // first (the cache is shared across ranks).
            assert!(c.plan_hits >= 1, "rank {rank}: repeated plan must hit");
            assert!(c.plan_misses <= 2, "rank {rank}: only two distinct plans exist");
        }
    }

    #[test]
    fn launcher_engine_serves_the_same_results_as_run() {
        use crate::engine::OpRequest;
        let p = 3;
        let m = 17;
        let inputs: Vec<Vec<f32>> =
            (0..p).map(|r| (0..m).map(|j| (r + j) as f32).collect()).collect();
        let want: Vec<f32> =
            (0..m).map(|j| (0..p).map(|r| (r + j) as f32).sum()).collect();
        let mut engine = Launcher::new(p).engine();
        for _ in 0..3 {
            let out =
                engine.submit(OpRequest::allreduce(inputs.clone(), "sum")).unwrap().wait().unwrap();
            for buf in &out {
                assert_eq!(buf, &want);
            }
        }
        assert!(engine.plan_stats().hits >= 2, "repeated submits reuse the plan");
        engine.shutdown();
    }

    #[test]
    fn irregular_reduce_scatter_api() {
        let p = 4;
        let counts = vec![1usize, 0, 5, 2];
        let counts2 = counts.clone();
        let out = Launcher::new(p).run(move |mut comm| {
            let total: usize = counts2.iter().sum();
            let send: Vec<f32> = (0..total).map(|j| (comm.rank() + j) as f32).collect();
            let mut recv = vec![0.0f32; counts2[comm.rank()]];
            comm.reduce_scatter(&send, &counts2, &mut recv, "sum").unwrap();
            recv
        });
        let part = BlockPartition::from_counts(&counts);
        for (r, got) in out.iter().enumerate() {
            for (i, j) in part.range(r).enumerate() {
                let want: f32 = (0..p).map(|src| (src + j) as f32).sum();
                assert_eq!(got[i], want, "r={r} i={i}");
            }
        }
    }
}
