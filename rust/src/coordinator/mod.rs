//! Layer-3 coordinator: communicator API, launcher, metrics, and the
//! algorithm selector.

pub mod communicator;
pub mod metrics;
pub mod selector;
pub mod train;

pub use communicator::{Communicator, Launcher, OpBackend};
pub use metrics::RunMetrics;
pub use selector::{select_allreduce, select_execution_mode, ExecMode};
pub use train::{train, TrainConfig, TrainReport};
