//! The persistent collective engine: spawn `p` rank workers **once**,
//! keep the typed endpoint network alive, and feed it a stream of
//! collectives.
//!
//! The paper's schedules are pure functions of `(p, partition, skip
//! scheme)`, and the pre-engine entry points
//! ([`crate::coordinator::Launcher::run`], the `run_schedule_threads*`
//! drivers) rebuilt *everything* per call: `p` fresh threads, a fresh
//! endpoint network (cold buffer pools!), and freshly generated
//! schedules. Fine for one-shot benches; fatal for serving repeated
//! traffic, where per-op cost should be the schedule's communication and
//! nothing else. A [`CollectiveEngine`] amortizes all three:
//!
//!  * **threads** — `p` long-lived workers, spawned once in
//!    [`CollectiveEngine::new`] and joined in
//!    [`shutdown`](CollectiveEngine::shutdown) (the `ccoll serve` soak
//!    asserts zero per-op spawns via
//!    [`crate::transport::rank_threads_spawned`]);
//!  * **transport** — one persistent [`Transport`] per worker (the
//!    in-process [`crate::transport::ThreadTransport`] by default; any
//!    other backend — e.g. the Unix-domain-socket transport for
//!    multi-process runs — via
//!    [`CollectiveEngine::with_transports`]), so buffer pools stay warm
//!    across operations and steady-state traffic allocates nothing;
//!  * **plans** — a shared [`PlanCache`] memoizing
//!    `(algorithm, p, partition, dtype) → Arc<Plan>`, so a repeated
//!    collective pays one hash lookup on the submission path.
//!
//! # Submission model
//!
//! [`submit`](CollectiveEngine::submit) enqueues an [`OpRequest`] (the
//! collective kind, ⊕ name, and per-rank input vectors) and returns an
//! [`OpHandle`] future immediately; [`OpHandle::wait`] joins that one
//! operation. Several operations may be in flight at once and complete
//! **out of submission order**: each worker keeps a table of resumable
//! [`OpCursor`]s and round-robin polls them with the transport's
//! non-blocking primitives, so a small op submitted after a large one
//! overtakes it instead of queueing behind it. Cross-op isolation on the
//! wire comes from the operation **tag** (epoch) allocated per submit —
//! see the `crate::transport` docs ("Op tags").
//!
//! Backpressure: `queue_depth` (config `engine.queue_depth`, env
//! `CCOLL_ENGINE_QUEUE_DEPTH`, 0 = unbounded) caps in-flight operations;
//! `submit` parks until a slot frees. The worker's wait strategy between
//! poll passes is [`ParkPolicy`] (`engine.park` / `CCOLL_ENGINE_PARK`):
//! `spin` for minimum latency, `yield` (default) for a fair middle
//! ground, `sleep` for minimum idle CPU. Idle workers (no in-flight op)
//! always block on the submission channel regardless of policy.
//!
//! # Size-adaptive dispatch: the fusion and pipelined tiers
//!
//! For small repeated collectives the per-round latency dominates; the
//! engine can coalesce compatible in-flight operations into **one** fused
//! circulant run (opt-in via [`EngineConfig::fusion`]). The batcher, its
//! flush policy (byte budget + a window of *completed engine steps*),
//! the block-major pack/scatter layout and the failure semantics live in
//! [`fusion`] — see that module's docs.
//!
//! At the other end of the size axis, large allreduces dispatch to the
//! **pipelined** tier ([`EngineConfig::pipeline_min_bytes`] /
//! [`EngineConfig::pipeline_chunk_bytes`]): the working vector is split
//! into chunks ([`crate::collectives::pipeline_chunk_sizes`]) and each
//! chunk runs the circulant schedule as its own wire epoch inside the
//! op's tag space, driven by a [`PipelinedCursor`] that overlaps chunk
//! k+1's sends with chunk k's combines. The thresholds are grounded in
//! the closed-form break-even analysis
//! (`crate::sim::closed_form::pipelined_circulant_allreduce`); mid-sized
//! ops run the plain one-epoch schedule.
//!
//! # When to prefer the engine vs the launcher
//!
//! [`Launcher`](crate::coordinator::Launcher) remains the right tool for
//! one-shot jobs and for interactive per-rank programs (its closure gets
//! a full [`Communicator`](crate::coordinator::Communicator)); it is
//! itself a thin wrapper that spawns an engine, runs the closure on every
//! worker, and shuts down. The engine is the right tool when the same
//! process issues many collectives over time — serving, training loops,
//! benches measuring steady state.

pub mod fusion;

pub use fusion::{
    FusionStats, DEFAULT_FUSION_MAX_BYTES, DEFAULT_FUSION_WINDOW, DEFAULT_PIPELINE_CHUNK_BYTES,
    DEFAULT_PIPELINE_MIN_BYTES,
};

use std::any::Any;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::collectives::exec::{
    CollectiveError, OpCursor, PipelinedCursor, Progress, DEFAULT_PIPELINE_WINDOW,
};
use crate::collectives::generators::allreduce_schedule;
use crate::collectives::CirculantPlans;
use crate::coordinator::OpBackend;
use crate::datatypes::{BlockPartition, Elem};
use crate::ops::{kernels, ReduceOp};
use crate::schedule::{Plan, PlanCache, PlanCacheStats};
use crate::topology::skips::SkipScheme;
use crate::transport::{network_typed, Endpoint, Remap, Transport, TransportError};

use fusion::{FlushReason, FusedLayout, FusedRankOp, FusedShare, Fuser};

/// Shared count of operations submitted but not yet finished everywhere.
pub(crate) type InflightCounter = Arc<AtomicUsize>;
/// Monotone count of fully-completed operations — the engine's logical
/// clock; the fusion tier's flush window is measured against it.
pub(crate) type StepCounter = Arc<AtomicU64>;
/// The sending half of one operation's completion channel.
pub(crate) type DoneTx<T> = Sender<(usize, Result<Vec<T>, CollectiveError>)>;
/// The receiving half ([`OpHandle`]'s end).
pub(crate) type DoneRx<T> = Receiver<(usize, Result<Vec<T>, CollectiveError>)>;

/// How a worker waits between poll passes while operations are in flight
/// (idle workers always block on the submission channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParkPolicy {
    /// Busy-spin (`spin_loop` hint) — lowest latency, one core per worker.
    Spin,
    /// `thread::yield_now` between passes — the default.
    Yield,
    /// Sleep ~50µs between passes — lowest idle CPU, adds wakeup latency.
    Sleep,
}

impl ParkPolicy {
    /// Grammar accepted by [`ParkPolicy::parse`], for knob diagnostics.
    pub const NAMES_HELP: &'static str = "spin|yield|sleep";

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "spin" => Some(ParkPolicy::Spin),
            "yield" => Some(ParkPolicy::Yield),
            "sleep" => Some(ParkPolicy::Sleep),
            _ => None,
        }
    }

    /// Canonical name; round-trips through [`ParkPolicy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            ParkPolicy::Spin => "spin",
            ParkPolicy::Yield => "yield",
            ParkPolicy::Sleep => "sleep",
        }
    }

    fn park(self) {
        match self {
            ParkPolicy::Spin => std::hint::spin_loop(),
            ParkPolicy::Yield => thread::yield_now(),
            ParkPolicy::Sleep => thread::sleep(Duration::from_micros(50)),
        }
    }
}

/// Engine construction parameters. Defaults come from the process-wide
/// `CCOLL_ENGINE_*` knobs (`crate::env_knobs`); the builder methods
/// override per engine.
#[derive(Clone)]
pub struct EngineConfig {
    pub p: usize,
    pub scheme: SkipScheme,
    pub backend: OpBackend,
    /// Enable the zero-copy rendezvous transport tier (subject to the
    /// process-wide `CCOLL_NO_RENDEZVOUS` kill-switch).
    pub rendezvous: bool,
    /// Override the per-endpoint small-payload rendezvous threshold
    /// (`None` keeps the latency-tuned process default; tests pin 0).
    pub rendezvous_min_elems: Option<usize>,
    /// Max operations in flight before `submit` parks (0 = unbounded).
    pub queue_depth: usize,
    /// Worker wait strategy between poll passes.
    pub park: ParkPolicy,
    /// Enable the fusion tier: coalesce compatible small in-flight ops
    /// into one fused circulant run (see [`fusion`]). Off by default —
    /// fusion trades a pack/scatter copy for saved rounds, a win only
    /// for latency-bound small-op traffic.
    pub fusion: bool,
    /// Fusion byte budget: a pending batch flushes before exceeding it,
    /// and any single op larger than it bypasses the batcher. Default
    /// from `CCOLL_FUSION_MAX_BYTES`.
    pub fusion_max_bytes: usize,
    /// Fusion flush window in **completed engine steps** (not
    /// wall-clock); 0 disables fusion. Default from
    /// `CCOLL_FUSION_WINDOW`.
    pub fusion_window: u64,
    /// Override the per-endpoint message/ack timeout (the liveness
    /// watchdog bound). `None` keeps the transport's generous default;
    /// failure-injection tests shrink it.
    pub op_timeout: Option<Duration>,
    /// How long `submit` may park on `queue_depth` backpressure before
    /// failing with [`EngineError::BackpressureTimeout`]. Default from
    /// `CCOLL_ENGINE_BACKPRESSURE_TIMEOUT` (seconds); config key
    /// `engine.backpressure_timeout`.
    pub backpressure_timeout: Duration,
    /// Transient-send retry budget applied to every rank transport via
    /// [`Transport::set_retry`]. Default from `CCOLL_RETRY_ATTEMPTS`;
    /// config key `engine.retry.attempts`.
    pub retry_attempts: usize,
    /// Base backoff (ms, doubling per attempt) between those retries.
    /// Default from `CCOLL_RETRY_BASE_MS`; config key
    /// `engine.retry.base_ms`.
    pub retry_base_ms: u64,
    /// Payload byte size at which an allreduce dispatches to the
    /// pipelined (chunked) tier; 0 disables pipelining. Default from
    /// `CCOLL_PIPELINE_MIN_BYTES`; config key
    /// `engine.pipeline.min_bytes`.
    pub pipeline_min_bytes: usize,
    /// Chunk byte size of the pipelined tier; 0 disables pipelining.
    /// Default from `CCOLL_PIPELINE_CHUNK_BYTES`; config key
    /// `engine.pipeline.chunk_bytes`.
    pub pipeline_chunk_bytes: usize,
}

impl EngineConfig {
    pub fn new(p: usize) -> Self {
        let knobs = crate::env_knobs::knobs();
        Self {
            p,
            scheme: SkipScheme::HalvingUp,
            backend: OpBackend::Native,
            rendezvous: true,
            rendezvous_min_elems: None,
            queue_depth: knobs.engine_queue_depth,
            park: knobs.engine_park,
            fusion: false,
            fusion_max_bytes: knobs.fusion_max_bytes,
            fusion_window: knobs.fusion_window,
            op_timeout: None,
            backpressure_timeout: Duration::from_secs(knobs.engine_backpressure_timeout_secs),
            retry_attempts: knobs.retry_attempts,
            retry_base_ms: knobs.retry_base_ms,
            pipeline_min_bytes: knobs.pipeline_min_bytes,
            pipeline_chunk_bytes: knobs.pipeline_chunk_bytes,
        }
    }

    pub fn scheme(mut self, scheme: SkipScheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn backend(mut self, backend: OpBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn rendezvous(mut self, enabled: bool) -> Self {
        self.rendezvous = enabled;
        self
    }

    pub fn rendezvous_min_elems(mut self, elems: usize) -> Self {
        self.rendezvous_min_elems = Some(elems);
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    pub fn park(mut self, park: ParkPolicy) -> Self {
        self.park = park;
        self
    }

    pub fn fusion(mut self, enabled: bool) -> Self {
        self.fusion = enabled;
        self
    }

    pub fn fusion_max_bytes(mut self, bytes: usize) -> Self {
        self.fusion_max_bytes = bytes;
        self
    }

    pub fn fusion_window(mut self, window: u64) -> Self {
        self.fusion_window = window;
        self
    }

    pub fn op_timeout(mut self, timeout: Duration) -> Self {
        self.op_timeout = Some(timeout);
        self
    }

    pub fn backpressure_timeout(mut self, timeout: Duration) -> Self {
        self.backpressure_timeout = timeout;
        self
    }

    pub fn retry(mut self, attempts: usize, base_ms: u64) -> Self {
        self.retry_attempts = attempts;
        self.retry_base_ms = base_ms;
        self
    }

    pub fn pipeline_min_bytes(mut self, bytes: usize) -> Self {
        self.pipeline_min_bytes = bytes;
        self
    }

    pub fn pipeline_chunk_bytes(mut self, bytes: usize) -> Self {
        self.pipeline_chunk_bytes = bytes;
        self
    }
}

/// Which collective an [`OpRequest`] runs.
#[derive(Debug, Clone)]
pub enum CollectiveKind {
    /// Algorithm 2 over the regular partition of the input length.
    Allreduce,
    /// Algorithm 1 over the regular partition (block `r` finishes at
    /// rank `r` of the returned buffer).
    ReduceScatter,
    /// Algorithm 1 over an explicit per-block partition (Corollary 3).
    ReduceScatterCounts(Vec<usize>),
}

/// One collective to run through the engine: the kind, the ⊕ name
/// (resolved against the engine's backend), and one input vector per rank
/// (all the same length — the working vectors move in and are returned
/// transformed by [`OpHandle::wait`]).
#[derive(Debug)]
pub struct OpRequest<T: Elem = f32> {
    pub kind: CollectiveKind,
    pub op: String,
    pub inputs: Vec<Vec<T>>,
}

impl<T: Elem> OpRequest<T> {
    pub fn allreduce(inputs: Vec<Vec<T>>, op: &str) -> Self {
        Self { kind: CollectiveKind::Allreduce, op: op.to_string(), inputs }
    }

    pub fn reduce_scatter(inputs: Vec<Vec<T>>, op: &str) -> Self {
        Self { kind: CollectiveKind::ReduceScatter, op: op.to_string(), inputs }
    }

    pub fn reduce_scatter_counts(inputs: Vec<Vec<T>>, counts: Vec<usize>, op: &str) -> Self {
        Self { kind: CollectiveKind::ReduceScatterCounts(counts), op: op.to_string(), inputs }
    }
}

/// Default seconds `submit` waits for an in-flight slot under
/// `queue_depth` backpressure before failing with
/// [`EngineError::BackpressureTimeout`] — comfortably past the
/// transport's 30s per-op liveness watchdog, so a wedged op fails (and
/// releases its slot) long before this fires unless a worker is actually
/// gone. Override with `CCOLL_ENGINE_BACKPRESSURE_TIMEOUT` /
/// `engine.backpressure_timeout` / [`EngineConfig::backpressure_timeout`].
pub const DEFAULT_BACKPRESSURE_TIMEOUT_SECS: u64 = 90;

/// Render the in-flight op-tag set for a backpressure diagnostic —
/// bounded so a deep queue cannot flood the error message.
fn render_tags(tags: &[u64]) -> String {
    const SHOWN: usize = 16;
    let head: Vec<String> = tags.iter().take(SHOWN).map(u64::to_string).collect();
    if tags.len() > SHOWN {
        format!("[{}, … +{} more]", head.join(", "), tags.len() - SHOWN)
    } else {
        format!("[{}]", head.join(", "))
    }
}

/// Errors surfaced by the engine's submission/completion paths.
#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("engine(p={p}): request carries inputs for {got} ranks")]
    WrongRankCount { p: usize, got: usize },
    #[error("engine(p={p}): rank {rank} input has {got} elements, others have {want}")]
    RaggedInputs { p: usize, rank: usize, got: usize, want: usize },
    #[error("engine(p={p}): reduce-scatter counts vector has {got} entries (need one per rank)")]
    BadCountsLen { p: usize, got: usize },
    #[error("engine: reduce-scatter counts sum to {want} elements but inputs have {got}")]
    BadCounts { got: usize, want: usize },
    #[error(
        "engine: unknown op {name:?} for dtype {dtype} on this backend \
         (native ops: sum|prod|min|max for every dtype; pjrt is f32 only)"
    )]
    UnknownOp { name: String, dtype: &'static str },
    #[error(
        "engine: backpressure timeout — {in_flight} ops in flight ≥ queue depth {depth} \
         with no completion for {secs}s; stuck op tags {tags} (worker dead or peer wedged?)",
        tags = render_tags(stuck_tags)
    )]
    BackpressureTimeout { in_flight: usize, depth: usize, secs: u64, stuck_tags: Vec<u64> },
    #[error("engine: worker {rank} is gone (engine shut down or crashed)")]
    WorkerGone { rank: usize },
    #[error("engine: already shut down")]
    ShutDown,
    #[error("engine: recovery failed — {detail}")]
    RecoveryFailed { detail: String },
    #[error("engine: operation results lost (a worker exited early)")]
    ResultsLost,
    #[error("rank {rank}: {source}")]
    Collective {
        rank: usize,
        #[source]
        source: CollectiveError,
    },
}

/// The live set of in-flight operation ids — registered at submission,
/// deregistered when the last rank share settles. The
/// [`EngineError::BackpressureTimeout`] diagnostic snapshots it so a
/// stuck queue names *which* ops are wedged, not just how many.
pub(crate) type InflightTags = Arc<Mutex<BTreeSet<u64>>>;

/// Per-operation bookkeeping shared by the `p` rank-sides of one op
/// (fused members each have their own — a fused run carries one per
/// member, so each member's slot releases independently).
pub(crate) struct OpShared {
    /// Rank-sides not yet finished; the last one releases the in-flight
    /// slot and ticks the completed-step clock.
    remaining: AtomicUsize,
    inflight: InflightCounter,
    completed: StepCounter,
    /// This op's id, held in `tags` until every rank share settles.
    tag: u64,
    tags: InflightTags,
}

impl OpShared {
    pub(crate) fn new(
        p: usize,
        tag: u64,
        inflight: InflightCounter,
        completed: StepCounter,
        tags: InflightTags,
    ) -> Self {
        tags.lock().unwrap().insert(tag);
        Self { remaining: AtomicUsize::new(p), inflight, completed, tag, tags }
    }

    /// One rank's share of this operation is settled — a result or error
    /// was delivered, or the share was rolled back as undeliverable. The
    /// last share releases the in-flight slot and advances the engine's
    /// completed-step clock (the fusion flush window counts those steps).
    pub(crate) fn note_rank_done(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.completed.fetch_add(1, Ordering::AcqRel);
            self.tags.lock().unwrap().remove(&self.tag);
        }
    }
}

/// One rank's share of a submitted (unfused) operation.
pub(crate) struct RankOp<T: Elem> {
    pub(crate) op_tag: u64,
    pub(crate) plan: Arc<Plan>,
    pub(crate) op: Arc<dyn ReduceOp<T>>,
    pub(crate) buf: Vec<T>,
    pub(crate) done: DoneTx<T>,
    pub(crate) shared: Arc<OpShared>,
}

/// One rank's share of a pipelined (chunked large-message) operation:
/// the chunk geometry travels as `(element offset, chunk plan)` pairs —
/// at most two distinct plans (full chunk + fold-in remainder), both
/// from the engine's [`PlanCache`] and therefore statically audited.
pub(crate) struct PipelinedRankOp<T: Elem> {
    pub(crate) op_tag: u64,
    pub(crate) chunks: Vec<(usize, Arc<Plan>)>,
    pub(crate) op: Arc<dyn ReduceOp<T>>,
    pub(crate) buf: Vec<T>,
    pub(crate) done: DoneTx<T>,
    pub(crate) shared: Arc<OpShared>,
}

/// Type-erased one-shot closure a worker runs inline on its transport —
/// the substrate [`crate::coordinator::Launcher`] is built on. A job may
/// consume the transport (the launcher's communicator closures do), so
/// the engine must be shut down after a closure run; see
/// [`CollectiveEngine::run_closure`].
type JobFn<C> = Box<dyn FnOnce(usize, &mut C) -> Box<dyn Any + Send> + Send>;

pub(crate) struct Job<C> {
    run: JobFn<C>,
    done: Sender<(usize, Box<dyn Any + Send>)>,
}

/// A worker's parting gift on [`WorkerCmd::Surrender`]: its endpoint
/// (alive, pools warm) plus the counters only the owning thread could
/// read. The engine's reconfiguration round collects one per worker,
/// remaps the survivors, and respawns.
pub(crate) struct Surrendered<C> {
    ep: C,
    /// Cumulative stale-generation frames this endpoint dropped.
    stale_frames: u64,
}

pub(crate) enum WorkerCmd<T: Elem, C = Endpoint<T>> {
    Op(RankOp<T>),
    Pipelined(PipelinedRankOp<T>),
    Fused(FusedRankOp<T>),
    Job(Job<C>),
    Shutdown,
    /// Like [`WorkerCmd::Shutdown`] — the worker settles its in-flight
    /// operations first — but instead of dropping its endpoint on exit
    /// it hands it back through the enclosed channel, keeping the
    /// transport (connections, buffer pools, health bitmap) alive for a
    /// reconfiguration round or for shutdown-time counter aggregation.
    Surrender(Sender<Surrendered<C>>),
}

/// Future for one submitted operation.
pub struct OpHandle<T: Elem = f32, C = Endpoint<T>> {
    op_id: u64,
    p: usize,
    rx: DoneRx<T>,
    /// The engine's batching stage: waiting on a still-batched member
    /// must force its batch out, or the wait could never return. Shared
    /// with the engine, which swaps the fuser *in place* on recovery —
    /// so a handle taken before a reconfiguration still reaches the
    /// current batching stage.
    fuser: Arc<Mutex<Fuser<T, Remap<T, C>>>>,
}

impl<T: Elem, C> OpHandle<T, C> {
    /// The operation's id (unique per engine, monotonically increasing
    /// in submission order). Unfused operations use it as their wire
    /// epoch; a fused member's batch runs under its own separate epoch.
    pub fn op_id(&self) -> u64 {
        self.op_id
    }

    /// Block until every rank finished this operation; returns the
    /// per-rank working vectors in rank order (allreduce: the full
    /// reduction everywhere; reduce-scatter: block `r` finished at rank
    /// `r`). The first rank error wins; remaining ranks are still
    /// drained so the engine is quiesced when this returns. If this
    /// operation is still sitting in the fusion tier's pending batch,
    /// the batch is flushed first — a waited handle can never deadlock
    /// on its own batching.
    pub fn wait(self) -> Result<Vec<Vec<T>>, EngineError> {
        {
            let mut fuser = self.fuser.lock().unwrap();
            if fuser.pending_contains(self.op_id) {
                fuser.flush(FlushReason::Forced);
            } else {
                // Opportunistic window enforcement: the completed-step
                // window has no timer behind it, so waits on *other*
                // operations also evict a batch that outlived its
                // window (see `Fuser::flush_if_stale`).
                fuser.flush_if_stale();
            }
        }
        let mut out: Vec<Option<Vec<T>>> = (0..self.p).map(|_| None).collect();
        let mut err: Option<EngineError> = None;
        for _ in 0..self.p {
            match self.rx.recv() {
                Ok((rank, Ok(buf))) => out[rank] = Some(buf),
                Ok((rank, Err(source))) => {
                    err.get_or_insert(EngineError::Collective { rank, source });
                }
                Err(_) => {
                    err.get_or_insert(EngineError::ResultsLost);
                    break;
                }
            }
        }
        match err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|b| b.expect("every rank reported")).collect()),
        }
    }
}

/// What an in-flight worker entry resolves into on completion: one
/// operation's handle, or a fused batch's many.
enum ActiveKind<T: Elem> {
    Single { done: DoneTx<T>, shared: Arc<OpShared> },
    Fused { allreduce: bool, layout: Arc<FusedLayout>, shares: Vec<FusedShare<T>> },
}

/// The schedule driver of one in-flight op: a single [`OpCursor`] over
/// one plan (single and fused ops), or a [`PipelinedCursor`] over the
/// chunk plans of a pipelined large-message op. Both expose the same
/// engine-facing surface — monotone progress stamp, down-peer scan,
/// watchdog error, single-epoch abort — so the worker loop is
/// tier-agnostic.
enum Driver {
    Plain { cursor: OpCursor, plan: Arc<Plan> },
    Pipelined(PipelinedCursor),
}

impl Driver {
    fn op_tag(&self) -> u64 {
        match self {
            Driver::Plain { cursor, .. } => cursor.op_tag(),
            Driver::Pipelined(c) => c.op_tag(),
        }
    }

    fn progress(&self) -> u64 {
        match self {
            Driver::Plain { cursor, .. } => cursor.progress(),
            Driver::Pipelined(c) => c.progress(),
        }
    }

    fn first_needed_down_peer(&self, rank: usize, up: &[bool]) -> Option<usize> {
        match self {
            Driver::Plain { cursor, plan } => {
                cursor.first_needed_down_peer(&plan.schedule, rank, up)
            }
            Driver::Pipelined(c) => c.first_needed_down_peer(rank, up),
        }
    }

    fn timeout_error(&self, rank: usize) -> CollectiveError {
        match self {
            Driver::Plain { cursor, plan } => cursor.timeout_error(&plan.schedule, rank),
            Driver::Pipelined(c) => c.timeout_error(rank),
        }
    }

    fn abort<T: Elem, C: Transport<T>>(&mut self, ep: &mut C) {
        match self {
            Driver::Plain { cursor, .. } => cursor.abort(ep),
            Driver::Pipelined(c) => c.abort(ep),
        }
    }

    /// One non-blocking poll pass of this op's schedule driver.
    fn step<T: Elem, C: Transport<T>>(
        &mut self,
        ep: &mut C,
        op: &dyn ReduceOp<T>,
        buf: &mut [T],
    ) -> Result<Progress, CollectiveError> {
        match self {
            Driver::Plain { cursor, plan } => cursor.step_with_tiers(
                ep,
                &plan.schedule,
                &plan.part,
                op,
                buf,
                false,
                Some(&plan.tiers),
            ),
            Driver::Pipelined(c) => c.step(ep, op, buf, false),
        }
    }
}

/// One in-flight operation in a worker's table (`buf` is the working
/// vector: the member's own for a single op, the packed segment buffer
/// for a fused run).
struct ActiveOp<T: Elem> {
    driver: Driver,
    op: Arc<dyn ReduceOp<T>>,
    buf: Vec<T>,
    kind: ActiveKind<T>,
    /// Last observed cursor progress stamp (liveness watchdog).
    last_progress: u64,
    /// When to declare this op stuck if no progress happens.
    deadline: Instant,
}

impl<T: Elem> ActiveOp<T> {
    /// Deliver success. Single ops hand their working vector to the
    /// handle; fused runs scatter each member's result segments back
    /// (every span for allreduce, the owned-block span for
    /// reduce-scatter) and return the spent segment buffer for reuse.
    /// The handle may have been dropped — completion accounting happens
    /// regardless, so in-flight slots are always released.
    fn finish_ok(&mut self, rank: usize) -> Option<Vec<T>> {
        let buf = std::mem::take(&mut self.buf);
        match &mut self.kind {
            ActiveKind::Single { done, shared } => {
                let _ = done.send((rank, Ok(buf)));
                shared.note_rank_done();
                None
            }
            ActiveKind::Fused { allreduce, layout, shares } => {
                for (j, share) in shares.iter_mut().enumerate() {
                    let spans = &layout.spans[j];
                    let spans = if *allreduce { &spans[..] } else { &spans[rank..rank + 1] };
                    let mut out = std::mem::take(&mut share.buf);
                    kernels::scatter_segments(&mut out, &buf, spans);
                    let _ = share.done.send((rank, Ok(out)));
                    share.shared.note_rank_done();
                }
                Some(buf)
            }
        }
    }

    /// Deliver failure. Every member of a failed fused run gets the
    /// error with the fusion tag (batch epoch + member count) in its
    /// diagnostic — per-op error isolation with a traceable cause.
    fn finish_err(&mut self, rank: usize, err: CollectiveError) {
        let fused_op = self.driver.op_tag();
        match &mut self.kind {
            ActiveKind::Single { done, shared } => {
                let _ = done.send((rank, Err(err)));
                shared.note_rank_done();
            }
            ActiveKind::Fused { shares, .. } => {
                let detail = err.to_string();
                let members = shares.len();
                for share in shares.iter() {
                    let _ = share.done.send((
                        rank,
                        Err(CollectiveError::FusedBatch {
                            fused_op,
                            members,
                            detail: detail.clone(),
                        }),
                    ));
                    share.shared.note_rank_done();
                }
            }
        }
    }
}

/// The persistent engine: `p` long-lived rank workers around a persistent
/// typed transport network, fed through per-worker submission queues. See
/// the module docs. `C` is the transport backend — the in-process
/// [`crate::transport::ThreadTransport`] by default
/// ([`CollectiveEngine::new`]), or any other [`Transport`] via
/// [`CollectiveEngine::with_transports`].
pub struct CollectiveEngine<T: Elem = f32, C = Endpoint<T>> {
    /// Current world size — `p′` after reconfigurations, the
    /// construction `p` before any.
    p: usize,
    /// World size at construction (physical rank space).
    p0: usize,
    scheme: SkipScheme,
    backend: OpBackend,
    queue_depth: usize,
    backpressure_timeout: Duration,
    /// Worker/fuser knobs retained for post-recovery rebuilds.
    park: ParkPolicy,
    fusion: bool,
    fusion_max_bytes: usize,
    fusion_window: u64,
    pipeline_min_bytes: usize,
    pipeline_chunk_bytes: usize,
    inflight: InflightCounter,
    inflight_tags: InflightTags,
    completed: StepCounter,
    plans: Arc<PlanCache>,
    /// The batching stage + submission fan-out ([`fusion`]): holds the
    /// plan vocabulary, the epoch allocator and the pending batch.
    /// Shared with every [`OpHandle`] so a waited member can force its
    /// batch out; workers never touch it. Every transport is wrapped in
    /// a [`Remap`] so a reconfiguration can renumber survivors densely
    /// without the backend's cooperation.
    fuser: Arc<Mutex<Fuser<T, Remap<T, C>>>>,
    txs: Vec<Sender<WorkerCmd<T, Remap<T, C>>>>,
    workers: Vec<thread::JoinHandle<()>>,
    /// `live[dense] = physical` construction rank of each current rank.
    live: Vec<usize>,
    /// Current generation epoch — 0 until the first reconfiguration,
    /// bumped by every [`CollectiveEngine::recover`] and composed into
    /// each op's wire tag so pre-failure traffic can never cross-match
    /// post-recovery operations.
    generation: u64,
    /// Completed reconfiguration rounds.
    recoveries: u64,
    /// Completed-op clock reading at the last reconfiguration.
    completed_at_recovery: u64,
    /// Stale-generation frames dropped across all endpoints, as
    /// snapshotted at the last reconfiguration or shutdown (workers own
    /// their endpoints in between, so there is no live counter to read).
    stale_frames_seen: u64,
    /// Final stale counts of endpoints already dropped (dead ranks at
    /// past reconfigurations) — folded into every later snapshot.
    retired_stale: u64,
}

impl<T: Elem> CollectiveEngine<T> {
    /// Spawn the `p` rank workers over a fresh in-process
    /// [`crate::transport::ThreadTransport`] network — the default
    /// single-process engine all PR 1–5 entry points use.
    pub fn new(cfg: EngineConfig) -> Self {
        assert!(cfg.p >= 1, "engine needs at least one rank");
        let endpoints = network_typed::<T>(cfg.p);
        Self::with_transports(cfg, endpoints)
    }
}

impl<T: Elem, C> CollectiveEngine<T, C> {
    /// Spawn the `p` rank workers over caller-provided transports (one
    /// per rank, in rank order — e.g.
    /// [`crate::transport::uds::uds_network_typed`] handles, or one
    /// process's single [`crate::transport::uds::UdsTransport`] with the
    /// other ranks' workers living in peer processes). This is the
    /// engine's only thread spawn — every subsequent operation reuses
    /// the workers ([`crate::transport::rank_threads_spawned`] counts
    /// exactly `transports.len()` for an engine's whole lifetime).
    ///
    /// The config's rendezvous/timeout knobs are applied through the
    /// [`Transport`] trait; backends without a tier (the UDS backend has
    /// no rendezvous) treat the corresponding setters as no-ops and the
    /// executor falls back per its capability flags.
    pub fn with_transports(cfg: EngineConfig, transports: Vec<C>) -> Self
    where
        C: Transport<T> + Send + 'static,
    {
        assert!(cfg.p >= 1, "engine needs at least one rank");
        assert_eq!(
            transports.len(),
            cfg.p,
            "engine(p={}) needs one transport per rank",
            cfg.p
        );
        // Validate the scheme + derive the plan vocabulary once, up
        // front: every submission reuses both, and a bad scheme should
        // fail at construction — not on the Nth submit.
        let vocab = CirculantPlans::new(&cfg.scheme, cfg.p);
        let mut eps: Vec<Remap<T, C>> = Vec::with_capacity(cfg.p);
        for t in transports {
            // Wrap every backend in a dense-rank remapper (identity map
            // until a reconfiguration shrinks the world). Config knobs
            // pass straight through to the real transport.
            let mut ep = Remap::new(t);
            ep.set_rendezvous(cfg.rendezvous && crate::transport::rendezvous_env_enabled());
            if let Some(min) = cfg.rendezvous_min_elems {
                ep.set_rendezvous_min_elems(min);
            }
            if let Some(timeout) = cfg.op_timeout {
                ep.set_timeout(timeout);
            }
            ep.set_retry(cfg.retry_attempts, cfg.retry_base_ms);
            eps.push(ep);
        }
        let (txs, workers) = spawn_workers(eps, cfg.park);
        let inflight: InflightCounter = Arc::new(AtomicUsize::new(0));
        let inflight_tags: InflightTags = Arc::new(Mutex::new(BTreeSet::new()));
        let completed: StepCounter = Arc::new(AtomicU64::new(0));
        let plans = Arc::new(PlanCache::new());
        let fuser = Arc::new(Mutex::new(Fuser::new(
            cfg.p,
            vocab,
            txs.clone(),
            plans.clone(),
            inflight.clone(),
            completed.clone(),
            inflight_tags.clone(),
            cfg.fusion,
            cfg.fusion_max_bytes,
            cfg.fusion_window,
            cfg.pipeline_min_bytes,
            cfg.pipeline_chunk_bytes,
        )));
        Self {
            p: cfg.p,
            p0: cfg.p,
            scheme: cfg.scheme,
            backend: cfg.backend,
            queue_depth: cfg.queue_depth,
            backpressure_timeout: cfg.backpressure_timeout,
            park: cfg.park,
            fusion: cfg.fusion,
            fusion_max_bytes: cfg.fusion_max_bytes,
            fusion_window: cfg.fusion_window,
            pipeline_min_bytes: cfg.pipeline_min_bytes,
            pipeline_chunk_bytes: cfg.pipeline_chunk_bytes,
            inflight,
            inflight_tags,
            completed,
            plans,
            fuser,
            txs,
            workers,
            live: (0..cfg.p).collect(),
            generation: 0,
            recoveries: 0,
            completed_at_recovery: 0,
            stale_frames_seen: 0,
            retired_stale: 0,
        }
    }

    /// The engine's skip scheme.
    pub fn scheme(&self) -> &SkipScheme {
        &self.scheme
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// Operations submitted but not yet finished on every rank.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// The engine's current generation epoch: 0 until the first
    /// reconfiguration, bumped by every [`CollectiveEngine::recover`].
    /// Composed into each op's wire tag (`crate::transport::compose_op`).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Completed reconfiguration rounds.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    /// Operations fully completed since the last reconfiguration (0
    /// when the engine has never reconfigured).
    pub fn recovered_ops(&self) -> u64 {
        if self.recoveries == 0 {
            0
        } else {
            self.completed.load(Ordering::Acquire) - self.completed_at_recovery
        }
    }

    /// Stale-generation frames dropped across all rank endpoints, as of
    /// the last reconfiguration or shutdown. Workers own their
    /// endpoints between those events, so this is a snapshot, not a
    /// live counter.
    pub fn stale_frames_dropped(&self) -> u64 {
        self.stale_frames_seen
    }

    /// Health of the **original** construction ranks: `up[physical]` is
    /// `true` while that rank is part of the current live set.
    pub fn peer_health(&self) -> Vec<bool> {
        let mut up = vec![false; self.p0];
        for &physical in &self.live {
            up[physical] = true;
        }
        up
    }

    /// Physical (construction-index) rank of each current dense rank.
    pub fn live_ranks(&self) -> &[usize] {
        &self.live
    }

    /// The shared plan cache (hand it to communicators that should reuse
    /// this engine's plans).
    pub fn plan_cache(&self) -> Arc<PlanCache> {
        self.plans.clone()
    }

    /// Plan-cache hit/miss/size counters.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// Fusion-tier counters (batches, fused ops, bypasses, flush
    /// reasons, fused-plan hits) — all zero when fusion is off.
    pub fn fusion_stats(&self) -> FusionStats {
        self.fuser.lock().unwrap().stats()
    }

    /// Dispatch the fusion tier's pending batch immediately (no-op when
    /// empty or fusion is off). Waiting on any member's handle does this
    /// implicitly; call it to bound latency before going idle.
    pub fn flush(&self) {
        self.fuser.lock().unwrap().flush(FlushReason::Forced);
    }

    /// Enqueue one collective; returns its future immediately. Parks when
    /// `queue_depth` operations are already in flight. With the fusion
    /// tier enabled the operation may be held briefly in a pending batch
    /// (see [`fusion`] for the flush policy); [`OpHandle::wait`] always
    /// forces it out. See [`OpRequest`] for input semantics and
    /// [`OpHandle::wait`] for result layout.
    pub fn submit(&mut self, req: OpRequest<T>) -> Result<OpHandle<T, C>, EngineError> {
        let p = self.p;
        if self.txs.is_empty() {
            return Err(EngineError::ShutDown);
        }
        if req.inputs.len() != p {
            return Err(EngineError::WrongRankCount { p, got: req.inputs.len() });
        }
        let m = req.inputs.first().map_or(0, Vec::len);
        for (rank, v) in req.inputs.iter().enumerate() {
            if v.len() != m {
                return Err(EngineError::RaggedInputs { p, rank, got: v.len(), want: m });
            }
        }
        if let CollectiveKind::ReduceScatterCounts(counts) = &req.kind {
            if counts.len() != p {
                return Err(EngineError::BadCountsLen { p, got: counts.len() });
            }
            let want: usize = counts.iter().sum();
            if want != m {
                return Err(EngineError::BadCounts { got: m, want });
            }
        }
        let op: Arc<dyn ReduceOp<T>> =
            Arc::from(self.backend.resolve::<T>(&req.op).ok_or_else(|| EngineError::UnknownOp {
                name: req.op.clone(),
                dtype: T::DTYPE.name(),
            })?);

        // Backpressure: park until an in-flight slot frees. Workers
        // release slots as ops finish (even on error or watchdog
        // timeout), so this drains within the transport's 30s liveness
        // bound unless a worker is actually gone — the deadline turns
        // that pathology into an error instead of a silent forever-spin.
        if self.queue_depth > 0 {
            let deadline = Instant::now() + self.backpressure_timeout;
            while self.inflight.load(Ordering::Acquire) >= self.queue_depth {
                // A pending fused batch occupies in-flight slots but can
                // never complete until dispatched: flush before parking,
                // or the park could only end in BackpressureTimeout.
                self.fuser.lock().unwrap().flush(FlushReason::Forced);
                if Instant::now() >= deadline {
                    return Err(EngineError::BackpressureTimeout {
                        in_flight: self.inflight.load(Ordering::Acquire),
                        depth: self.queue_depth,
                        secs: self.backpressure_timeout.as_secs(),
                        stuck_tags: self
                            .inflight_tags
                            .lock()
                            .unwrap()
                            .iter()
                            .copied()
                            .collect(),
                    });
                }
                thread::sleep(Duration::from_micros(50));
            }
        }

        let (op_id, rx) =
            self.fuser.lock().unwrap().submit_op(req.kind, &req.op, op, req.inputs, m)?;
        Ok(OpHandle { op_id, p, rx, fuser: self.fuser.clone() })
    }

    /// Run `f(rank, transport)` once on every worker and collect the
    /// results in rank order — the launcher substrate. The closure may
    /// consume/replace the transport (the launcher's communicator does),
    /// so the engine is only good for [`shutdown`]
    /// (CollectiveEngine::shutdown) afterwards; that is why this is
    /// crate-private. Worker panics propagate like `run_ranks`' did.
    pub(crate) fn run_closure<R, F>(&mut self, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &mut Remap<T, C>) -> R + Send + Sync + 'static,
    {
        // Jobs run inline on otherwise-idle workers; a batched op left
        // pending would be stranded behind them, so dispatch it first.
        self.fuser.lock().unwrap().flush(FlushReason::Forced);
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, Box<dyn Any + Send>)>();
        for rank in 0..self.p {
            let f = f.clone();
            let run: JobFn<Remap<T, C>> =
                Box::new(move |rank, ep| Box::new(f(rank, ep)) as Box<dyn Any + Send>);
            if self.txs[rank].send(WorkerCmd::Job(Job { run, done: tx.clone() })).is_err() {
                self.join_workers_propagating();
                panic!("engine worker {rank} exited before running its job");
            }
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..self.p).map(|_| None).collect();
        for _ in 0..self.p {
            match rx.recv() {
                Ok((rank, boxed)) => {
                    out[rank] = Some(*boxed.downcast::<R>().expect("job result type"));
                }
                Err(_) => {
                    // A worker died before reporting — join to surface its
                    // panic payload with the original message.
                    self.join_workers_propagating();
                    panic!("engine worker exited before returning its job result");
                }
            }
        }
        out.into_iter().map(|r| r.expect("all ranks reported")).collect()
    }

    /// Drain-mode shutdown: immediately reject **new** submissions
    /// (`EngineError::ShutDown`), dispatch the pending fused batch, let
    /// every already-submitted operation run to completion (or to its
    /// per-op watchdog error), then join the workers. The wait for
    /// in-flight ops is bounded by the backpressure timeout — ops release
    /// their slots even on failure within the op-timeout watchdog, so
    /// only a dead worker can make this bound bite, and [`shutdown`]
    /// (CollectiveEngine::shutdown) still tears down afterwards either
    /// way. Idempotent, like `shutdown`.
    pub fn drain_shutdown(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        {
            let mut fuser = self.fuser.lock().unwrap();
            fuser.flush(FlushReason::Forced);
            fuser.shut_down = true; // submit_op now refuses new work
        }
        let deadline = Instant::now() + self.backpressure_timeout;
        while self.inflight.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_micros(100));
        }
        self.shutdown();
    }

    /// Ask every worker to finish its in-flight operations and exit, then
    /// join them. A pending fused batch is dispatched first so its
    /// members complete rather than strand. Propagates worker panics.
    /// Idempotent. Endpoints are surrendered (not dropped in place) so
    /// their stale-frame counters fold into the engine's final
    /// [`CollectiveEngine::stale_frames_dropped`] snapshot.
    pub fn shutdown(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        {
            let mut fuser = self.fuser.lock().unwrap();
            fuser.flush(FlushReason::Forced);
            fuser.shut_down = true;
        }
        for s in self.collect_endpoints() {
            self.retired_stale += s.stale_frames;
        }
        self.stale_frames_seen = self.retired_stale;
    }

    /// Hand every worker a surrender ticket, collect the endpoints back
    /// (each worker settles its in-flight ops first — shutdown
    /// semantics), and join the worker threads. Tolerates workers that
    /// already exited: they simply do not report.
    fn collect_endpoints(&mut self) -> Vec<Surrendered<Remap<T, C>>> {
        let (give, take) = channel::<Surrendered<Remap<T, C>>>();
        for tx in &self.txs {
            let _ = tx.send(WorkerCmd::Surrender(give.clone()));
        }
        drop(give);
        // Blocks until every worker either surrendered or exited (each
        // send-half drops with its worker, closing the channel).
        let mut eps = Vec::with_capacity(self.txs.len());
        while let Ok(s) = take.recv() {
            eps.push(s);
        }
        self.join_workers_propagating();
        eps
    }

    fn join_workers_propagating(&mut self) {
        // Closing the command channels unblocks idle workers' recv().
        self.txs.clear();
        for handle in self.workers.drain(..) {
            if let Err(payload) = handle.join() {
                if !thread::panicking() {
                    std::panic::resume_unwind(payload);
                }
            }
        }
    }

    /// The reconfiguration round: re-form the engine over the surviving
    /// ranks after a failure (detect → fail → **reconfigure** → resume).
    ///
    /// In-flight operations needing a dead rank have already failed with
    /// [`CollectiveError::RankDown`] via the per-worker health bitmap
    /// fast-fail; this call then
    ///
    ///  1. quiesces submissions and collects every worker's endpoint
    ///     (workers settle their remaining ops first, so no in-flight
    ///     slot leaks across the round);
    ///  2. runs survivor consensus over the dense health bitmaps — a
    ///     rank is dead if **any** endpoint positively observed it down
    ///     (every backend keeps its own slot up by contract, so a dead
    ///     rank can neither veto itself back in nor vote others out);
    ///  3. bumps the generation epoch — even when nobody died, because
    ///     each round restarts the op-sequence allocator and
    ///     `(generation, seq)` wire tags must never repeat — and stamps
    ///     it into every surviving endpoint, which from then on drop
    ///     (and count) frames from older generations;
    ///  4. rebuilds the circulant plan vocabulary for `p′` survivors and
    ///     proves the rebuilt schedule with the static `analysis` audit
    ///     **before** any worker respawns — a recovery that cannot
    ///     produce a verified schedule fails loudly instead of resuming
    ///     on an unproven plan (all future survivor-set plan builds are
    ///     force-audited too, via [`PlanCache::set_force_audit`]);
    ///  5. remaps survivors onto dense ranks `0..p′`, respawns workers,
    ///     and swaps a fresh fuser in place so existing [`OpHandle`]s
    ///     stay valid.
    ///
    /// Not a replay mechanism: operations that failed stay failed — the
    /// caller resubmits if desired. Survivors' partial contributions
    /// from failed ops are discarded, never merged.
    pub fn recover(&mut self) -> Result<RecoveryReport, EngineError>
    where
        C: Transport<T> + Send + 'static,
    {
        if self.workers.is_empty() {
            return Err(EngineError::ShutDown);
        }
        {
            let mut fuser = self.fuser.lock().unwrap();
            fuser.flush(FlushReason::Forced);
            fuser.shut_down = true; // reopened by the fuser swap below
        }
        let mut eps = self.collect_endpoints();
        if eps.len() != self.p {
            return Err(EngineError::RecoveryFailed {
                detail: format!(
                    "only {}/{} workers surrendered their endpoints (worker crashed?)",
                    eps.len(),
                    self.p
                ),
            });
        }
        eps.sort_by_key(|s| s.ep.rank());
        let mut up = vec![true; self.p];
        for s in &eps {
            for (r, ok) in s.ep.peer_status().into_iter().enumerate() {
                if !ok {
                    up[r] = false;
                }
            }
        }
        let p_new = up.iter().filter(|&&ok| ok).count();
        if p_new < 2 {
            return Err(EngineError::RecoveryFailed {
                detail: format!(
                    "{p_new} of {} ranks survive — not enough for a collective",
                    self.p
                ),
            });
        }
        // Stale accounting: live endpoints report cumulative counters
        // (re-read fresh at every snapshot); endpoints retired at past
        // rounds contribute their final counts permanently.
        let live_total: u64 = eps.iter().map(|s| s.stale_frames).sum();
        self.stale_frames_seen = self.retired_stale + live_total;
        let failed: Vec<usize> =
            (0..self.p).filter(|&r| !up[r]).map(|r| self.live[r]).collect();
        let new_map: Vec<usize> =
            (0..self.p).filter(|&r| up[r]).map(|r| self.live[r]).collect();
        self.generation += 1;
        // Rebuild + prove the survivor-set plans before any worker
        // respawns; `CirculantPlans` itself asserts scheme validity.
        let vocab = CirculantPlans::new(&self.scheme, p_new);
        self.plans.set_force_audit(true);
        let schedule = allreduce_schedule(p_new, &vocab.skips);
        let probe = BlockPartition::regular(p_new, p_new);
        if let Err(e) = crate::analysis::audit_plan(&vocab.allreduce, &schedule, &probe) {
            return Err(EngineError::RecoveryFailed {
                detail: format!(
                    "rebuilt p={p_new} allreduce schedule failed the static audit [{}]: {e}",
                    e.code()
                ),
            });
        }
        let mut new_eps: Vec<Remap<T, C>> = Vec::with_capacity(p_new);
        for (r, s) in eps.into_iter().enumerate() {
            if !up[r] {
                // Dead rank: retire its endpoint — and its counters —
                // for good.
                self.retired_stale += s.stale_frames;
                continue;
            }
            let mut ep = s.ep;
            ep.set_map(new_map.clone());
            ep.set_generation(self.generation);
            new_eps.push(ep);
        }
        let (txs, workers) = spawn_workers(new_eps, self.park);
        self.txs = txs;
        self.workers = workers;
        let mut fuser = Fuser::new(
            p_new,
            vocab,
            self.txs.clone(),
            self.plans.clone(),
            self.inflight.clone(),
            self.completed.clone(),
            self.inflight_tags.clone(),
            self.fusion,
            self.fusion_max_bytes,
            self.fusion_window,
            self.pipeline_min_bytes,
            self.pipeline_chunk_bytes,
        );
        fuser.set_generation(self.generation);
        // Swap in place: existing OpHandles hold this Arc.
        *self.fuser.lock().unwrap() = fuser;
        self.p = p_new;
        self.live = new_map;
        self.recoveries += 1;
        self.completed_at_recovery = self.completed.load(Ordering::Acquire);
        Ok(RecoveryReport {
            generation: self.generation,
            p: p_new,
            failed,
            stale_frames_dropped: self.stale_frames_seen,
        })
    }
}

/// What one [`CollectiveEngine::recover`] round did.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// Generation epoch in force after this round (monotone, starts at 1).
    pub generation: u64,
    /// Surviving world size `p′`.
    pub p: usize,
    /// Physical (construction-index) ranks removed this round.
    pub failed: Vec<usize>,
    /// Cumulative stale-generation frames dropped, as observed at this
    /// round's snapshot.
    pub stale_frames_dropped: u64,
}

impl<T: Elem, C> Drop for CollectiveEngine<T, C> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Most segment buffers a worker keeps around for fused runs — enough to
/// cover a window of interleaved fused batches without unbounded hoard.
const SEGMENT_POOL_CAP: usize = 4;

/// Check a segment buffer with at least `need` capacity out of the
/// worker-local pool (or allocate one — a one-time warm-up cost per
/// capacity class, like the transport's payload pools).
fn take_segment<T: Elem>(pool: &mut Vec<Vec<T>>, need: usize) -> Vec<T> {
    if let Some(i) = pool.iter().position(|b| b.capacity() >= need) {
        let mut buf = pool.swap_remove(i);
        buf.clear();
        buf
    } else {
        Vec::with_capacity(need)
    }
}

/// Return a spent segment buffer to the worker-local pool.
fn recycle_segment<T: Elem>(pool: &mut Vec<Vec<T>>, buf: Vec<T>) {
    if buf.capacity() > 0 && pool.len() < SEGMENT_POOL_CAP {
        pool.push(buf);
    }
}

/// Spawn one `engine-rank-{r}` worker thread per endpoint (the engine's
/// only thread spawns — construction and every reconfiguration round go
/// through here, each spawn counted by
/// [`crate::transport::note_rank_thread_spawn`]).
fn spawn_workers<T: Elem, C: Transport<T> + Send + 'static>(
    eps: Vec<Remap<T, C>>,
    park: ParkPolicy,
) -> (Vec<Sender<WorkerCmd<T, Remap<T, C>>>>, Vec<thread::JoinHandle<()>>) {
    let mut txs = Vec::with_capacity(eps.len());
    let mut workers = Vec::with_capacity(eps.len());
    for (rank, ep) in eps.into_iter().enumerate() {
        let (tx, rx) = channel::<WorkerCmd<T, Remap<T, C>>>();
        txs.push(tx);
        crate::transport::note_rank_thread_spawn();
        workers.push(
            thread::Builder::new()
                .name(format!("engine-rank-{rank}"))
                .stack_size(8 << 20)
                .spawn(move || worker_loop(rank, ep, rx, park))
                .expect("spawn engine worker"),
        );
    }
    (txs, workers)
}

/// The worker body: admit commands, round-robin poll the in-flight
/// cursors with non-blocking steps, park per policy when nothing moved.
/// Fused runs pack into (and recycle) worker-local pooled segment
/// buffers, so steady-state fused traffic allocates nothing per batch.
fn worker_loop<T: Elem, C: Transport<T>>(
    rank: usize,
    mut ep: C,
    rx: Receiver<WorkerCmd<T, C>>,
    park: ParkPolicy,
) {
    let mut active: Vec<ActiveOp<T>> = Vec::new();
    let mut seg_pool: Vec<Vec<T>> = Vec::new();
    let mut shutting_down = false;
    let mut surrender: Option<Sender<Surrendered<C>>> = None;
    loop {
        // Admit work. With nothing in flight, block on the queue (no
        // busy-wait while idle); otherwise drain whatever is ready.
        if active.is_empty() {
            if shutting_down {
                break;
            }
            match rx.recv() {
                Ok(cmd) => admit(
                    cmd,
                    &mut active,
                    &mut seg_pool,
                    &mut ep,
                    rank,
                    &mut shutting_down,
                    &mut surrender,
                ),
                Err(_) => break, // engine dropped the sender: exit
            }
        }
        loop {
            match rx.try_recv() {
                Ok(cmd) => admit(
                    cmd,
                    &mut active,
                    &mut seg_pool,
                    &mut ep,
                    rank,
                    &mut shutting_down,
                    &mut surrender,
                ),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        // One non-blocking poll pass over every in-flight op. An op whose
        // peer messages have arrived advances (possibly several rounds);
        // ops waiting on slower peers stay put — that is what lets a
        // later small op complete before an earlier big one.
        let now = Instant::now();
        let timeout = ep.timeout();
        let mut made_progress = false;
        // Fast-fail on positive peer death: the transport's health bitmap
        // (fed by reader-thread EOF notices or fault-injected kills —
        // updated as the poll steps below drain the inbox) marks dead
        // ranks, and any op whose *remaining* schedule touches one can
        // never complete — fail it with RankDown now instead of burning
        // its liveness watchdog. Ops that no longer need the dead rank
        // keep running: the circulant pattern fixes each round's peers,
        // so this is a per-op decision, not an engine-wide abort.
        let status = ep.peer_status();
        let any_down = status.iter().any(|&up| !up);
        active.retain_mut(|a| {
            if any_down {
                if let Some(peer) = a.driver.first_needed_down_peer(rank, &status) {
                    let detail = ep
                        .peer_down(peer)
                        .unwrap_or_else(|| "peer reported down".to_string());
                    a.driver.abort(&mut ep);
                    cleanup_failed_op(&mut ep, &mut a.buf, a.driver.op_tag());
                    a.finish_err(rank, CollectiveError::RankDown { rank, peer, detail });
                    made_progress = true;
                    return false;
                }
            }
            match a.driver.step(&mut ep, a.op.as_ref(), &mut a.buf) {
                Ok(Progress::Done) => {
                    made_progress = true;
                    if let Some(segment) = a.finish_ok(rank) {
                        recycle_segment(&mut seg_pool, segment);
                    }
                    false
                }
                Ok(Progress::Pending) => {
                    let progress = a.driver.progress();
                    if progress != a.last_progress {
                        a.last_progress = progress;
                        a.deadline = now + timeout;
                        made_progress = true;
                        true
                    } else if now >= a.deadline {
                        // Liveness watchdog: the blocking executor's
                        // recv/ack timeouts, ported to the polled world.
                        let err = a.driver.timeout_error(rank);
                        a.driver.abort(&mut ep);
                        cleanup_failed_op(&mut ep, &mut a.buf, a.driver.op_tag());
                        a.finish_err(rank, err);
                        made_progress = true;
                        false
                    } else {
                        true
                    }
                }
                Err(e) => {
                    // step() already quiesced this op's publishes
                    // (bounded by ep.timeout); if that quiesce itself
                    // timed out the buffer is not safe to free.
                    cleanup_failed_op(&mut ep, &mut a.buf, a.driver.op_tag());
                    made_progress = true;
                    // A send/recv that hit a positively-dead peer is the
                    // same failure class as the bitmap fast-fail above —
                    // surface it under the one RankDown taxonomy.
                    let e = match e {
                        CollectiveError::Transport(TransportError::PeerDown {
                            peer,
                            detail,
                            ..
                        }) => CollectiveError::RankDown { rank, peer, detail },
                        other => other,
                    };
                    a.finish_err(rank, e);
                    false
                }
            }
        });
        if !active.is_empty() && !made_progress {
            park.park();
        }
    }
    // A surrendering worker hands its endpoint — and the counters only
    // the owning thread could read — back to the engine for the
    // reconfiguration round / shutdown-time aggregation.
    if let Some(give) = surrender {
        let stale_frames = ep.stale_frames_dropped();
        let _ = give.send(Surrendered { ep, stale_frames });
    }
}

/// Failure-path teardown for one op on one endpoint, in two steps.
///
/// **Quarantine:** if the op's quiesce (`finish_op`) *timed out*, the
/// rendezvous contract is void — a merely-stalled (not dead) peer may
/// still hold `RemoteSlices` descriptors into the working vector, so
/// freeing it would be a use-after-free on the peer's side
/// (`crate::transport` docs, "Rendezvous safety contract"). Deliberately
/// leak the allocation for the process lifetime instead: a bounded leak
/// on an already-failed op (each has burned its 30s watchdog) in
/// exchange for unconditional memory safety. The handle receives the
/// error, so nothing observes the emptied buffer.
///
/// **Forget:** then drop every remaining wire artifact of the epoch
/// (stashed payloads completed back to their senders, stale pending-ack
/// entries removed), so repeated failures cannot grow the persistent
/// endpoint's stash without bound.
fn cleanup_failed_op<T: Elem, C: Transport<T>>(ep: &mut C, buf: &mut Vec<T>, op_tag: u64) {
    if ep.op_has_pending_publish(op_tag) {
        std::mem::forget(std::mem::take(buf));
    }
    ep.forget_op(op_tag);
}

fn admit<T: Elem, C: Transport<T>>(
    cmd: WorkerCmd<T, C>,
    active: &mut Vec<ActiveOp<T>>,
    seg_pool: &mut Vec<Vec<T>>,
    ep: &mut C,
    rank: usize,
    shutting_down: &mut bool,
    surrender: &mut Option<Sender<Surrendered<C>>>,
) {
    match cmd {
        WorkerCmd::Op(op) => {
            let deadline = Instant::now() + ep.timeout();
            active.push(ActiveOp {
                driver: Driver::Plain { cursor: OpCursor::new(op.op_tag, 0), plan: op.plan },
                op: op.op,
                buf: op.buf,
                kind: ActiveKind::Single { done: op.done, shared: op.shared },
                last_progress: 0,
                deadline,
            });
        }
        WorkerCmd::Pipelined(pl) => {
            // Large-message tier: one op epoch, the working vector split
            // into chunks that each run the circulant schedule on their
            // own round-offset Tags. The sliding window inside
            // `PipelinedCursor` keeps later chunks' sends overlapping
            // earlier chunks' combines.
            let deadline = Instant::now() + ep.timeout();
            active.push(ActiveOp {
                driver: Driver::Pipelined(PipelinedCursor::new(
                    pl.op_tag,
                    pl.chunks,
                    DEFAULT_PIPELINE_WINDOW,
                )),
                op: pl.op,
                buf: pl.buf,
                kind: ActiveKind::Single { done: pl.done, shared: pl.shared },
                last_progress: 0,
                deadline,
            });
        }
        WorkerCmd::Fused(f) => {
            // Pack this rank's member inputs into a pooled segment buffer
            // (strided gather, block-major layout) — parallel across the
            // p workers — then drive the fused run like any other op.
            let mut buf = take_segment(seg_pool, f.layout.total);
            buf.resize(f.layout.total, T::default());
            for (j, share) in f.shares.iter().enumerate() {
                kernels::pack_segments(&mut buf, &share.buf, &f.layout.spans[j]);
            }
            let deadline = Instant::now() + ep.timeout();
            active.push(ActiveOp {
                driver: Driver::Plain { cursor: OpCursor::new(f.op_tag, 0), plan: f.plan },
                op: f.op,
                buf,
                kind: ActiveKind::Fused {
                    allreduce: f.allreduce,
                    layout: f.layout,
                    shares: f.shares,
                },
                last_progress: 0,
                deadline,
            });
        }
        WorkerCmd::Job(job) => {
            // Jobs run inline and may block on collectives of their own
            // (epoch 0); the launcher only uses them on an otherwise-idle
            // engine.
            let out = (job.run)(rank, ep);
            let _ = job.done.send((rank, out));
        }
        WorkerCmd::Shutdown => *shutting_down = true,
        WorkerCmd::Surrender(give) => {
            // Shutdown semantics first — settle the in-flight ops — then
            // the worker's epilogue hands the endpoint back instead of
            // dropping it.
            *shutting_down = true;
            *surrender = Some(give);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::SumOp;

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<i64>> {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        (0..p).map(|_| crate::datatypes::elem::int_vec(&mut rng, m, -8, 9)).collect()
    }

    fn oracle_sum(inputs: &[Vec<i64>]) -> Vec<i64> {
        let mut acc = vec![0i64; inputs[0].len()];
        for v in inputs {
            SumOp.combine(&mut acc, v);
        }
        acc
    }

    #[test]
    fn single_op_round_trip() {
        let p = 4;
        let m = 37;
        let inputs = int_inputs(p, m, 7);
        let want = oracle_sum(&inputs);
        let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
        let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
        let out = handle.wait().unwrap();
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &want, "rank {r}");
        }
        engine.shutdown();
    }

    #[test]
    fn pipelined_dispatch_matches_plain() {
        // 4096 i64 = 32 KiB with an 8 KiB chunk budget → 4 chunks; the
        // 1 KiB min-bytes threshold forces the pipelined tier while the
        // fusion budget (64 KiB default) would otherwise have claimed it,
        // so this also checks pipeline-vs-fusion precedence.
        let p = 4;
        let m = 4096;
        let inputs = int_inputs(p, m, 21);
        let want = oracle_sum(&inputs);
        let mut engine = CollectiveEngine::<i64>::new(
            EngineConfig::new(p).pipeline_min_bytes(1024).pipeline_chunk_bytes(8192),
        );
        let out = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &want, "rank {r}");
        }
        assert_eq!(engine.fusion_stats().pipelined_ops, 1);
        // Below the min-bytes threshold the same engine falls back to the
        // small/medium tiers — the pipelined counter must not move.
        let small = int_inputs(p, 16, 22);
        let want_small = oracle_sum(&small);
        let out = engine.submit(OpRequest::allreduce(small, "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want_small);
        assert_eq!(engine.fusion_stats().pipelined_ops, 1);
        engine.shutdown();
    }

    #[test]
    fn park_policy_round_trips() {
        for policy in [ParkPolicy::Spin, ParkPolicy::Yield, ParkPolicy::Sleep] {
            assert_eq!(ParkPolicy::parse(policy.name()), Some(policy));
        }
        assert_eq!(ParkPolicy::parse("nap"), None);
    }

    #[test]
    fn submit_validates_requests() {
        let p = 3;
        let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
        // wrong rank count
        let err = engine.submit(OpRequest::allreduce(int_inputs(2, 8, 1), "sum")).unwrap_err();
        assert!(matches!(err, EngineError::WrongRankCount { got: 2, .. }), "{err}");
        // ragged inputs
        let mut ragged = int_inputs(p, 8, 2);
        ragged[1].pop();
        let err = engine.submit(OpRequest::allreduce(ragged, "sum")).unwrap_err();
        assert!(matches!(err, EngineError::RaggedInputs { rank: 1, .. }), "{err}");
        // bad counts
        let err = engine
            .submit(OpRequest::reduce_scatter_counts(int_inputs(p, 8, 3), vec![1, 2, 3], "sum"))
            .unwrap_err();
        assert!(matches!(err, EngineError::BadCounts { got: 8, want: 6 }), "{err}");
        // counts-vector length mismatch gets its own diagnostic (not the
        // misleading wrong-rank-count-of-inputs message)
        let err = engine
            .submit(OpRequest::reduce_scatter_counts(int_inputs(p, 8, 3), vec![4, 4], "sum"))
            .unwrap_err();
        assert!(matches!(err, EngineError::BadCountsLen { got: 2, .. }), "{err}");
        // unknown op
        let err = engine.submit(OpRequest::allreduce(int_inputs(p, 8, 4), "xor")).unwrap_err();
        assert!(matches!(err, EngineError::UnknownOp { .. }), "{err}");
        // the engine must still be healthy after rejected submissions
        let want = oracle_sum(&int_inputs(p, 8, 5));
        let out =
            engine.submit(OpRequest::allreduce(int_inputs(p, 8, 5), "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want);
        engine.shutdown();
    }

    #[test]
    fn drain_shutdown_completes_in_flight_and_rejects_new() {
        let p = 2;
        let inputs = int_inputs(p, 16, 9);
        let want = oracle_sum(&inputs);
        let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
        let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
        engine.drain_shutdown();
        // New work is rejected …
        let err = engine.submit(OpRequest::allreduce(int_inputs(p, 16, 10), "sum")).unwrap_err();
        assert!(matches!(err, EngineError::ShutDown), "{err}");
        // … but the already-submitted op completed, not errored.
        let out = handle.wait().unwrap();
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf, &want, "rank {r}");
        }
        engine.drain_shutdown(); // idempotent
    }

    #[test]
    fn config_carries_retry_and_backpressure_knobs() {
        let cfg = EngineConfig::new(2)
            .retry(7, 40)
            .backpressure_timeout(Duration::from_secs(3));
        assert_eq!((cfg.retry_attempts, cfg.retry_base_ms), (7, 40));
        assert_eq!(cfg.backpressure_timeout, Duration::from_secs(3));
        // Defaults resolve from the process knob set.
        let cfg = EngineConfig::new(2);
        let knobs = crate::env_knobs::knobs();
        assert_eq!(cfg.retry_attempts, knobs.retry_attempts);
        assert_eq!(cfg.retry_base_ms, knobs.retry_base_ms);
        assert_eq!(
            cfg.backpressure_timeout,
            Duration::from_secs(knobs.engine_backpressure_timeout_secs)
        );
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_safe() {
        let mut engine = CollectiveEngine::<f32>::new(EngineConfig::new(2));
        engine.shutdown();
        engine.shutdown();
        let err = engine.submit(OpRequest::allreduce(vec![vec![0.0f32; 4]; 2], "sum")).unwrap_err();
        assert!(matches!(err, EngineError::ShutDown), "{err}");
        drop(engine); // Drop after shutdown must be a no-op
    }

    #[test]
    fn recover_reforms_over_survivors_and_bumps_generation() {
        use crate::transport::fault::{FaultPlan, FaultTransport};
        let p = 4;
        let plan = FaultPlan::new(11).kill_rank(3, 3);
        let transports: Vec<_> = network_typed::<i64>(p)
            .into_iter()
            .map(|ep| FaultTransport::new(ep, plan.clone()))
            .collect();
        let mut engine = CollectiveEngine::<i64, _>::with_transports(
            EngineConfig::new(p).op_timeout(Duration::from_millis(500)),
            transports,
        );
        // Op epochs 1 and 2 flow; epoch 3 trips the kill.
        for seed in [1u64, 2] {
            let inputs = int_inputs(p, 16, seed);
            let want = oracle_sum(&inputs);
            let out =
                engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
            assert_eq!(out[0], want);
        }
        let err = engine
            .submit(OpRequest::allreduce(int_inputs(p, 16, 3), "sum"))
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(
            matches!(
                err,
                EngineError::Collective { source: CollectiveError::RankDown { .. }, .. }
            ),
            "{err}"
        );
        let report = engine.recover().unwrap();
        assert_eq!((report.p, report.generation), (3, 1));
        assert_eq!(report.failed, vec![3]);
        assert_eq!(engine.p(), 3);
        assert_eq!(engine.generation(), 1);
        assert_eq!(engine.recoveries(), 1);
        assert_eq!(engine.peer_health(), vec![true, true, true, false]);
        assert_eq!(engine.live_ranks().to_vec(), vec![0, 1, 2]);
        // Post-recovery ops run over p′ = 3 and must be bit-exact
        // against a fresh 3-rank oracle.
        for seed in [5u64, 6, 7] {
            let inputs = int_inputs(3, 16, seed);
            let want = oracle_sum(&inputs);
            let out =
                engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "rank {r}");
            }
        }
        assert_eq!(engine.in_flight(), 0, "no in-flight slot leaked across recovery");
        assert_eq!(engine.recovered_ops(), 3);
        engine.shutdown();
    }

    #[test]
    fn spurious_recover_keeps_the_world_and_bumps_generation() {
        let p = 3;
        let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(p));
        let inputs = int_inputs(p, 8, 1);
        let want = oracle_sum(&inputs);
        let out = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want);
        // Nobody died: the world survives intact, but the generation
        // still bumps — the op-sequence allocator restarted, and
        // (generation, seq) wire tags must never repeat.
        let report = engine.recover().unwrap();
        assert_eq!((report.p, report.generation), (p, 1));
        assert!(report.failed.is_empty());
        assert_eq!(engine.peer_health(), vec![true; p]);
        let inputs = int_inputs(p, 8, 2);
        let want = oracle_sum(&inputs);
        let out = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want);
        engine.shutdown();
    }

    #[test]
    fn recover_after_shutdown_is_refused() {
        let mut engine = CollectiveEngine::<i64>::new(EngineConfig::new(2));
        engine.shutdown();
        let err = engine.recover().unwrap_err();
        assert!(matches!(err, EngineError::ShutDown), "{err}");
    }
}
