//! The fusion tier: coalesce compatible in-flight small collectives into
//! **one** circulant run.
//!
//! The paper's schedules are round-optimal per collective — `⌈log₂ p⌉`
//! rounds, `p−1` blocks — but for the tiny payloads that dominate serving
//! traffic the fixed per-round latency swamps the volume term: N small
//! allreduces as N separate runs pay `N·⌈log₂ p⌉` round latencies for
//! work that fits in one. The schedule is indifferent to how the vector
//! is composed (⊕ is elementwise), so a batch of compatible operations
//! can execute as a single fused collective — the classic message-
//! aggregation lever, applied at the engine's submission seam.
//!
//! # How a batch forms and flushes
//!
//! A [`Fuser`] sits ahead of the per-worker submission queues. A
//! submitted op joins the pending batch iff it has the same collective
//! kind (allreduce / regular reduce-scatter), the same ⊕ name, and fits
//! the byte budget; `ReduceScatterCounts` and ops larger than the budget
//! **bypass** the batcher (for a large op, one extra fused pack/scatter
//! copy costs more than the rounds it saves — fusion would be a
//! pessimization). The pending batch is flushed when:
//!
//!  * adding the next op would exceed the byte budget
//!    ([`EngineConfig::fusion_max_bytes`](super::EngineConfig)), or
//!  * an incompatible op arrives, or
//!  * the **flush window** expires — measured in *completed engine
//!    steps* (operations finished since the batch opened,
//!    [`EngineConfig::fusion_window`](super::EngineConfig)), not
//!    wall-clock, so an idle engine burns no timer and a busy engine
//!    flushes at a rate proportional to its own throughput; there is no
//!    timer thread, so expiry is checked at every submit and every
//!    handle wait ([`Fuser::flush_if_stale`]), or
//!  * a member's [`OpHandle`](super::OpHandle) is waited on (the handle
//!    force-flushes, so batching can never deadlock a caller), or
//!  * the engine shuts down or parks on `queue_depth` backpressure (a
//!    batched op occupies an in-flight slot but cannot complete until
//!    dispatched).
//!
//! A 1-member "batch" is dispatched through the ordinary unfused path —
//! pack/scatter would be pure overhead.
//!
//! # The fused run
//!
//! Member inputs are packed **block-major**: for each owner block `g`,
//! every member's block `g` (of its own regular partition) lands
//! consecutively, so the fused [`BlockPartition`] — per-block counts
//! summed across members — keeps each constituent op's blocks whole on
//! their owning ranks. Rank `r` packs its members' inputs into a pooled
//! segment buffer with [`crate::ops::kernels::pack_segments`], the whole
//! batch runs as one tagged operation (one wire epoch per fused run)
//! through the same [`OpCursor`](crate::collectives::exec::OpCursor)
//! worker path as any other op, and the result segments are scattered
//! back per member with exact per-op offsets
//! ([`crate::ops::kernels::scatter_segments`]) — every span for a fused
//! allreduce, the owned-block span for a fused reduce-scatter. Fused
//! plans are memoized in the engine's [`PlanCache`] under the fused
//! partition's fingerprint, which *is* the batch-shape fingerprint
//! (kind + member-length sequence determine it), so repeated traffic
//! mixes hit cache.
//!
//! Each member's handle resolves independently. A failed fused run fails
//! **every** member, each with the fusion tag in its diagnostic
//! ([`CollectiveError::FusedBatch`]); a batch that cannot even be
//! delivered (a worker died mid-fan-out) rolls back all members'
//! undelivered rank shares so no in-flight slot leaks — the PR-4 partial
//! fan-out reasoning extended to fused epochs.
//!
//! # Correctness caveat (commutativity over fused segments)
//!
//! Fusing changes which *fused block* an element lives in, so the ⊕
//! application order for a given element can differ from its unfused
//! run's order. For the wrapping-integer dtypes ⊕ is exactly
//! associative and commutative, so fused results are bit-identical to
//! unfused (asserted by `rust/tests/fusion.rs`); float results remain
//! deterministic per batch shape but may round differently than the
//! unfused run — same caveat class as the schedule's own commutativity
//! assumption (paper §2.1).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use crate::collectives::exec::CollectiveError;
use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
use crate::collectives::CirculantPlans;
use crate::datatypes::{BlockPartition, Elem};
use crate::ops::kernels::SegmentSpan;
use crate::ops::ReduceOp;
use crate::schedule::{Plan, PlanCache, PlanKey};

use super::{
    CollectiveKind, DoneRx, DoneTx, EngineError, InflightCounter, InflightTags, OpShared,
    PipelinedRankOp, RankOp, StepCounter, WorkerCmd,
};

/// Default fusion byte budget: 64 KiB of member payload per batch. Small
/// enough that a fused run stays latency-bound (the regime where fusion
/// wins), large enough to coalesce dozens of KiB-scale ops. Override with
/// `CCOLL_FUSION_MAX_BYTES` / `engine.fusion.max_bytes`.
pub const DEFAULT_FUSION_MAX_BYTES: usize = 64 * 1024;

/// Default flush window: a pending batch waits at most this many
/// completed engine steps for more members. Override with
/// `CCOLL_FUSION_WINDOW` / `engine.fusion.window`; 0 disables fusion.
pub const DEFAULT_FUSION_WINDOW: u64 = 8;

/// Default pipelining threshold: allreduces of at least 1 MiB payload run
/// through the chunked large-message tier. Below it the per-chunk round
/// latency `α·(n_c − 1)` is not paid back by the hidden combine time (see
/// [`crate::sim::closed_form::pipelined_circulant_allreduce`]). Override
/// with `CCOLL_PIPELINE_MIN_BYTES` / `engine.pipeline.min_bytes`; 0
/// disables the tier.
pub const DEFAULT_PIPELINE_MIN_BYTES: usize = 1 << 20;

/// Default pipelined chunk size: 256 KiB per chunk epoch. Large enough
/// that each chunk's wire time dominates its round latency, small enough
/// that several chunks are in flight for any payload over the 1 MiB
/// threshold. Override with `CCOLL_PIPELINE_CHUNK_BYTES` /
/// `engine.pipeline.chunk_bytes`; 0 disables the tier.
pub const DEFAULT_PIPELINE_CHUNK_BYTES: usize = 1 << 18;

/// Why a pending batch was flushed (each maps to a [`FusionStats`]
/// counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum FlushReason {
    /// The byte budget was reached (or the next op would exceed it).
    Budget,
    /// The completed-step window expired.
    Window,
    /// An incompatible operation arrived.
    Incompatible,
    /// A member handle was waited on, the engine parked on backpressure,
    /// or the engine is shutting down.
    Forced,
}

/// Counters of the fusion tier's behavior, snapshot via
/// [`CollectiveEngine::fusion_stats`](super::CollectiveEngine::fusion_stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FusionStats {
    /// Fused runs dispatched (batches of ≥ 2 members).
    pub batches: u64,
    /// Member operations carried by those fused runs.
    pub fused_ops: u64,
    /// Member payload bytes packed through fused runs.
    pub fused_bytes: u64,
    /// 1-member batches dispatched through the unfused path.
    pub single_flushes: u64,
    /// Ops over the byte budget that bypassed the batcher.
    pub bypass_large: u64,
    /// Non-fusible kinds (`ReduceScatterCounts`) that bypassed it.
    pub bypass_kind: u64,
    /// Fused-plan cache hits (the batch shape was seen before).
    pub plan_hits: u64,
    /// Fused-plan cache misses (a new batch shape built its schedule).
    pub plan_misses: u64,
    /// Flushes triggered by the byte budget.
    pub flush_budget: u64,
    /// Flushes triggered by the completed-step window.
    pub flush_window: u64,
    /// Flushes triggered by an incompatible arrival.
    pub flush_incompatible: u64,
    /// Forced flushes (handle wait, backpressure, shutdown).
    pub flush_forced: u64,
    /// Allreduces dispatched through the pipelined large-message tier.
    pub pipelined_ops: u64,
}

impl FusionStats {
    /// Mean members per fused run (0 when nothing fused).
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.fused_ops as f64 / self.batches as f64
        }
    }
}

/// Pack/scatter geometry of one fused batch, shared by every rank:
/// `spans[j][g]` maps member `j`'s elements of owner block `g` to their
/// offset in the fused vector. The spans of all members tile the fused
/// vector exactly once.
#[derive(Debug)]
pub(crate) struct FusedLayout {
    pub(crate) spans: Vec<Vec<SegmentSpan>>,
    pub(crate) total: usize,
}

impl FusedLayout {
    /// Derive the block-major layout and the fused partition (per-block
    /// counts summed across members) from the members' own partitions.
    pub(super) fn new(parts: &[BlockPartition], p: usize) -> (Self, BlockPartition) {
        let mut counts = vec![0usize; p];
        for part in parts {
            for (g, c) in counts.iter_mut().enumerate() {
                *c += part.size(g);
            }
        }
        let fused = BlockPartition::from_counts(&counts);
        let mut spans: Vec<Vec<SegmentSpan>> =
            (0..parts.len()).map(|_| Vec::with_capacity(p)).collect();
        let mut cursor: Vec<usize> = (0..p).map(|g| fused.range(g).start).collect();
        for g in 0..p {
            for (j, part) in parts.iter().enumerate() {
                spans[j].push((part.range(g), cursor[g]));
                cursor[g] += part.size(g);
            }
        }
        (Self { spans, total: fused.total() }, fused)
    }
}

/// One rank's share of one member op inside a fused run: the member's
/// input vector for that rank (scatter-back target) plus its completion
/// plumbing.
pub(crate) struct FusedShare<T: Elem> {
    pub(crate) buf: Vec<T>,
    pub(crate) done: DoneTx<T>,
    pub(crate) shared: Arc<OpShared>,
}

/// The fused command one worker receives: pack `shares` into a segment
/// buffer per `layout`, drive the fused plan under `op_tag`, scatter the
/// results back.
pub(crate) struct FusedRankOp<T: Elem> {
    pub(crate) op_tag: u64,
    pub(crate) plan: Arc<Plan>,
    pub(crate) op: Arc<dyn ReduceOp<T>>,
    pub(crate) allreduce: bool,
    pub(crate) layout: Arc<FusedLayout>,
    pub(crate) shares: Vec<FusedShare<T>>,
}

/// A batched member op awaiting flush.
struct Member<T: Elem> {
    op_id: u64,
    m: usize,
    inputs: Vec<Vec<T>>,
    done: DoneTx<T>,
    shared: Arc<OpShared>,
}

/// The open batch: compatible members accumulated since `opened_at`
/// completed engine steps.
struct PendingBatch<T: Elem> {
    allreduce: bool,
    op_name: String,
    op: Arc<dyn ReduceOp<T>>,
    members: Vec<Member<T>>,
    bytes: usize,
    opened_at: u64,
}

/// The batching stage + submission fan-out. Shared as
/// `Arc<Mutex<Fuser<T, C>>>` between the engine (submit, shutdown) and
/// every [`OpHandle`](super::OpHandle) (force-flush on wait); workers
/// never touch it. `C` is the engine's transport backend — the fuser
/// never calls transport methods itself (it only feeds the per-worker
/// command queues), so it carries the parameter without a
/// [`crate::transport::Transport`] bound.
pub(crate) struct Fuser<T: Elem, C = crate::transport::Endpoint<T>> {
    p: usize,
    vocab: CirculantPlans,
    txs: Vec<Sender<WorkerCmd<T, C>>>,
    plans: Arc<PlanCache>,
    inflight: InflightCounter,
    completed: StepCounter,
    /// Live op-id set shared with the engine — every submitted member
    /// registers here (via [`OpShared::new`]) and deregisters when its
    /// last rank share settles, so backpressure diagnostics can name the
    /// stuck operations.
    inflight_tags: InflightTags,
    /// Next operation epoch (starts at 1; epoch 0 is the legacy untagged
    /// wire space). Single ops run under their own id; each fused run
    /// takes one fresh epoch for the whole batch.
    next_op: u64,
    /// Generation epoch composed into every allocated op id
    /// ([`crate::transport::compose_op`]). 0 before any recovery — the
    /// composed id is then the bare sequence number, bit-identical to the
    /// pre-recovery wire format. The engine's reconfiguration round bumps
    /// it so post-recovery traffic can never cross-match pre-failure
    /// frames.
    generation: u64,
    enabled: bool,
    max_bytes: usize,
    window: u64,
    /// Allreduce payloads of at least this many bytes dispatch through
    /// the pipelined tier (0 disables it).
    pipeline_min_bytes: usize,
    /// Chunk-epoch size for the pipelined tier, in bytes (0 disables it).
    pipeline_chunk_bytes: usize,
    pending: Option<PendingBatch<T>>,
    stats: FusionStats,
    pub(super) shut_down: bool,
}

impl<T: Elem, C> Fuser<T, C> {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(
        p: usize,
        vocab: CirculantPlans,
        txs: Vec<Sender<WorkerCmd<T, C>>>,
        plans: Arc<PlanCache>,
        inflight: InflightCounter,
        completed: StepCounter,
        inflight_tags: InflightTags,
        enabled: bool,
        max_bytes: usize,
        window: u64,
        pipeline_min_bytes: usize,
        pipeline_chunk_bytes: usize,
    ) -> Self {
        Self {
            p,
            vocab,
            txs,
            plans,
            inflight,
            completed,
            inflight_tags,
            next_op: 1,
            generation: 0,
            // window == 0 means "flush on every submit": batching never
            // coalesces anything, so treat it as fusion-off outright.
            enabled: enabled && window > 0,
            max_bytes,
            window,
            pipeline_min_bytes,
            pipeline_chunk_bytes,
            pending: None,
            stats: FusionStats::default(),
            shut_down: false,
        }
    }

    pub(super) fn stats(&self) -> FusionStats {
        self.stats
    }

    /// Stamp this fuser's op ids with a generation epoch (the sequence
    /// counter restarts: a fresh fuser is built per reconfiguration, so
    /// `(generation, seq)` pairs never repeat).
    pub(super) fn set_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    fn alloc_op(&mut self) -> u64 {
        let id = self.next_op;
        self.next_op += 1;
        crate::transport::compose_op(self.generation, id)
    }

    /// Whether `op_id` is sitting in the pending batch (so its handle
    /// must force a flush before waiting).
    pub(super) fn pending_contains(&self, op_id: u64) -> bool {
        self.pending.as_ref().is_some_and(|b| b.members.iter().any(|m| m.op_id == op_id))
    }

    /// Flush the pending batch if its completed-step window has expired.
    /// The window has no timer thread behind it: it is enforced at every
    /// engine interaction — each submit (fusible or not) and, via this
    /// hook, each [`OpHandle::wait`](super::OpHandle::wait) — so a batch
    /// cannot outlive its window while anyone is observing the engine.
    pub(super) fn flush_if_stale(&mut self) {
        if let Some(b) = &self.pending {
            if self.completed.load(Ordering::Acquire).saturating_sub(b.opened_at) >= self.window {
                self.flush(FlushReason::Window);
            }
        }
    }

    /// Admit one validated operation: batch it when eligible, otherwise
    /// dispatch it unfused (flushing the pending batch first so it is
    /// never starved by incompatible traffic). Returns the op id and the
    /// handle's receiving end.
    pub(super) fn submit_op(
        &mut self,
        kind: CollectiveKind,
        op_name: &str,
        op: Arc<dyn ReduceOp<T>>,
        inputs: Vec<Vec<T>>,
        m: usize,
    ) -> Result<(u64, DoneRx<T>), EngineError> {
        if self.shut_down {
            return Err(EngineError::ShutDown);
        }
        let op_id = self.alloc_op();
        let (tx, rx) = channel();
        let shared = Arc::new(OpShared::new(
            self.p,
            op_id,
            self.inflight.clone(),
            self.completed.clone(),
            self.inflight_tags.clone(),
        ));
        self.inflight.fetch_add(1, Ordering::AcqRel);

        let bytes = m.saturating_mul(std::mem::size_of::<T>());
        let allreduce = match &kind {
            CollectiveKind::Allreduce => true,
            CollectiveKind::ReduceScatter => false,
            CollectiveKind::ReduceScatterCounts(_) => {
                if self.enabled {
                    self.stats.bypass_kind += 1;
                }
                self.flush(FlushReason::Incompatible);
                self.dispatch_single(op_id, &kind, op, inputs, tx, shared)?;
                return Ok((op_id, rx));
            }
        };
        // Size-adaptive dispatch, largest tier first: allreduces over the
        // pipeline threshold run chunked (the bandwidth end of the size
        // story), and only payloads below it fall through to the fusion /
        // plain decision. Reduce-scatters never pipeline: their output
        // layout is defined by the caller's partition, which a chunked
        // run would scatter.
        if allreduce && self.pipeline_min_bytes > 0 && bytes >= self.pipeline_min_bytes {
            let chunk_elems = self.pipeline_chunk_bytes / std::mem::size_of::<T>();
            // m < 2 chunks degenerates to a plain run — fall through.
            if chunk_elems > 0 && m / chunk_elems >= 2 {
                // A pending batch cannot hold this op; flush it so it is
                // never starved behind large traffic.
                self.flush(FlushReason::Budget);
                self.stats.pipelined_ops += 1;
                self.dispatch_pipelined(op_id, op, inputs, m, chunk_elems, tx, shared)?;
                return Ok((op_id, rx));
            }
        }
        if !self.enabled || bytes > self.max_bytes {
            if self.enabled {
                // An over-budget same-kind arrival is a budget-driven
                // flush (the batcher cannot hold it); with fusion off no
                // batch can exist, so no flush is needed at all.
                self.stats.bypass_large += 1;
                self.flush(FlushReason::Budget);
            }
            self.dispatch_single(op_id, &kind, op, inputs, tx, shared)?;
            return Ok((op_id, rx));
        }

        // Eligible: flush a pending batch this op cannot join, then join
        // (or open) the batch.
        if let Some(b) = &self.pending {
            let reason = if b.allreduce != allreduce || b.op_name != op_name {
                Some(FlushReason::Incompatible)
            } else if b.bytes + bytes > self.max_bytes {
                Some(FlushReason::Budget)
            } else if self.completed.load(Ordering::Acquire).saturating_sub(b.opened_at)
                >= self.window
            {
                Some(FlushReason::Window)
            } else {
                None
            };
            if let Some(r) = reason {
                self.flush(r);
            }
        }
        let opened_at = self.completed.load(Ordering::Acquire);
        let batch = self.pending.get_or_insert_with(|| PendingBatch {
            allreduce,
            op_name: op_name.to_string(),
            op,
            members: Vec::new(),
            bytes: 0,
            opened_at,
        });
        batch.members.push(Member { op_id, m, inputs, done: tx, shared });
        batch.bytes += bytes;
        if batch.bytes >= self.max_bytes {
            self.flush(FlushReason::Budget);
        }
        Ok((op_id, rx))
    }

    /// Dispatch the pending batch (if any) as one fused run — or through
    /// the unfused path when it holds a single member. Errors cannot be
    /// returned here (the members' handles are already out): a failed
    /// fan-out delivers a [`CollectiveError`] through every affected
    /// member's handle and rolls back the undelivered rank shares.
    pub(super) fn flush(&mut self, why: FlushReason) {
        let Some(batch) = self.pending.take() else { return };
        match why {
            FlushReason::Budget => self.stats.flush_budget += 1,
            FlushReason::Window => self.stats.flush_window += 1,
            FlushReason::Incompatible => self.stats.flush_incompatible += 1,
            FlushReason::Forced => self.stats.flush_forced += 1,
        }
        let p = self.p;
        let kind =
            if batch.allreduce { CollectiveKind::Allreduce } else { CollectiveKind::ReduceScatter };
        if batch.members.len() == 1 {
            // Pack/scatter for one op is pure overhead; run it unfused.
            self.stats.single_flushes += 1;
            let member = batch.members.into_iter().next().expect("one member");
            // The handle owns the error channel; dispatch_single already
            // routed per-rank errors there, so the Err return (which
            // submit would surface) is redundant here.
            let _ = self.dispatch_single(
                member.op_id,
                &kind,
                batch.op,
                member.inputs,
                member.done,
                member.shared,
            );
            return;
        }

        let k = batch.members.len();
        self.stats.batches += 1;
        self.stats.fused_ops += k as u64;
        self.stats.fused_bytes += batch.bytes as u64;
        let parts: Vec<BlockPartition> =
            batch.members.iter().map(|mm| BlockPartition::regular(p, mm.m)).collect();
        let (layout, fused_part) = FusedLayout::new(&parts, p);
        let layout = Arc::new(layout);
        let name = if batch.allreduce {
            self.vocab.allreduce.clone()
        } else {
            self.vocab.reduce_scatter.clone()
        };
        // The fused partition's fingerprint IS the batch-shape key:
        // (kind, ⊕-independent member-length sequence) determine it, so
        // repeated traffic mixes hit the same cached plan — and it shares
        // the engine's one plan-key space, so a fused batch whose layout
        // coincides with an unfused geometry reuses that plan too.
        let (plan, hit) = self.plan_for(name, &fused_part, batch.allreduce);
        if hit {
            self.stats.plan_hits += 1;
        } else {
            self.stats.plan_misses += 1;
        }
        let op_tag = self.alloc_op(); // one wire epoch for the whole fused run
        let mut per_rank: Vec<Vec<FusedShare<T>>> = (0..p).map(|_| Vec::with_capacity(k)).collect();
        for member in batch.members {
            for (r, buf) in member.inputs.into_iter().enumerate() {
                per_rank[r].push(FusedShare {
                    buf,
                    done: member.done.clone(),
                    shared: member.shared.clone(),
                });
            }
        }
        for rank in 0..p {
            let cmd = WorkerCmd::Fused(FusedRankOp {
                op_tag,
                plan: plan.clone(),
                op: batch.op.clone(),
                allreduce: batch.allreduce,
                layout: layout.clone(),
                shares: std::mem::take(&mut per_rank[rank]),
            });
            if let Err(undelivered) = self.txs[rank].send(cmd) {
                // A batch that cannot flush because a member's rank share
                // fails to deliver must roll back ALL members' in-flight
                // slots: recover this rank's shares from the bounced
                // command, then fail every still-undelivered rank share
                // of every member. Delivered ranks (< rank) complete or
                // watchdog out on their own and release the rest.
                if let WorkerCmd::Fused(f) = undelivered.0 {
                    per_rank[rank] = f.shares;
                }
                for (r, shares) in per_rank.iter().enumerate().skip(rank) {
                    for share in shares {
                        let _ = share.done.send((
                            r,
                            Err(CollectiveError::FusedBatch {
                                fused_op: op_tag,
                                members: k,
                                detail: format!(
                                    "worker {rank} gone before the fused run was delivered"
                                ),
                            }),
                        ));
                        share.shared.note_rank_done();
                    }
                }
                return;
            }
        }
    }

    /// The pipelined fan-out: split the working vector into chunk epochs
    /// ([`crate::collectives::pipeline_chunk_sizes`]), build one plan per
    /// *distinct* chunk length — at most two, since the remainder folds
    /// into the last chunk — and hand every worker a
    /// [`PipelinedRankOp`] under one op epoch. Dead-worker rollback
    /// mirrors [`Fuser::dispatch_single`].
    fn dispatch_pipelined(
        &mut self,
        op_tag: u64,
        op: Arc<dyn ReduceOp<T>>,
        inputs: Vec<Vec<T>>,
        m: usize,
        chunk_elems: usize,
        done: DoneTx<T>,
        shared: Arc<OpShared>,
    ) -> Result<(), EngineError> {
        let p = self.p;
        let sizes = crate::collectives::pipeline_chunk_sizes(m, chunk_elems);
        let mut chunks: Vec<(usize, Arc<Plan>)> = Vec::with_capacity(sizes.len());
        let mut offset = 0usize;
        let mut last: Option<(usize, Arc<Plan>)> = None;
        for len in sizes {
            let plan = match &last {
                Some((l, plan)) if *l == len => plan.clone(),
                _ => {
                    let part = BlockPartition::regular(p, len);
                    let (plan, _hit) = self.plan_for(self.vocab.allreduce.clone(), &part, true);
                    last = Some((len, plan.clone()));
                    plan
                }
            };
            chunks.push((offset, plan));
            offset += len;
        }
        debug_assert_eq!(offset, m);
        for (rank, buf) in inputs.into_iter().enumerate() {
            let cmd = WorkerCmd::Pipelined(PipelinedRankOp {
                op_tag,
                chunks: chunks.clone(),
                op: op.clone(),
                buf,
                done: done.clone(),
                shared: shared.clone(),
            });
            if self.txs[rank].send(cmd).is_err() {
                for r in rank..p {
                    let _ = done.send((r, Err(CollectiveError::WorkerLost { rank: r })));
                    shared.note_rank_done();
                }
                return Err(EngineError::WorkerGone { rank });
            }
        }
        Ok(())
    }

    /// The unfused fan-out (what `CollectiveEngine::submit` always did):
    /// one [`RankOp`] per worker under the op's own epoch. On a dead
    /// worker, every undelivered rank share is failed through the handle
    /// *and* rolled back, then the failing rank is reported.
    fn dispatch_single(
        &mut self,
        op_tag: u64,
        kind: &CollectiveKind,
        op: Arc<dyn ReduceOp<T>>,
        inputs: Vec<Vec<T>>,
        done: DoneTx<T>,
        shared: Arc<OpShared>,
    ) -> Result<(), EngineError> {
        let p = self.p;
        let m = inputs.first().map_or(0, Vec::len);
        let (algorithm, part, is_allreduce) = match kind {
            CollectiveKind::Allreduce => {
                (self.vocab.allreduce.clone(), BlockPartition::regular(p, m), true)
            }
            CollectiveKind::ReduceScatter => {
                (self.vocab.reduce_scatter.clone(), BlockPartition::regular(p, m), false)
            }
            CollectiveKind::ReduceScatterCounts(counts) => {
                (self.vocab.reduce_scatter.clone(), BlockPartition::from_counts(counts), false)
            }
        };
        let (plan, _hit) = self.plan_for(algorithm, &part, is_allreduce);
        for (rank, buf) in inputs.into_iter().enumerate() {
            let cmd = WorkerCmd::Op(RankOp {
                op_tag,
                plan: plan.clone(),
                op: op.clone(),
                buf,
                done: done.clone(),
                shared: shared.clone(),
            });
            if self.txs[rank].send(cmd).is_err() {
                for r in rank..p {
                    let _ = done.send((r, Err(CollectiveError::WorkerLost { rank: r })));
                    shared.note_rank_done();
                }
                return Err(EngineError::WorkerGone { rank });
            }
        }
        Ok(())
    }

    /// Memoized plan lookup shared by the fused and unfused paths — the
    /// skip sequence was validated at engine construction, so cache
    /// misses rebuild from it without re-deriving anything.
    fn plan_for(
        &mut self,
        algorithm: Arc<str>,
        part: &BlockPartition,
        is_allreduce: bool,
    ) -> (Arc<Plan>, bool) {
        let key = PlanKey::new(algorithm, self.p, part, T::DTYPE);
        let skips = self.vocab.skips.clone();
        let p = self.p;
        self.plans.get_or_build(key, part, move || {
            if is_allreduce {
                allreduce_schedule(p, &skips)
            } else {
                reduce_scatter_schedule(p, &skips)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CollectiveEngine, EngineConfig, OpRequest};
    use super::*;
    use crate::ops::SumOp;
    use std::time::Duration;

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<i64>> {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        (0..p).map(|_| crate::datatypes::elem::int_vec(&mut rng, m, -8, 9)).collect()
    }

    fn oracle_sum(inputs: &[Vec<i64>]) -> Vec<i64> {
        let mut acc = vec![0i64; inputs[0].len()];
        for v in inputs {
            SumOp.combine(&mut acc, v);
        }
        acc
    }

    /// Fusion on, with a window/budget so large that only forced flushes
    /// (handle waits) dispatch — deterministic batch composition.
    fn fused_cfg(p: usize) -> EngineConfig {
        EngineConfig::new(p).fusion(true).fusion_window(1_000_000).fusion_max_bytes(1 << 24)
    }

    #[test]
    fn layout_tiles_the_fused_vector_block_major() {
        let p = 3;
        let parts = [
            BlockPartition::regular(p, 7),
            BlockPartition::regular(p, 0),
            BlockPartition::regular(p, 4),
        ];
        let (layout, fused) = FusedLayout::new(&parts, p);
        assert_eq!(layout.total, 11);
        assert_eq!(fused.total(), 11);
        // Per-block counts sum across members.
        for g in 0..p {
            let want: usize = parts.iter().map(|pt| pt.size(g)).sum();
            assert_eq!(fused.size(g), want, "block {g}");
        }
        // Spans tile [0, total) exactly once.
        let mut covered = vec![false; layout.total];
        for spans in &layout.spans {
            assert_eq!(spans.len(), p);
            for (src, dst) in spans {
                for i in 0..src.len() {
                    assert!(!covered[dst + i], "offset {} covered twice", dst + i);
                    covered[dst + i] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c), "layout left a hole");
        // Each member's block g lands whole inside fused block g.
        for (j, spans) in layout.spans.iter().enumerate() {
            for (g, (src, dst)) in spans.iter().enumerate() {
                let fr = fused.range(g);
                assert!(
                    *dst >= fr.start && dst + src.len() <= fr.end,
                    "member {j} block {g} leaks out of fused block {g}"
                );
            }
        }
    }

    #[test]
    fn fused_batch_matches_oracle_and_counts_stats() {
        let p = 4;
        let mut engine = CollectiveEngine::<i64>::new(fused_cfg(p));
        let run_round = |engine: &mut CollectiveEngine<i64>, seed: u64| {
            let lens = [8usize, 16, 8, 16];
            let mut handles = Vec::new();
            let mut oracles = Vec::new();
            for (i, &m) in lens.iter().enumerate() {
                let inputs = int_inputs(p, m, seed + i as u64);
                oracles.push(oracle_sum(&inputs));
                handles.push(engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap());
            }
            for (i, h) in handles.into_iter().enumerate() {
                let out = h.wait().unwrap();
                for (r, buf) in out.iter().enumerate() {
                    assert_eq!(buf, &oracles[i], "op {i} rank {r}");
                }
            }
        };
        run_round(&mut engine, 100);
        let s = engine.fusion_stats();
        assert_eq!(s.batches, 1, "{s:?}");
        assert_eq!(s.fused_ops, 4, "{s:?}");
        assert_eq!(s.plan_misses, 1, "first batch shape builds its plan: {s:?}");
        assert_eq!(s.flush_forced, 1, "the first wait flushed: {s:?}");
        // The same shape again: the fused plan must be a cache hit.
        run_round(&mut engine, 200);
        let s = engine.fusion_stats();
        assert_eq!((s.batches, s.fused_ops), (2, 8), "{s:?}");
        assert_eq!(s.plan_hits, 1, "repeated batch shape must hit the plan cache: {s:?}");
        assert_eq!(s.single_flushes, 0, "{s:?}");
        engine.shutdown();
    }

    #[test]
    fn window_zero_disables_fusion_and_counts_bypass() {
        let p = 2;
        let mut engine =
            CollectiveEngine::<i64>::new(EngineConfig::new(p).fusion(true).fusion_window(0));
        let inputs = int_inputs(p, 8, 3);
        let want = oracle_sum(&inputs);
        let out = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want);
        let s = engine.fusion_stats();
        assert_eq!(s.batches, 0);
        assert_eq!(s.fused_ops, 0);
        engine.shutdown();
    }

    #[test]
    fn large_and_counts_ops_bypass_the_batcher() {
        let p = 2;
        // Budget of 64 bytes = 8 i64 elements.
        let mut engine = CollectiveEngine::<i64>::new(
            EngineConfig::new(p).fusion(true).fusion_window(1_000_000).fusion_max_bytes(64),
        );
        // 16 elems = 128 B > budget → bypass_large, runs unfused.
        let big = int_inputs(p, 16, 5);
        let want_big = oracle_sum(&big);
        let out = engine.submit(OpRequest::allreduce(big, "sum")).unwrap().wait().unwrap();
        assert_eq!(out[0], want_big);
        // Counts reduce-scatter → bypass_kind.
        let counts = vec![3usize, 5];
        let inputs = int_inputs(p, 8, 6);
        let want = oracle_sum(&inputs);
        let part = BlockPartition::from_counts(&counts);
        let out = engine
            .submit(OpRequest::reduce_scatter_counts(inputs, counts, "sum"))
            .unwrap()
            .wait()
            .unwrap();
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(&buf[part.range(r)], &want[part.range(r)], "rank {r}");
        }
        let s = engine.fusion_stats();
        assert_eq!(s.bypass_large, 1, "{s:?}");
        assert_eq!(s.bypass_kind, 1, "{s:?}");
        assert_eq!(s.batches, 0, "{s:?}");
        engine.shutdown();
    }

    #[test]
    fn budget_flushes_mid_stream_and_results_stay_exact() {
        let p = 2;
        // Budget 256 B = 32 i64 elems: three 16-elem ops → flush after 2.
        let mut engine = CollectiveEngine::<i64>::new(
            EngineConfig::new(p).fusion(true).fusion_window(1_000_000).fusion_max_bytes(256),
        );
        let mut handles = Vec::new();
        let mut oracles = Vec::new();
        for i in 0..3 {
            let inputs = int_inputs(p, 16, 40 + i);
            oracles.push(oracle_sum(&inputs));
            handles.push(engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap());
        }
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.wait().unwrap();
            assert_eq!(out[0], oracles[i], "op {i}");
        }
        let s = engine.fusion_stats();
        assert_eq!(s.flush_budget, 1, "{s:?}");
        assert_eq!(s.batches, 1, "{s:?}");
        assert_eq!(s.fused_ops, 2, "{s:?}");
        assert_eq!(s.single_flushes, 1, "the third op flushed alone on wait: {s:?}");
        engine.shutdown();
    }

    /// Kill one worker by sending it a direct Shutdown and waiting for
    /// its receiver to drop.
    fn kill_worker(engine: &CollectiveEngine<i64>, rank: usize) {
        let _ = engine.txs[rank].send(WorkerCmd::Shutdown);
        for _ in 0..20_000 {
            if engine.txs[rank].send(WorkerCmd::Shutdown).is_err() {
                return;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        panic!("worker {rank} did not exit");
    }

    #[test]
    fn flush_with_all_workers_dead_rolls_back_every_member() {
        let p = 3;
        let mut engine = CollectiveEngine::<i64>::new(fused_cfg(p));
        for r in 0..p {
            kill_worker(&engine, r);
        }
        let h1 = engine.submit(OpRequest::allreduce(int_inputs(p, 8, 1), "sum")).unwrap();
        let h2 = engine.submit(OpRequest::allreduce(int_inputs(p, 8, 2), "sum")).unwrap();
        assert_eq!(engine.in_flight(), 2, "both members occupy slots while batched");
        for h in [h1, h2] {
            let err = h.wait().unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("fused batch"), "diagnostic must carry the fusion tag: {msg}");
        }
        // The rollback must have released every member's in-flight slot.
        for _ in 0..10_000 {
            if engine.in_flight() == 0 {
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(engine.in_flight(), 0, "rolled-back members leaked in-flight slots");
        engine.shutdown();
    }

    #[test]
    fn partial_flush_failure_rolls_back_undelivered_shares() {
        let p = 3;
        let mut engine = CollectiveEngine::<i64>::new(
            fused_cfg(p).op_timeout(Duration::from_millis(300)),
        );
        kill_worker(&engine, p - 1);
        let h1 = engine.submit(OpRequest::allreduce(int_inputs(p, 8, 11), "sum")).unwrap();
        let h2 = engine.submit(OpRequest::allreduce(int_inputs(p, 8, 12), "sum")).unwrap();
        // Force the flush: ranks 0..p-1 receive the fused run; the dead
        // worker's shares are failed immediately, the delivered ranks
        // watchdog out (they need the dead peer), and EVERY member
        // resolves with the fusion tag in its diagnostic.
        for h in [h1, h2] {
            let err = h.wait().unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("fused batch"), "{msg}");
        }
        for _ in 0..50_000 {
            if engine.in_flight() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        assert_eq!(engine.in_flight(), 0, "partial fused fan-out leaked in-flight slots");
        engine.shutdown();
    }

    #[test]
    fn queue_depth_backpressure_flushes_the_pending_batch() {
        let p = 2;
        let depth = 2;
        let mut engine =
            CollectiveEngine::<i64>::new(fused_cfg(p).queue_depth(depth));
        let mut handles = Vec::new();
        let mut oracles = Vec::new();
        // Ops 1+2 fill the depth while batched; op 3's submit must flush
        // them (they can never complete unflushed) instead of timing out.
        for i in 0..5u64 {
            let inputs = int_inputs(p, 8, 60 + i);
            oracles.push(oracle_sum(&inputs));
            handles.push(engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap());
            assert!(engine.in_flight() <= depth, "depth bound violated");
        }
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.wait().unwrap();
            assert_eq!(out[0], oracles[i], "op {i}");
        }
        engine.shutdown();
    }

    #[test]
    fn shutdown_flushes_the_pending_batch_first() {
        let p = 2;
        let mut engine = CollectiveEngine::<i64>::new(fused_cfg(p));
        let inputs = int_inputs(p, 8, 77);
        let want = oracle_sum(&inputs);
        let handle = engine.submit(OpRequest::allreduce(inputs, "sum")).unwrap();
        engine.shutdown(); // must dispatch + drain the batched op, not strand it
        let out = handle.wait().unwrap();
        assert_eq!(out[0], want);
    }
}
