//! Process-wide `CCOLL_*` environment knobs, parsed **once** and validated
//! **loudly**.
//!
//! Before this module, each knob was re-read ad hoc at its point of use
//! and malformed values were silently swallowed by `.ok()`/`.unwrap_or()`
//! chains — `CCOLL_RENDEZVOUS_MIN_ELEMS=abc` quietly behaved like the
//! default, and `CCOLL_BENCH_FAST=true` quietly behaved like *off* (only
//! the literal `1` was recognized). Now every knob is parsed exactly once
//! per process into [`EnvKnobs`]; a value that does not parse aborts with
//! a message naming the variable, the offending value and the accepted
//! grammar, instead of running a long job under the wrong configuration.
//!
//! Knobs:
//!
//! | variable                     | type   | default | consumers |
//! |------------------------------|--------|---------|-----------|
//! | `CCOLL_NO_RENDEZVOUS`        | bool   | `0`     | transport tier-1 kill-switch |
//! | `CCOLL_RENDEZVOUS_MIN_ELEMS` | usize  | 256     | rendezvous small-payload threshold |
//! | `CCOLL_BENCH_FAST`           | bool   | `0`     | bench sweep shrinking |
//! | `CCOLL_BENCH_DTYPE`          | dtype  | `f32`   | element type of the T1/T2 benches |
//! | `CCOLL_PJRT_CHUNK`           | usize? | unset   | PJRT engine chunk-bucket override |
//! | `CCOLL_ENGINE_QUEUE_DEPTH`   | usize  | `0`     | engine in-flight op cap (0 = unbounded) |
//! | `CCOLL_ENGINE_PARK`          | park   | `yield` | engine worker wait strategy |
//! | `CCOLL_FUSION_MAX_BYTES`     | usize  | 65536   | fusion-tier batch byte budget (ops above it bypass the batcher) |
//! | `CCOLL_FUSION_WINDOW`        | usize  | `8`     | fusion-tier flush window in completed engine steps (0 disables fusion) |
//! | `CCOLL_TRANSPORT`            | transport | `thread` | default transport backend (`transport.backend` overrides per run) |
//! | `CCOLL_RETRY_ATTEMPTS`       | usize  | `3`     | transient-send retry budget per frame (UDS writer; `engine.retry.attempts` overrides per run) |
//! | `CCOLL_RETRY_BASE_MS`        | usize  | `10`    | base backoff between send retries, doubling per attempt (`engine.retry.base_ms` overrides per run) |
//! | `CCOLL_ENGINE_BACKPRESSURE_TIMEOUT` | usize | `90` | seconds `submit` may park on a full engine queue before `BackpressureTimeout` (`engine.backpressure_timeout` overrides per run) |
//! | `CCOLL_AUDIT_PLANS`          | bool   | `0`     | release-build opt-in for the plan-cache static audit (debug builds always audit) |
//! | `CCOLL_PIPELINE_MIN_BYTES`   | usize  | 1048576 | payload size at which the engine switches to the pipelined tier (0 disables pipelining; `engine.pipeline.min_bytes` overrides per run) |
//! | `CCOLL_PIPELINE_CHUNK_BYTES` | usize  | 262144  | chunk size for the pipelined tier (0 disables pipelining; `engine.pipeline.chunk_bytes` overrides per run) |
//! | `CCOLL_HEARTBEAT_MS`         | usize  | `0`     | UDS liveness-probe interval in ms (0 disables heartbeats) |
//! | `CCOLL_RECONNECT_ATTEMPTS`   | usize  | `0`     | UDS reconnect budget for a dropped peer stream (0 = fail-fast, no reconnection) |
//! | `CCOLL_RECONNECT_BASE_MS`    | usize  | `50`    | base backoff between UDS reconnect attempts, doubling per attempt |
//!
//! Booleans accept `0|1|true|false|yes|no` (empty = unset = default).
//! Integers accept decimal digits with optional `_` separators. Dtypes
//! accept `f32|f64|i32|i64|u64`; park policies accept `spin|yield|sleep`;
//! transport backends accept `thread|uds`.
//! `ccoll info` lists every knob with its resolved value.

use std::sync::OnceLock;

use crate::datatypes::DType;
use crate::engine::ParkPolicy;
use crate::transport::TransportBackend;

/// The parsed knob set. Construct via [`knobs`] (process env, cached) or
/// [`parse_from`] (explicit lookup, for tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnvKnobs {
    /// Rendezvous (zero-copy tier) enabled — `CCOLL_NO_RENDEZVOUS`
    /// inverted.
    pub rendezvous_enabled: bool,
    /// Minimum payload (elements) for a rendezvous publish
    /// (`CCOLL_RENDEZVOUS_MIN_ELEMS`).
    pub rendezvous_min_elems: usize,
    /// Shrink bench sweeps for smoke runs (`CCOLL_BENCH_FAST`).
    pub bench_fast: bool,
    /// Element type the dtype-aware benches (T1/T2) run in
    /// (`CCOLL_BENCH_DTYPE`).
    pub bench_dtype: DType,
    /// Preferred chunk bucket (elements) for large PJRT combines
    /// (`CCOLL_PJRT_CHUNK`); `None` means use the engine's measured
    /// default. Validated here even when the `pjrt` feature is off, so
    /// a malformed value always aborts loudly.
    pub pjrt_chunk: Option<usize>,
    /// Default cap on in-flight engine operations before `submit` parks
    /// (`CCOLL_ENGINE_QUEUE_DEPTH`; 0 = unbounded). Per-engine override:
    /// `EngineConfig::queue_depth` / config key `engine.queue_depth`.
    pub engine_queue_depth: usize,
    /// Default engine worker wait strategy between poll passes
    /// (`CCOLL_ENGINE_PARK`: spin|yield|sleep). Per-engine override:
    /// `EngineConfig::park` / config key `engine.park`.
    pub engine_park: ParkPolicy,
    /// Default fusion-tier batch byte budget (`CCOLL_FUSION_MAX_BYTES`):
    /// a pending batch flushes before exceeding it, and any single op
    /// larger than it bypasses the batcher entirely. Per-engine override:
    /// `EngineConfig::fusion_max_bytes` / config key
    /// `engine.fusion.max_bytes`.
    pub fusion_max_bytes: usize,
    /// Default fusion-tier flush window (`CCOLL_FUSION_WINDOW`), measured
    /// in **completed engine steps** — not wall-clock: a pending batch is
    /// flushed once this many operations have completed since it opened.
    /// 0 disables fusion outright (a zero-step window could never
    /// coalesce anything). Per-engine override:
    /// `EngineConfig::fusion_window` / config key `engine.fusion.window`.
    pub fusion_window: u64,
    /// Default transport backend (`CCOLL_TRANSPORT`: thread|uds) — which
    /// [`crate::transport::Transport`] implementation carries the rank
    /// network. Per-run override: config key `transport.backend`.
    pub transport_backend: TransportBackend,
    /// Default retry budget for transient send errors
    /// (`CCOLL_RETRY_ATTEMPTS`): how many times a backend writer may
    /// re-attempt a frame segment that hit a transient condition
    /// (`WouldBlock`) before surfacing `PeerDown`. 0 disables retries.
    /// Per-run override: `EngineConfig::retry_attempts` / config key
    /// `engine.retry.attempts`.
    pub retry_attempts: usize,
    /// Base backoff in milliseconds between transient-send retries
    /// (`CCOLL_RETRY_BASE_MS`); attempt `k` sleeps `base << (k-1)`
    /// (capped). Per-run override: `EngineConfig::retry_base_ms` /
    /// config key `engine.retry.base_ms`.
    pub retry_base_ms: u64,
    /// Seconds [`crate::engine::CollectiveEngine::submit`] may park
    /// waiting for queue-depth headroom before failing with
    /// `EngineError::BackpressureTimeout`
    /// (`CCOLL_ENGINE_BACKPRESSURE_TIMEOUT`). Per-engine override:
    /// `EngineConfig::backpressure_timeout` / config key
    /// `engine.backpressure_timeout`.
    pub engine_backpressure_timeout_secs: u64,
    /// Run the static schedule audit ([`crate::analysis`]) on every
    /// `PlanCache` miss even in release builds (`CCOLL_AUDIT_PLANS`).
    /// Debug builds always audit regardless of this knob.
    pub audit_plans: bool,
    /// Default payload byte size at which the engine dispatches an op to
    /// the pipelined (chunked) execution tier instead of the plain
    /// schedule (`CCOLL_PIPELINE_MIN_BYTES`; 0 disables pipelining).
    /// The default is grounded in the closed-form break-even analysis
    /// ([`crate::sim::closed_form::pipelined_circulant_allreduce`]).
    /// Per-engine override: `EngineConfig::pipeline_min_bytes` / config
    /// key `engine.pipeline.min_bytes`.
    pub pipeline_min_bytes: usize,
    /// Default chunk byte size for the pipelined tier
    /// (`CCOLL_PIPELINE_CHUNK_BYTES`; 0 disables pipelining). Each chunk
    /// runs the circulant schedule as its own wire epoch inside one op.
    /// Per-engine override: `EngineConfig::pipeline_chunk_bytes` /
    /// config key `engine.pipeline.chunk_bytes`.
    pub pipeline_chunk_bytes: usize,
    /// UDS liveness-probe interval in milliseconds (`CCOLL_HEARTBEAT_MS`;
    /// 0 disables heartbeats — peers are only declared down when a read
    /// or write on their stream actually fails). A peer that has sent at
    /// least one probe and then goes silent for 4× this interval is
    /// reported down by `peer_status`/`peer_down`.
    pub heartbeat_ms: u64,
    /// UDS reconnect budget for a peer whose stream dropped
    /// (`CCOLL_RECONNECT_ATTEMPTS`; 0 = fail-fast, the historical
    /// behaviour — a broken stream immediately surfaces `PeerDown`).
    /// With a budget, a write failure triggers bounded re-dial of the
    /// peer's socket at the current generation before giving up.
    pub reconnect_attempts: usize,
    /// Base backoff in milliseconds between UDS reconnect attempts
    /// (`CCOLL_RECONNECT_BASE_MS`); attempt `k` sleeps `base << (k-1)`
    /// with the shift capped at 6.
    pub reconnect_base_ms: u64,
}

fn parse_bool(name: &str, raw: Option<&str>, default: bool) -> Result<bool, String> {
    match raw {
        None | Some("") => Ok(default),
        Some("0") | Some("false") | Some("no") => Ok(false),
        Some("1") | Some("true") | Some("yes") => Ok(true),
        Some(v) => Err(format!("{name}={v:?} is not a boolean (accepted: 0|1|true|false|yes|no)")),
    }
}

fn parse_usize(name: &str, raw: Option<&str>, default: usize) -> Result<usize, String> {
    match raw {
        None | Some("") => Ok(default),
        Some(v) => v.replace('_', "").parse().map_err(|_| {
            format!("{name}={v:?} is not a non-negative integer (e.g. {name}=4096)")
        }),
    }
}

fn parse_opt_usize(name: &str, raw: Option<&str>) -> Result<Option<usize>, String> {
    match raw {
        None | Some("") => Ok(None),
        Some(v) => v.replace('_', "").parse().map(Some).map_err(|_| {
            format!("{name}={v:?} is not a non-negative integer (e.g. {name}=8192)")
        }),
    }
}

fn parse_dtype(name: &str, raw: Option<&str>, default: DType) -> Result<DType, String> {
    match raw {
        None | Some("") => Ok(default),
        Some(v) => DType::parse(v)
            .ok_or_else(|| format!("{name}={v:?} is not a dtype (accepted: {})", DType::NAMES_HELP)),
    }
}

fn parse_park(name: &str, raw: Option<&str>, default: ParkPolicy) -> Result<ParkPolicy, String> {
    match raw {
        None | Some("") => Ok(default),
        Some(v) => ParkPolicy::parse(v).ok_or_else(|| {
            format!("{name}={v:?} is not a park policy (accepted: {})", ParkPolicy::NAMES_HELP)
        }),
    }
}

fn parse_transport(
    name: &str,
    raw: Option<&str>,
    default: TransportBackend,
) -> Result<TransportBackend, String> {
    match raw {
        None | Some("") => Ok(default),
        Some(v) => TransportBackend::parse(v).ok_or_else(|| {
            format!(
                "{name}={v:?} is not a transport backend (accepted: {})",
                TransportBackend::NAMES_HELP
            )
        }),
    }
}

/// Parse a knob set from an arbitrary lookup function — pure, so malformed
/// values are testable without touching the process environment.
pub fn parse_from(get: impl Fn(&str) -> Option<String>) -> Result<EnvKnobs, String> {
    let no_rendezvous =
        parse_bool("CCOLL_NO_RENDEZVOUS", get("CCOLL_NO_RENDEZVOUS").as_deref(), false)?;
    Ok(EnvKnobs {
        rendezvous_enabled: !no_rendezvous,
        rendezvous_min_elems: parse_usize(
            "CCOLL_RENDEZVOUS_MIN_ELEMS",
            get("CCOLL_RENDEZVOUS_MIN_ELEMS").as_deref(),
            crate::transport::DEFAULT_RENDEZVOUS_MIN_ELEMS,
        )?,
        bench_fast: parse_bool("CCOLL_BENCH_FAST", get("CCOLL_BENCH_FAST").as_deref(), false)?,
        bench_dtype: parse_dtype(
            "CCOLL_BENCH_DTYPE",
            get("CCOLL_BENCH_DTYPE").as_deref(),
            DType::F32,
        )?,
        pjrt_chunk: parse_opt_usize("CCOLL_PJRT_CHUNK", get("CCOLL_PJRT_CHUNK").as_deref())?,
        engine_queue_depth: parse_usize(
            "CCOLL_ENGINE_QUEUE_DEPTH",
            get("CCOLL_ENGINE_QUEUE_DEPTH").as_deref(),
            0,
        )?,
        engine_park: parse_park(
            "CCOLL_ENGINE_PARK",
            get("CCOLL_ENGINE_PARK").as_deref(),
            ParkPolicy::Yield,
        )?,
        fusion_max_bytes: parse_usize(
            "CCOLL_FUSION_MAX_BYTES",
            get("CCOLL_FUSION_MAX_BYTES").as_deref(),
            crate::engine::DEFAULT_FUSION_MAX_BYTES,
        )?,
        fusion_window: parse_usize(
            "CCOLL_FUSION_WINDOW",
            get("CCOLL_FUSION_WINDOW").as_deref(),
            crate::engine::DEFAULT_FUSION_WINDOW as usize,
        )? as u64,
        transport_backend: parse_transport(
            "CCOLL_TRANSPORT",
            get("CCOLL_TRANSPORT").as_deref(),
            TransportBackend::Thread,
        )?,
        retry_attempts: parse_usize(
            "CCOLL_RETRY_ATTEMPTS",
            get("CCOLL_RETRY_ATTEMPTS").as_deref(),
            crate::transport::DEFAULT_RETRY_ATTEMPTS,
        )?,
        retry_base_ms: parse_usize(
            "CCOLL_RETRY_BASE_MS",
            get("CCOLL_RETRY_BASE_MS").as_deref(),
            crate::transport::DEFAULT_RETRY_BASE_MS as usize,
        )? as u64,
        engine_backpressure_timeout_secs: parse_usize(
            "CCOLL_ENGINE_BACKPRESSURE_TIMEOUT",
            get("CCOLL_ENGINE_BACKPRESSURE_TIMEOUT").as_deref(),
            crate::engine::DEFAULT_BACKPRESSURE_TIMEOUT_SECS as usize,
        )? as u64,
        audit_plans: parse_bool("CCOLL_AUDIT_PLANS", get("CCOLL_AUDIT_PLANS").as_deref(), false)?,
        pipeline_min_bytes: parse_usize(
            "CCOLL_PIPELINE_MIN_BYTES",
            get("CCOLL_PIPELINE_MIN_BYTES").as_deref(),
            crate::engine::DEFAULT_PIPELINE_MIN_BYTES,
        )?,
        pipeline_chunk_bytes: parse_usize(
            "CCOLL_PIPELINE_CHUNK_BYTES",
            get("CCOLL_PIPELINE_CHUNK_BYTES").as_deref(),
            crate::engine::DEFAULT_PIPELINE_CHUNK_BYTES,
        )?,
        heartbeat_ms: parse_usize(
            "CCOLL_HEARTBEAT_MS",
            get("CCOLL_HEARTBEAT_MS").as_deref(),
            crate::transport::DEFAULT_HEARTBEAT_MS as usize,
        )? as u64,
        reconnect_attempts: parse_usize(
            "CCOLL_RECONNECT_ATTEMPTS",
            get("CCOLL_RECONNECT_ATTEMPTS").as_deref(),
            crate::transport::DEFAULT_RECONNECT_ATTEMPTS,
        )?,
        reconnect_base_ms: parse_usize(
            "CCOLL_RECONNECT_BASE_MS",
            get("CCOLL_RECONNECT_BASE_MS").as_deref(),
            crate::transport::DEFAULT_RECONNECT_BASE_MS as usize,
        )? as u64,
    })
}

/// The process-wide knob set, parsed from the environment on first use and
/// cached (the transport's hot path pays one pointer load). Panics with a
/// clear message on a malformed value — configuration errors must surface
/// at startup, not as silently-defaulted behavior.
pub fn knobs() -> &'static EnvKnobs {
    static KNOBS: OnceLock<EnvKnobs> = OnceLock::new();
    KNOBS.get_or_init(|| {
        parse_from(|k| std::env::var(k).ok())
            .unwrap_or_else(|e| panic!("invalid CCOLL environment knob: {e}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn with(vars: &[(&str, &str)]) -> Result<EnvKnobs, String> {
        let map: HashMap<String, String> =
            vars.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        parse_from(move |k| map.get(k).cloned())
    }

    #[test]
    fn defaults_when_unset() {
        let k = with(&[]).unwrap();
        assert!(k.rendezvous_enabled);
        assert_eq!(k.rendezvous_min_elems, crate::transport::DEFAULT_RENDEZVOUS_MIN_ELEMS);
        assert!(!k.bench_fast);
        assert_eq!(k.bench_dtype, DType::F32);
        assert_eq!(k.pjrt_chunk, None);
        assert_eq!(k.engine_queue_depth, 0, "0 = unbounded");
        assert_eq!(k.engine_park, ParkPolicy::Yield);
        assert_eq!(k.fusion_max_bytes, crate::engine::DEFAULT_FUSION_MAX_BYTES);
        assert_eq!(k.fusion_window, crate::engine::DEFAULT_FUSION_WINDOW);
        assert_eq!(k.transport_backend, TransportBackend::Thread);
        assert_eq!(k.retry_attempts, crate::transport::DEFAULT_RETRY_ATTEMPTS);
        assert_eq!(k.retry_base_ms, crate::transport::DEFAULT_RETRY_BASE_MS);
        assert_eq!(
            k.engine_backpressure_timeout_secs,
            crate::engine::DEFAULT_BACKPRESSURE_TIMEOUT_SECS
        );
        assert!(!k.audit_plans, "release-build plan audits are opt-in");
        assert_eq!(k.pipeline_min_bytes, crate::engine::DEFAULT_PIPELINE_MIN_BYTES);
        assert_eq!(k.pipeline_chunk_bytes, crate::engine::DEFAULT_PIPELINE_CHUNK_BYTES);
        assert_eq!(k.heartbeat_ms, crate::transport::DEFAULT_HEARTBEAT_MS);
        assert_eq!(k.reconnect_attempts, crate::transport::DEFAULT_RECONNECT_ATTEMPTS);
        assert_eq!(k.reconnect_base_ms, crate::transport::DEFAULT_RECONNECT_BASE_MS);
    }

    #[test]
    fn recovery_knobs_parse_and_reject_loudly() {
        let k = with(&[
            ("CCOLL_HEARTBEAT_MS", "20"),
            ("CCOLL_RECONNECT_ATTEMPTS", "4"),
            ("CCOLL_RECONNECT_BASE_MS", "10"),
        ])
        .unwrap();
        assert_eq!(k.heartbeat_ms, 20);
        assert_eq!(k.reconnect_attempts, 4);
        assert_eq!(k.reconnect_base_ms, 10);
        let k = with(&[("CCOLL_HEARTBEAT_MS", "0")]).unwrap();
        assert_eq!(k.heartbeat_ms, 0, "0 must parse (it disables heartbeats)");
        let k = with(&[("CCOLL_RECONNECT_ATTEMPTS", "0")]).unwrap();
        assert_eq!(k.reconnect_attempts, 0, "0 must parse (it disables reconnection)");
        let err = with(&[("CCOLL_HEARTBEAT_MS", "fast")]).unwrap_err();
        assert!(err.contains("CCOLL_HEARTBEAT_MS") && err.contains("fast"), "{err}");
        let err = with(&[("CCOLL_RECONNECT_ATTEMPTS", "many")]).unwrap_err();
        assert!(err.contains("CCOLL_RECONNECT_ATTEMPTS") && err.contains("many"), "{err}");
        let err = with(&[("CCOLL_RECONNECT_BASE_MS", "-1")]).unwrap_err();
        assert!(err.contains("CCOLL_RECONNECT_BASE_MS") && err.contains("non-negative"), "{err}");
    }

    #[test]
    fn pipeline_knobs_parse_and_reject_loudly() {
        let k = with(&[
            ("CCOLL_PIPELINE_MIN_BYTES", "4_194_304"),
            ("CCOLL_PIPELINE_CHUNK_BYTES", "65536"),
        ])
        .unwrap();
        assert_eq!(k.pipeline_min_bytes, 4_194_304);
        assert_eq!(k.pipeline_chunk_bytes, 65_536);
        let k = with(&[("CCOLL_PIPELINE_MIN_BYTES", "0")]).unwrap();
        assert_eq!(k.pipeline_min_bytes, 0, "0 must parse (it disables pipelining)");
        let k = with(&[("CCOLL_PIPELINE_CHUNK_BYTES", "0")]).unwrap();
        assert_eq!(k.pipeline_chunk_bytes, 0, "0 must parse (it disables pipelining)");
        let err = with(&[("CCOLL_PIPELINE_MIN_BYTES", "huge")]).unwrap_err();
        assert!(err.contains("CCOLL_PIPELINE_MIN_BYTES") && err.contains("huge"), "{err}");
        let err = with(&[("CCOLL_PIPELINE_CHUNK_BYTES", "-7")]).unwrap_err();
        assert!(err.contains("CCOLL_PIPELINE_CHUNK_BYTES") && err.contains("non-negative"), "{err}");
    }

    #[test]
    fn audit_plans_knob_parses_and_rejects_loudly() {
        assert!(with(&[("CCOLL_AUDIT_PLANS", "1")]).unwrap().audit_plans);
        assert!(!with(&[("CCOLL_AUDIT_PLANS", "no")]).unwrap().audit_plans);
        let err = with(&[("CCOLL_AUDIT_PLANS", "always")]).unwrap_err();
        assert!(err.contains("CCOLL_AUDIT_PLANS") && err.contains("always"), "{err}");
    }

    #[test]
    fn retry_and_backpressure_knobs_parse_and_reject_loudly() {
        let k = with(&[
            ("CCOLL_RETRY_ATTEMPTS", "5"),
            ("CCOLL_RETRY_BASE_MS", "25"),
            ("CCOLL_ENGINE_BACKPRESSURE_TIMEOUT", "2"),
        ])
        .unwrap();
        assert_eq!(k.retry_attempts, 5);
        assert_eq!(k.retry_base_ms, 25);
        assert_eq!(k.engine_backpressure_timeout_secs, 2);
        let k = with(&[("CCOLL_RETRY_ATTEMPTS", "0")]).unwrap();
        assert_eq!(k.retry_attempts, 0, "0 must parse (it disables retries)");
        let err = with(&[("CCOLL_RETRY_ATTEMPTS", "lots")]).unwrap_err();
        assert!(err.contains("CCOLL_RETRY_ATTEMPTS") && err.contains("lots"), "{err}");
        let err = with(&[("CCOLL_RETRY_BASE_MS", "-5")]).unwrap_err();
        assert!(err.contains("CCOLL_RETRY_BASE_MS") && err.contains("non-negative"), "{err}");
        let err = with(&[("CCOLL_ENGINE_BACKPRESSURE_TIMEOUT", "forever")]).unwrap_err();
        assert!(
            err.contains("CCOLL_ENGINE_BACKPRESSURE_TIMEOUT") && err.contains("forever"),
            "{err}"
        );
    }

    #[test]
    fn transport_knob_parses_and_rejects_loudly() {
        for (v, want) in [("thread", TransportBackend::Thread), ("uds", TransportBackend::Uds)] {
            assert_eq!(with(&[("CCOLL_TRANSPORT", v)]).unwrap().transport_backend, want, "{v}");
        }
        let k = with(&[("CCOLL_TRANSPORT", "")]).unwrap();
        assert_eq!(k.transport_backend, TransportBackend::Thread, "empty string means unset");
        let err = with(&[("CCOLL_TRANSPORT", "tcp")]).unwrap_err();
        assert!(err.contains("CCOLL_TRANSPORT") && err.contains("tcp"), "{err}");
        assert!(err.contains("thread|uds"), "must enumerate the valid set: {err}");
    }

    #[test]
    fn fusion_knobs_parse_and_reject_loudly() {
        let k =
            with(&[("CCOLL_FUSION_MAX_BYTES", "16_384"), ("CCOLL_FUSION_WINDOW", "4")]).unwrap();
        assert_eq!(k.fusion_max_bytes, 16_384);
        assert_eq!(k.fusion_window, 4);
        let k = with(&[("CCOLL_FUSION_WINDOW", "0")]).unwrap();
        assert_eq!(k.fusion_window, 0, "0 must parse (it disables fusion)");
        let err = with(&[("CCOLL_FUSION_MAX_BYTES", "big")]).unwrap_err();
        assert!(err.contains("CCOLL_FUSION_MAX_BYTES") && err.contains("big"), "{err}");
        let err = with(&[("CCOLL_FUSION_WINDOW", "-3")]).unwrap_err();
        assert!(err.contains("CCOLL_FUSION_WINDOW") && err.contains("non-negative"), "{err}");
    }

    #[test]
    fn engine_knobs_parse_and_reject_loudly() {
        let k = with(&[("CCOLL_ENGINE_QUEUE_DEPTH", "64"), ("CCOLL_ENGINE_PARK", "spin")]).unwrap();
        assert_eq!(k.engine_queue_depth, 64);
        assert_eq!(k.engine_park, ParkPolicy::Spin);
        for v in ["yield", "sleep"] {
            assert_eq!(with(&[("CCOLL_ENGINE_PARK", v)]).unwrap().engine_park.name(), v);
        }
        let err = with(&[("CCOLL_ENGINE_QUEUE_DEPTH", "deep")]).unwrap_err();
        assert!(err.contains("CCOLL_ENGINE_QUEUE_DEPTH") && err.contains("deep"), "{err}");
        let err = with(&[("CCOLL_ENGINE_PARK", "nap")]).unwrap_err();
        assert!(err.contains("CCOLL_ENGINE_PARK") && err.contains("spin|yield|sleep"), "{err}");
    }

    #[test]
    fn pjrt_chunk_parses_or_rejects() {
        assert_eq!(with(&[("CCOLL_PJRT_CHUNK", "8192")]).unwrap().pjrt_chunk, Some(8192));
        assert_eq!(with(&[("CCOLL_PJRT_CHUNK", "16_384")]).unwrap().pjrt_chunk, Some(16384));
        let err = with(&[("CCOLL_PJRT_CHUNK", "abc")]).unwrap_err();
        assert!(err.contains("CCOLL_PJRT_CHUNK") && err.contains("abc"), "{err}");
    }

    #[test]
    fn kill_switch_and_threshold_parse() {
        let k = with(&[("CCOLL_NO_RENDEZVOUS", "1"), ("CCOLL_RENDEZVOUS_MIN_ELEMS", "4_096")])
            .unwrap();
        assert!(!k.rendezvous_enabled);
        assert_eq!(k.rendezvous_min_elems, 4096);
        let k = with(&[("CCOLL_NO_RENDEZVOUS", "0")]).unwrap();
        assert!(k.rendezvous_enabled);
        let k = with(&[("CCOLL_NO_RENDEZVOUS", "")]).unwrap();
        assert!(k.rendezvous_enabled, "empty string means unset");
    }

    #[test]
    fn bool_synonyms_accepted() {
        for v in ["1", "true", "yes"] {
            assert!(with(&[("CCOLL_BENCH_FAST", v)]).unwrap().bench_fast, "{v}");
        }
        for v in ["0", "false", "no"] {
            assert!(!with(&[("CCOLL_BENCH_FAST", v)]).unwrap().bench_fast, "{v}");
        }
    }

    #[test]
    fn malformed_values_rejected_loudly() {
        let err = with(&[("CCOLL_RENDEZVOUS_MIN_ELEMS", "abc")]).unwrap_err();
        assert!(err.contains("CCOLL_RENDEZVOUS_MIN_ELEMS"), "{err}");
        assert!(err.contains("abc"), "{err}");
        let err = with(&[("CCOLL_RENDEZVOUS_MIN_ELEMS", "-1")]).unwrap_err();
        assert!(err.contains("non-negative"), "{err}");
        let err = with(&[("CCOLL_NO_RENDEZVOUS", "banana")]).unwrap_err();
        assert!(err.contains("CCOLL_NO_RENDEZVOUS") && err.contains("banana"), "{err}");
        let err = with(&[("CCOLL_BENCH_FAST", "2")]).unwrap_err();
        assert!(err.contains("boolean"), "{err}");
        let err = with(&[("CCOLL_BENCH_DTYPE", "f16")]).unwrap_err();
        assert!(err.contains("f32|f64|i32|i64|u64"), "{err}");
    }

    #[test]
    fn bench_dtype_parses() {
        for (v, dt) in
            [("f32", DType::F32), ("f64", DType::F64), ("i32", DType::I32), ("i64", DType::I64), ("u64", DType::U64)]
        {
            assert_eq!(with(&[("CCOLL_BENCH_DTYPE", v)]).unwrap().bench_dtype, dt);
        }
    }

    #[test]
    fn process_knobs_are_consistent_with_env() {
        // Whatever the ambient env says, the cached set must agree with a
        // fresh parse of the same lookup (i.e. knobs() is just a cache).
        let fresh = parse_from(|k| std::env::var(k).ok()).expect("ambient env must be valid");
        assert_eq!(knobs(), &fresh);
    }
}
