//! # circulant-collectives
//!
//! Reproduction of J. L. Träff, *"Optimal, Non-pipelined Reduce-scatter and
//! Allreduce Algorithms"* (2024): reduce-scatter in `⌈log2 p⌉` rounds with
//! exactly `p−1` blocks sent/received/reduced per processor, allreduce in
//! `2⌈log2 p⌉` rounds with `2(p−1)` blocks — both uniform in `p`, on
//! circulant-graph communication patterns.
//!
//! Three-layer architecture (DESIGN.md):
//!  * **Layer 3 (this crate)** — the collective schedules, thread-network
//!    transport, α-β-γ simulator and the MPI-like [`coordinator`] API;
//!  * **Layer 2 (python/compile/model.py)** — JAX compute graphs, AOT-lowered
//!    to HLO text at build time;
//!  * **Layer 1 (python/compile/kernels/)** — Pallas block-combine kernels,
//!    executed from Rust through PJRT ([`runtime`]).
// `[15]`-style citation brackets in doc comments are references to the
// paper's bibliography, not intra-doc links.
#![allow(rustdoc::broken_intra_doc_links)]

pub mod analysis;
pub mod bench_harness;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod datatypes;
pub mod engine;
pub mod env_knobs;
pub mod ops;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod topology;
pub mod transport;
pub mod util;
