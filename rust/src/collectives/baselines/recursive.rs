//! Recursive halving / doubling baselines (hypercube-pattern algorithms,
//! §1 of the paper: optimal for powers of two, awkward otherwise).
//!
//! * [`recursive_halving_rs_schedule`] — reduce-scatter for power-of-two
//!   `p`: the block space is halved every round (butterfly).
//! * [`recursive_doubling_allreduce_schedule`] — full-vector butterfly
//!   allreduce; non-power-of-two `p` handled by the standard fold: extra
//!   ranks fold their vector into a partner first and receive the result
//!   back at the end ([16]'s "trivial reduction to the nearest power of
//!   two", which is exactly what the paper's Algorithm 1 renders
//!   unnecessary).
//! * [`rabenseifner_allreduce_schedule`] — halving RS + doubling AG [16].

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};

/// Largest power of two ≤ `p`.
fn pow2_floor(p: usize) -> usize {
    assert!(p >= 1);
    1usize << p.ilog2()
}

/// Fold-in round for non-power-of-two `p`: ranks `pow..p` send their whole
/// vector to `r − pow`, which combines. Returns `None` if `p` is a power
/// of two.
fn fold_in_round(p: usize) -> Option<Round> {
    let pow = pow2_floor(p);
    if pow == p {
        return None;
    }
    let mut round = Round::idle(p);
    for e in pow..p {
        let partner = e - pow;
        round.steps[e] =
            RankStep { send: Some(Transfer { peer: partner, blocks: BlockRange::new(0, p) }), recv: None };
        round.steps[partner] = RankStep {
            send: None,
            recv: Some(Recv { peer: e, blocks: BlockRange::new(0, p), action: RecvAction::Combine }),
        };
    }
    Some(round)
}

/// Copy-back round: partners return the finished full vector to the folded
/// ranks.
fn fold_out_round(p: usize) -> Option<Round> {
    let pow = pow2_floor(p);
    if pow == p {
        return None;
    }
    let mut round = Round::idle(p);
    for e in pow..p {
        let partner = e - pow;
        round.steps[partner] =
            RankStep { send: Some(Transfer { peer: e, blocks: BlockRange::new(0, p) }), recv: None };
        round.steps[e] = RankStep {
            send: None,
            recv: Some(Recv { peer: partner, blocks: BlockRange::new(0, p), action: RecvAction::Store }),
        };
    }
    Some(round)
}

/// The (start, len) block window of rank `r` after `rounds` halving rounds
/// over `pow` ranks/blocks. The kept half always contains bit pattern of r.
fn window(r: usize, pow: usize, rounds: usize) -> (usize, usize) {
    let mut start = 0usize;
    let mut len = pow;
    for k in 0..rounds {
        let half = len / 2;
        let bit = pow >> (k + 1);
        if r & bit != 0 {
            start += half;
        }
        len = half;
    }
    (start, len)
}

/// Recursive halving reduce-scatter over the *block groups* `0..pow`.
/// Requires `p` to be a power of two and the partition to have exactly `p`
/// blocks. `log2 p` rounds; volume `(p−1)/p·m` — matches Algorithm 1 on
/// powers of two, which is the baseline's best case.
pub fn recursive_halving_rs_schedule(p: usize) -> Schedule {
    assert!(p.is_power_of_two(), "recursive halving requires power-of-two p (got {p})");
    let mut sched = Schedule::new(p, "rec-halving-rs");
    if p == 1 {
        return sched;
    }
    let q = p.ilog2() as usize;
    for k in 0..q {
        let bit = p >> (k + 1);
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let peer = r ^ bit;
            let (start, len) = window(r, p, k);
            let half = len / 2;
            // Keep the half containing r; send the half containing peer.
            let keep_upper = r & bit != 0;
            let (send_start, recv_start) =
                if keep_upper { (start, start + half) } else { (start + half, start) };
            *step = RankStep {
                send: Some(Transfer { peer, blocks: BlockRange::new(send_start, half) }),
                recv: Some(Recv {
                    peer,
                    blocks: BlockRange::new(recv_start, half),
                    action: RecvAction::Combine,
                }),
            };
        }
        sched.rounds.push(round);
    }
    sched
}

/// Recursive doubling allgather (mirror of halving): windows double back.
/// Precondition: rank `r` holds finished block `r`. Power-of-two `p`.
pub fn recursive_doubling_ag_schedule(p: usize) -> Schedule {
    assert!(p.is_power_of_two());
    let mut sched = Schedule::new(p, "rec-doubling-ag");
    if p == 1 {
        return sched;
    }
    let q = p.ilog2() as usize;
    for k in (0..q).rev() {
        let bit = p >> (k + 1);
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let peer = r ^ bit;
            let (start, len) = window(r, p, k + 1); // my kept window (complete)
            let (pstart, _) = window(peer, p, k + 1);
            *step = RankStep {
                send: Some(Transfer { peer, blocks: BlockRange::new(start, len) }),
                recv: Some(Recv {
                    peer,
                    blocks: BlockRange::new(pstart, len),
                    action: RecvAction::Store,
                }),
            };
        }
        sched.rounds.push(round);
    }
    sched
}

/// Full-vector recursive doubling allreduce, with fold rounds for
/// non-power-of-two `p`.
pub fn recursive_doubling_allreduce_schedule(p: usize) -> Schedule {
    let mut sched = Schedule::new(p, "rec-doubling-allreduce");
    if p == 1 {
        return sched;
    }
    let pow = pow2_floor(p);
    sched.rounds.extend(fold_in_round(p));
    let q = pow.ilog2() as usize;
    for k in 0..q {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for r in 0..pow {
            let peer = r ^ bit;
            round.steps[r] = RankStep {
                send: Some(Transfer { peer, blocks: BlockRange::new(0, p) }),
                recv: Some(Recv {
                    peer,
                    blocks: BlockRange::new(0, p),
                    action: RecvAction::Combine,
                }),
            };
        }
        sched.rounds.push(round);
    }
    sched.rounds.extend(fold_out_round(p));
    sched
}

/// Rabenseifner allreduce [16]: fold + recursive-halving reduce-scatter +
/// recursive-doubling allgather + copy-back. Optimal volume on powers of
/// two; the fold rounds cost an extra `(β+γ)m` and `βm` otherwise.
pub fn rabenseifner_allreduce_schedule(p: usize) -> Schedule {
    let mut sched = Schedule::new(p, "rabenseifner-allreduce");
    if p == 1 {
        return sched;
    }
    let pow = pow2_floor(p);
    sched.rounds.extend(fold_in_round(p));
    // Halving RS + doubling AG among the active pow ranks; block space is
    // the full p blocks, windowed by *group*: group g of the pow groups
    // covers blocks [g·p/pow…] — but p need not divide; instead run the
    // butterfly over pow *block groups* defined by splitting the p blocks
    // as evenly as possible. We express windows directly in block ids.
    let q = pow.ilog2() as usize;
    let group_start = |g: usize| -> usize { g * p / pow };
    for k in 0..q {
        let bit = pow >> (k + 1);
        let mut round = Round::idle(p);
        for r in 0..pow {
            let peer = r ^ bit;
            let (gstart, glen) = window(r, pow, k);
            let half = glen / 2;
            let keep_upper = r & bit != 0;
            let (sg, rg) = if keep_upper { (gstart, gstart + half) } else { (gstart + half, gstart) };
            let send_blocks =
                BlockRange::new(group_start(sg), group_start(sg + half) - group_start(sg));
            let recv_blocks =
                BlockRange::new(group_start(rg), group_start(rg + half) - group_start(rg));
            round.steps[r] = RankStep {
                send: Some(Transfer { peer, blocks: send_blocks }),
                recv: Some(Recv { peer, blocks: recv_blocks, action: RecvAction::Combine }),
            };
        }
        sched.rounds.push(round);
    }
    for k in (0..q).rev() {
        let bit = pow >> (k + 1);
        let mut round = Round::idle(p);
        for r in 0..pow {
            let peer = r ^ bit;
            let (gstart, glen) = window(r, pow, k + 1);
            let (pgstart, _) = window(peer, pow, k + 1);
            let send_blocks =
                BlockRange::new(group_start(gstart), group_start(gstart + glen) - group_start(gstart));
            let recv_blocks = BlockRange::new(
                group_start(pgstart),
                group_start(pgstart + glen) - group_start(pgstart),
            );
            round.steps[r] = RankStep {
                send: Some(Transfer { peer, blocks: send_blocks }),
                recv: Some(Recv { peer, blocks: recv_blocks, action: RecvAction::Store }),
            };
        }
        sched.rounds.push(round);
    }
    sched.rounds.extend(fold_out_round(p));
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..p).map(|_| rng.int_valued_vec(m, -5, 6)).collect()
    }

    #[test]
    fn windows_partition_block_space() {
        for q in 1..=5usize {
            let pow = 1 << q;
            for rounds in 0..=q {
                let mut seen = vec![0usize; pow];
                for r in 0..pow {
                    let (s, l) = window(r, pow, rounds);
                    for b in s..s + l {
                        seen[b] += 1;
                    }
                }
                // Each block covered by exactly pow/2^rounds ranks.
                assert!(seen.iter().all(|&c| c == pow >> rounds), "q={q} rounds={rounds}");
            }
        }
    }

    #[test]
    fn halving_rs_correct_pow2() {
        for p in [2usize, 4, 8, 16] {
            let part = BlockPartition::regular(p, 3 * p);
            let inputs = int_inputs(p, part.total(), p as u64);
            let want = oracle_sum(&inputs);
            let sched = recursive_halving_rs_schedule(p);
            sched.assert_valid();
            assert_eq!(sched.num_rounds(), p.ilog2() as usize);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &want[range], "p={p} r={r}");
            }
        }
    }

    #[test]
    fn doubling_allreduce_correct_any_p() {
        for p in [2usize, 3, 4, 6, 8, 11] {
            let part = BlockPartition::regular(p, 2 * p + 1);
            let inputs = int_inputs(p, part.total(), 7 + p as u64);
            let want = oracle_sum(&inputs);
            let sched = recursive_doubling_allreduce_schedule(p);
            sched.assert_valid();
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn rabenseifner_correct_any_p() {
        for p in [2usize, 4, 5, 8, 12, 16] {
            let part = BlockPartition::regular(p, 4 * p);
            let inputs = int_inputs(p, part.total(), 31 + p as u64);
            let want = oracle_sum(&inputs);
            let sched = rabenseifner_allreduce_schedule(p);
            sched.assert_valid();
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn halving_then_doubling_matches_alg2_volume_pow2() {
        // On powers of two the baseline achieves the same optimal counters
        // Theorem 2 states — the paper's point is achieving them for ALL p.
        let p = 16;
        let part = BlockPartition::uniform(p, 4);
        let mut sched = recursive_halving_rs_schedule(p);
        sched.rounds.extend(recursive_doubling_ag_schedule(p).rounds);
        for c in sched.counters(&part) {
            assert_eq!(c.blocks_sent, 2 * (p - 1));
            assert_eq!(c.blocks_combined, p - 1);
        }
    }
}
