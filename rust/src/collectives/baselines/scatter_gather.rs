//! Rooted scatter and gather (binomial block-tree specializations).
//!
//! §4 of the paper notes that "algorithms for the rooted, regular scatter
//! and gather problems can easily be derived" from the circulant schedules
//! by specialization. The classic derivation is the binomial block tree:
//! in round `k` (descending), a rank holding a contiguous run of blocks
//! forwards the half of its run belonging to its subtree partner — so
//! every block travels `≤ ⌈log2 p⌉` hops and each rank sends/receives only
//! the blocks it is responsible for (total volume `(p−1)/p·m` at the root,
//! optimal).
//!
//! These schedules complete the MPI collective family of §4:
//! MPI_Scatter = [`binomial_scatter_schedule`],
//! MPI_Gather = [`binomial_gather_schedule`] (the exact mirror).

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};
use crate::util::ceil_log2;

/// The contiguous run of (root-relative) blocks rank `rel` is responsible
/// for once it has been reached, at subtree width `width`:
/// `[rel, rel + min(width, p − rel))`.
fn subtree_run(rel: usize, width: usize, p: usize) -> (usize, usize) {
    (rel, width.min(p - rel))
}

/// Scatter from `root`: block `g` of root's vector ends at rank `g`
/// (sizes per the partition used at execution). `⌈log2 p⌉` rounds.
pub fn binomial_scatter_schedule(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    let mut sched = Schedule::new(p, format!("binomial-scatter(root={root})"));
    if p == 1 {
        return sched;
    }
    let q = ceil_log2(p) as usize;
    for k in (0..q).rev() {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for rel in 0..p {
            // sender: already reached (lower bits of rel are 0) and has a
            // partner rel+bit within range
            if rel & (bit - 1) == 0 && rel & bit == 0 && rel + bit < p {
                let child_rel = rel + bit;
                let (start, len) = subtree_run(child_rel, bit, p);
                let r = (rel + root) % p;
                let child = (child_rel + root) % p;
                // global block ids are root-relative too: block for rank x
                // is global block x, and x = (rel + root) mod p ⇒ the run
                // wraps as a circular range starting at (start + root).
                let blocks = BlockRange::new((start + root) % p, len);
                round.steps[r] = RankStep {
                    send: Some(Transfer { peer: child, blocks }),
                    recv: None,
                };
                round.steps[child] = RankStep {
                    send: None,
                    recv: Some(Recv { peer: r, blocks, action: RecvAction::Store }),
                };
            }
        }
        sched.rounds.push(round);
    }
    sched
}

/// Gather to `root`: the exact mirror of the scatter (blocks flow up the
/// binomial tree, each rank forwarding its collected run).
pub fn binomial_gather_schedule(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    let mut sched = Schedule::new(p, format!("binomial-gather(root={root})"));
    if p == 1 {
        return sched;
    }
    let q = ceil_log2(p) as usize;
    for k in 0..q {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for rel in 0..p {
            if rel & (bit - 1) == 0 && rel & bit == 0 && rel + bit < p {
                let child_rel = rel + bit;
                let (start, len) = subtree_run(child_rel, bit, p);
                let r = (rel + root) % p;
                let child = (child_rel + root) % p;
                let blocks = BlockRange::new((start + root) % p, len);
                round.steps[child] = RankStep {
                    send: Some(Transfer { peer: r, blocks }),
                    recv: None,
                };
                round.steps[r] = RankStep {
                    send: None,
                    recv: Some(Recv { peer: child, blocks, action: RecvAction::Store }),
                };
            }
        }
        sched.rounds.push(round);
    }
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use std::sync::Arc;

    #[test]
    fn scatter_delivers_each_block_to_its_rank() {
        for p in [2usize, 3, 5, 8, 13, 22] {
            for root in [0, p / 2, p - 1] {
                let b = 3;
                let part = BlockPartition::uniform(p, b);
                let sched = binomial_scatter_schedule(p, root);
                sched.assert_valid();
                assert!(sched.num_rounds() as u32 == ceil_log2(p));
                // only root has real data; others start zeroed
                let inputs: Vec<Vec<f32>> = (0..p)
                    .map(|r| {
                        if r == root {
                            (0..part.total()).map(|j| j as f32 + 1.0).collect()
                        } else {
                            vec![0.0; part.total()]
                        }
                    })
                    .collect();
                let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
                for (r, buf) in out.iter().enumerate() {
                    for (i, j) in part.range(r).enumerate() {
                        assert_eq!(
                            buf[part.range(r).start + i],
                            j as f32 + 1.0,
                            "p={p} root={root} rank {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gather_collects_all_blocks_at_root() {
        for p in [2usize, 4, 7, 16, 22] {
            let root = 1 % p;
            let b = 2;
            let part = BlockPartition::uniform(p, b);
            let sched = binomial_gather_schedule(p, root);
            sched.assert_valid();
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|r| {
                    let mut v = vec![0.0f32; part.total()];
                    for (i, x) in v[part.range(r)].iter_mut().enumerate() {
                        *x = (r * 10 + i) as f32;
                    }
                    v
                })
                .collect();
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for g in 0..p {
                for i in 0..b {
                    assert_eq!(out[root][part.range(g).start + i], (g * 10 + i) as f32, "p={p} g={g}");
                }
            }
        }
    }

    #[test]
    fn scatter_volume_is_optimal_at_root() {
        // Root sends each non-root block exactly once: (p−1)·b elements.
        let p = 16;
        let b = 5;
        let part = BlockPartition::uniform(p, b);
        let c = binomial_scatter_schedule(p, 0).counters(&part);
        assert_eq!(c[0].elems_sent, (p - 1) * b);
        // and a leaf receives exactly its own block
        assert_eq!(c[p - 1].elems_recv, b);
    }

    #[test]
    fn gather_mirrors_scatter_rounds() {
        for p in [2usize, 9, 22] {
            let s = binomial_scatter_schedule(p, 0);
            let g = binomial_gather_schedule(p, 0);
            assert_eq!(s.num_rounds(), g.num_rounds());
        }
    }
}
