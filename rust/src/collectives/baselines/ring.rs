//! Ring (bucket) algorithms — the classic bandwidth-optimal baselines with
//! a *linear* number of rounds ([10, 15] in the paper; §1's "well-known
//! algorithms assuming either a ring or a fully connected network").
//!
//! Reduce-scatter: `p−1` rounds; in round `k` rank `r` forwards the partial
//! of global block `(r−1−k) mod p` to `r+1` and folds the incoming partial
//! of block `(r−2−k) mod p`; block `g` travels `g+1 → g+2 → … → g`,
//! accumulating every rank's contribution.

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};

/// Ring reduce-scatter: `p−1` rounds, one block per message.
pub fn ring_reduce_scatter_schedule(p: usize) -> Schedule {
    let mut sched = Schedule::new(p, "ring-rs");
    if p == 1 {
        return sched;
    }
    for k in 0..p - 1 {
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let to = (r + 1) % p;
            let from = (r + p - 1) % p;
            let send_block = (r + p - 1 - k % p + p) % p;
            let recv_block = (r + 2 * p - 2 - k % p) % p;
            *step = RankStep {
                send: Some(Transfer { peer: to, blocks: BlockRange::new(send_block, 1) }),
                recv: Some(Recv {
                    peer: from,
                    blocks: BlockRange::new(recv_block, 1),
                    action: RecvAction::Combine,
                }),
            };
        }
        sched.rounds.push(round);
    }
    sched
}

/// Ring allgather: `p−1` rounds, one finished block per message.
/// Precondition: rank `r` holds finished block `r`.
pub fn ring_allgather_schedule(p: usize) -> Schedule {
    let mut sched = Schedule::new(p, "ring-ag");
    if p == 1 {
        return sched;
    }
    for k in 0..p - 1 {
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let to = (r + 1) % p;
            let from = (r + p - 1) % p;
            let send_block = (r + p - k % p) % p;
            let recv_block = (r + 2 * p - 1 - k % p) % p;
            *step = RankStep {
                send: Some(Transfer { peer: to, blocks: BlockRange::new(send_block, 1) }),
                recv: Some(Recv {
                    peer: from,
                    blocks: BlockRange::new(recv_block, 1),
                    action: RecvAction::Store,
                }),
            };
        }
        sched.rounds.push(round);
    }
    sched
}

/// Ring allreduce [15]: ring reduce-scatter + ring allgather;
/// `2(p−1)` rounds, volume-optimal, heavily latency-bound for large `p`.
pub fn ring_allreduce_schedule(p: usize) -> Schedule {
    let mut rs = ring_reduce_scatter_schedule(p);
    rs.name = "ring-allreduce".into();
    rs.rounds.extend(ring_allgather_schedule(p).rounds);
    rs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    #[test]
    fn ring_rs_valid_and_counts() {
        for p in 2..=32usize {
            let s = ring_reduce_scatter_schedule(p);
            s.assert_valid();
            assert_eq!(s.num_rounds(), p - 1);
            let part = BlockPartition::uniform(p, 2);
            for c in s.counters(&part) {
                assert_eq!(c.blocks_sent, p - 1); // volume optimal too
                assert_eq!(c.blocks_combined, p - 1);
            }
        }
    }

    #[test]
    fn ring_rs_correct() {
        for p in [2usize, 3, 6, 13] {
            let part = BlockPartition::regular(p, 2 * p + 1);
            let mut rng = SplitMix64::new(p as u64);
            let inputs: Vec<Vec<f32>> =
                (0..p).map(|_| rng.int_valued_vec(part.total(), -5, 6)).collect();
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(
                &ring_reduce_scatter_schedule(p),
                &part,
                Arc::new(SumOp),
                inputs,
            );
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &want[range], "p={p} r={r}");
            }
        }
    }

    #[test]
    fn ring_allreduce_correct() {
        for p in [2usize, 5, 9] {
            let part = BlockPartition::regular(p, 3 * p);
            let mut rng = SplitMix64::new(40 + p as u64);
            let inputs: Vec<Vec<f32>> =
                (0..p).map(|_| rng.int_valued_vec(part.total(), -5, 6)).collect();
            let want = oracle_sum(&inputs);
            let out =
                run_schedule_threads(&ring_allreduce_schedule(p), &part, Arc::new(SumOp), inputs);
            for buf in out {
                assert_eq!(buf, want, "p={p}");
            }
        }
    }
}
