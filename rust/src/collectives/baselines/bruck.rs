//! Bruck et al. allgather (dissemination / straight-doubling circulant) —
//! the classical `⌈log2 p⌉`-round allgather the paper builds on [8].
//!
//! Round `k` (distance `d = 2^k`): rank `r` sends its collected prefix of
//! blocks `r … r+min(d, p−d)` to `(r−d) mod p` and receives the next run
//! from `(r+d) mod p`. After `⌈log2 p⌉` rounds every rank holds all `p`
//! blocks. Unlike the paper's mirrored allgather (Algorithm 2 phase 2),
//! message runs here grow up to `p/2` blocks *and beyond* for non-powers
//! of two the last partial round sends `p − 2^{q−1}` blocks.

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};

/// Bruck dissemination allgather. Precondition: rank `r` holds block `r`.
pub fn bruck_allgather_schedule(p: usize) -> Schedule {
    let mut sched = Schedule::new(p, "bruck-ag");
    if p == 1 {
        return sched;
    }
    let mut d = 1usize;
    while d < p {
        let len = d.min(p - d);
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let to = (r + p - d) % p;
            let from = (r + d) % p;
            *step = RankStep {
                send: Some(Transfer { peer: to, blocks: BlockRange::new(r, len) }),
                recv: Some(Recv {
                    peer: from,
                    blocks: BlockRange::new(from, len),
                    action: RecvAction::Store,
                }),
            };
        }
        sched.rounds.push(round);
        d *= 2;
    }
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use crate::util::ceil_log2;
    use std::sync::Arc;

    #[test]
    fn allgather_collects_everything() {
        for p in [2usize, 3, 7, 8, 22] {
            let part = BlockPartition::regular(p, 2 * p + 1);
            // Rank r starts with only its own block set; rest zero.
            let mut want = vec![0.0f32; part.total()];
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|r| {
                    let mut v = vec![0.0f32; part.total()];
                    for (j, x) in v[part.range(r)].iter_mut().enumerate() {
                        *x = (r * 100 + j) as f32;
                    }
                    for (j, w) in want[part.range(r)].iter_mut().enumerate() {
                        *w = (r * 100 + j) as f32;
                    }
                    v
                })
                .collect();
            let sched = bruck_allgather_schedule(p);
            sched.assert_valid();
            assert_eq!(sched.num_rounds() as u32, ceil_log2(p), "p={p}");
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn volume_matches_p_minus_1_blocks() {
        for p in [4usize, 9, 16, 33] {
            let sched = bruck_allgather_schedule(p);
            let part = BlockPartition::uniform(p, 1);
            for c in sched.counters(&part) {
                assert_eq!(c.blocks_sent, p - 1, "p={p}");
                assert_eq!(c.blocks_recv, p - 1);
                assert_eq!(c.blocks_combined, 0); // pure data movement
            }
        }
    }

    #[test]
    fn message_runs_exceed_half_for_non_pow2() {
        // The §3 contrast: straight doubling lacks the ⌈p/2⌉ bound that
        // halving-up enjoys — for p=22 the last round sends runs longer
        // than would be needed.
        let sched = bruck_allgather_schedule(22);
        assert!(sched.max_message_blocks() >= 8);
    }
}
