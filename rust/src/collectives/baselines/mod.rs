//! Baseline collective algorithms the paper compares against (§1):
//! linear-round ring/fully-connected algorithms, hypercube
//! halving/doubling, Bruck dissemination allgather, and tree algorithms.

pub mod binomial;
pub mod bruck;
pub mod recursive;
pub mod ring;
pub mod scatter_gather;

pub use binomial::{binomial_allreduce_schedule, binomial_bcast_schedule, binomial_reduce_schedule};
pub use scatter_gather::{binomial_gather_schedule, binomial_scatter_schedule};
pub use bruck::bruck_allgather_schedule;
pub use recursive::{
    rabenseifner_allreduce_schedule, recursive_doubling_ag_schedule,
    recursive_doubling_allreduce_schedule, recursive_halving_rs_schedule,
};
pub use ring::{ring_allgather_schedule, ring_allreduce_schedule, ring_reduce_scatter_schedule};
