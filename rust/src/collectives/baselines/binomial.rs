//! Binomial-tree baselines: reduce-to-root, broadcast, and the
//! reduce+broadcast allreduce (the "two stage detour" the paper's
//! introduction warns about — full vector on every edge, so the β term is
//! `⌈log2 p⌉·m` instead of `2(p−1)/p·m`).

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};
use crate::util::ceil_log2;

/// Binomial-tree reduce to rank `root`: `⌈log2 p⌉` rounds; in round `k`
/// every rank with bit `k` set (relative to the root) and lower bits clear
/// sends its full partial vector to its parent.
pub fn binomial_reduce_schedule(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    let mut sched = Schedule::new(p, format!("binomial-reduce(root={root})"));
    if p == 1 {
        return sched;
    }
    let q = ceil_log2(p) as usize;
    for k in 0..q {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for rel in 0..p {
            // work in root-relative rank space
            if rel & ((bit << 1) - 1) == bit {
                let parent_rel = rel - bit;
                let r = (rel + root) % p;
                let parent = (parent_rel + root) % p;
                round.steps[r] = RankStep {
                    send: Some(Transfer { peer: parent, blocks: BlockRange::new(0, p) }),
                    recv: None,
                };
                round.steps[parent] = RankStep {
                    send: None,
                    recv: Some(Recv {
                        peer: r,
                        blocks: BlockRange::new(0, p),
                        action: RecvAction::Combine,
                    }),
                };
            }
        }
        sched.rounds.push(round);
    }
    sched
}

/// Binomial-tree broadcast from rank `root` (mirror of the reduce).
pub fn binomial_bcast_schedule(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    let mut sched = Schedule::new(p, format!("binomial-bcast(root={root})"));
    if p == 1 {
        return sched;
    }
    let q = ceil_log2(p) as usize;
    for k in (0..q).rev() {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for rel in 0..p {
            if rel & ((bit << 1) - 1) == bit {
                let parent_rel = rel - bit;
                let r = (rel + root) % p;
                let parent = (parent_rel + root) % p;
                round.steps[parent] = RankStep {
                    send: Some(Transfer { peer: r, blocks: BlockRange::new(0, p) }),
                    recv: None,
                };
                round.steps[r] = RankStep {
                    send: None,
                    recv: Some(Recv {
                        peer: parent,
                        blocks: BlockRange::new(0, p),
                        action: RecvAction::Store,
                    }),
                };
            }
        }
        sched.rounds.push(round);
    }
    sched
}

/// Reduce + broadcast allreduce: `2⌈log2 p⌉` rounds, full-vector edges.
pub fn binomial_allreduce_schedule(p: usize) -> Schedule {
    let mut sched = binomial_reduce_schedule(p, 0);
    sched.name = "binomial-allreduce".into();
    sched.rounds.extend(binomial_bcast_schedule(p, 0).rounds);
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::{MaxOp, SumOp};
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    #[test]
    fn reduce_reaches_root_any_p_any_root() {
        for p in [2usize, 3, 5, 8, 13] {
            for root in [0, p / 2, p - 1] {
                let part = BlockPartition::regular(p, p + 2);
                let mut rng = SplitMix64::new((p * 31 + root) as u64);
                let inputs: Vec<Vec<f32>> =
                    (0..p).map(|_| rng.int_valued_vec(part.total(), -4, 5)).collect();
                let want = oracle_sum(&inputs);
                let sched = binomial_reduce_schedule(p, root);
                sched.assert_valid();
                let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
                assert_eq!(out[root], want, "p={p} root={root}");
            }
        }
    }

    #[test]
    fn allreduce_correct_and_round_count() {
        for p in [2usize, 6, 9, 16] {
            let part = BlockPartition::regular(p, 2 * p);
            let mut rng = SplitMix64::new(p as u64);
            let inputs: Vec<Vec<f32>> =
                (0..p).map(|_| rng.int_valued_vec(part.total(), -4, 5)).collect();
            let want = oracle_sum(&inputs);
            let sched = binomial_allreduce_schedule(p);
            sched.assert_valid();
            assert_eq!(sched.num_rounds(), 2 * ceil_log2(p) as usize);
            let out = run_schedule_threads(&sched, &part, Arc::new(MaxOp), inputs.clone());
            // max oracle
            let mut wmax = vec![f32::NEG_INFINITY; want.len()];
            for v in &inputs {
                for (a, b) in wmax.iter_mut().zip(v) {
                    *a = a.max(*b);
                }
            }
            for buf in out {
                assert_eq!(buf, wmax, "p={p}");
            }
        }
    }

    #[test]
    fn full_vector_volume_is_the_penalty() {
        // The β-term inefficiency vs Theorem 2: q·m elements vs 2(p−1)/p·m.
        let p = 16;
        let part = BlockPartition::uniform(p, 10);
        let sched = binomial_allreduce_schedule(p);
        let counters = sched.counters(&part);
        // Rank 1 is a leaf in both trees: sends m once, receives m once.
        assert_eq!(counters[1].elems_sent, part.total());
        // Rank 0 (root) receives q full vectors and sends q full vectors.
        let q = ceil_log2(p) as usize;
        assert_eq!(counters[0].elems_recv, q * part.total());
        assert_eq!(counters[0].elems_sent, q * part.total());
    }
}
