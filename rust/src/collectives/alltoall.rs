//! All-to-all communication on the circulant schedule (paper §4), generic
//! over the element type.
//!
//! Take the reduce-scatter algorithm and let ⊕ be *concatenation*: each
//! partial "sum" for destination `d` is the multiset of `(source, block)`
//! pairs collected so far. After `⌈log2 p⌉` rounds, rank `r`'s slot for
//! destination `r` holds every rank's block for `r` — which is exactly the
//! all-to-all receive row. The "craft" (§4): payloads are framed with
//! `(source, length)` headers so blocks can be reordered into rank order
//! on delivery, and message sizes now *grow* with the subtree sizes
//! (`topology::spanning::subtree_sizes`), giving total volume
//! `Θ(m/2·⌈log2 p⌉)` instead of reduce-scatter's `(p−1)/p·m`.
//!
//! Frame headers are encoded in the payload's own element type via
//! [`Elem::from_usize`]/[`Elem::to_usize`] — exact for the small
//! non-negative counts involved in every supported dtype. The pack path
//! *asserts* each header survives the round-trip (floats lose integer
//! exactness past 2^24/2^53), so an outsized block aborts loudly rather
//! than mis-framing the payload.
//!
//! This module executes directly over the transport (the growing,
//! tag-framed payloads don't fit the fixed-block Schedule IR); round
//! structure and peers are identical to `generators::reduce_scatter_schedule`.

use crate::datatypes::{BlockPartition, Elem};
use crate::topology::skips::validate;
use crate::transport::Endpoint;

use super::exec::CollectiveError;

/// One collected entry: a source rank's block for some destination.
#[derive(Debug, Clone, PartialEq)]
struct Entry<T: Elem> {
    source: usize,
    data: Vec<T>,
}

/// Frame a slot run into a flat payload:
/// `[num_entries, (source, len, data…)*…]` per slot, slots in run order.
/// Header values are exact in every dtype (see module docs).
#[cfg(test)]
fn pack<T: Elem>(slots: &[Vec<Entry<T>>]) -> Vec<T> {
    let mut out = Vec::new();
    pack_into(&mut out, slots);
    out
}

/// Push one frame-header value, asserting it survives the dtype's
/// integer round-trip. Float dtypes lose exactness past 2^24 (f32) /
/// 2^53 (f64); a header that rounds would silently mis-frame the whole
/// payload downstream, so refuse loudly instead. (Entry lengths that
/// large mean ≥ 64 MiB blocks — far past any bench here — and integer
/// dtypes are always exact.)
fn push_header<T: Elem>(out: &mut Vec<T>, v: usize) {
    let h = T::from_usize(v);
    assert!(
        h.to_usize() == v,
        "all-to-all frame header {v} is not exactly representable in {:?}",
        T::DTYPE
    );
    out.push(h);
}

/// [`pack`] into a caller-provided (pooled) buffer instead of allocating.
fn pack_into<T: Elem>(out: &mut Vec<T>, slots: &[Vec<Entry<T>>]) {
    for slot in slots {
        push_header(out, slot.len());
        for e in slot {
            push_header(out, e.source);
            push_header(out, e.data.len());
            out.extend_from_slice(&e.data);
        }
    }
}

/// Exact element count [`pack_into`] will produce for `slots` — computed
/// up front so the pooled buffer is acquired at full size (no regrow).
fn packed_len<T: Elem>(slots: &[Vec<Entry<T>>]) -> usize {
    slots
        .iter()
        .map(|slot| 1 + slot.iter().map(|e| 2 + e.data.len()).sum::<usize>())
        .sum()
}

/// Inverse of [`pack`] for `n_slots` slots.
fn unpack<T: Elem>(
    payload: &[T],
    n_slots: usize,
    rank: usize,
    round: usize,
) -> Result<Vec<Vec<Entry<T>>>, CollectiveError> {
    let mut slots = Vec::with_capacity(n_slots);
    let mut i = 0usize;
    let bad = |got: usize| CollectiveError::BadPayload { rank, got, want: 0, round };
    for _ in 0..n_slots {
        if i >= payload.len() {
            return Err(bad(payload.len()));
        }
        let n = payload[i].to_usize();
        i += 1;
        let mut slot = Vec::with_capacity(n);
        for _ in 0..n {
            if i + 2 > payload.len() {
                return Err(bad(payload.len()));
            }
            let source = payload[i].to_usize();
            let len = payload[i + 1].to_usize();
            i += 2;
            if i + len > payload.len() {
                return Err(bad(payload.len()));
            }
            slot.push(Entry { source, data: payload[i..i + len].to_vec() });
            i += len;
        }
        slots.push(slot);
    }
    Ok(slots)
}

/// Per-rank all-to-all: `input` is rank `r`'s send vector partitioned by
/// `part` (block `g` goes to rank `g`); returns the receive vector in the
/// same layout (block `g` came from rank `g`).
///
/// `skips` must be a valid sequence (e.g. `SkipScheme::HalvingUp`).
pub fn alltoall_rank<T: Elem>(
    ep: &mut Endpoint<T>,
    part: &BlockPartition,
    skips: &[usize],
    input: &[T],
    round_base: u64,
) -> Result<Vec<T>, CollectiveError> {
    let p = part.p();
    let r = ep.rank;
    validate(p, skips)
        .map_err(|e| CollectiveError::InvalidSchedule { rank: r, source: e.into() })?;
    if input.len() != part.total() {
        return Err(CollectiveError::BadBuffer { rank: r, got: input.len(), want: part.total() });
    }
    // slots[i] = collected entries destined for rank (r + i) mod p
    // (distance space, like the paper's R[i]).
    let mut slots: Vec<Vec<Entry<T>>> = (0..p)
        .map(|i| {
            let dest = (r + i) % p;
            vec![Entry { source: r, data: input[part.range(dest)].to_vec() }]
        })
        .collect();

    let mut prev = p;
    for (k, &s) in skips.iter().enumerate() {
        let len = prev - s;
        let to = (r + s) % p;
        let from = (r + p - s) % p;
        // Send slots [s, prev) — they migrate to the to-processor, where
        // they sit at distance [0, len). Frame into a pooled buffer and
        // hand the received one back once unpacked (the loan protocol).
        let mut payload = ep.acquire(to, packed_len(&slots[s..prev]));
        pack_into(&mut payload, &slots[s..prev]);
        let received = ep
            .sendrecv_owned(Some((to, payload)), Some(from), round_base + k as u64)?
            .expect("recv requested");
        let incoming = unpack(&received, len, r, k)?;
        ep.release(from, received);
        for (j, entries) in incoming.into_iter().enumerate() {
            slots[j].extend(entries); // ⊕ = concatenation
            slots[s + j].clear(); // migrated away (mirrors R's live region)
        }
        prev = s;
    }

    // slots[0] now holds every rank's block for destination r; scatter the
    // entries into rank order. Output layout: block g = data from rank g.
    let out_part = receive_partition(part, r);
    let mut out = vec![T::zero(); out_part.total()];
    let mut seen = vec![false; p];
    for e in &slots[0] {
        let range = out_part.range(e.source);
        if e.data.len() != range.len() || seen[e.source] {
            return Err(CollectiveError::BadPayload {
                rank: r,
                got: e.data.len(),
                want: range.len(),
                round: skips.len(),
            });
        }
        seen[e.source] = true;
        out[range].copy_from_slice(&e.data);
    }
    if !seen.iter().all(|&s| s) {
        return Err(CollectiveError::BadPayload { rank: r, got: slots[0].len(), want: p, round: skips.len() });
    }
    Ok(out)
}

/// The layout of rank `r`'s receive vector: block `g` has the size of the
/// block every rank sends *to r* — under a shared send partition that is
/// `part.size(r)` for every source, so the receive partition is uniform.
pub fn receive_partition(part: &BlockPartition, r: usize) -> BlockPartition {
    BlockPartition::uniform(part.p(), part.size(r))
}

/// Irregular all-to-all (MPI_Alltoallv): every (source, destination) pair
/// may exchange a different element count.
///
/// `send_counts[g]` is how many elements this rank sends to rank `g`
/// (`input` is their concatenation in rank order); `recv_counts[g]` is how
/// many it receives from rank `g` (the caller knows its column of the
/// count matrix, as in MPI). The schedule is identical to [`alltoall_rank`]
/// — the framed payloads already carry per-entry lengths, so irregularity
/// costs nothing extra; only the delivery layout differs.
pub fn alltoallv_rank<T: Elem>(
    ep: &mut Endpoint<T>,
    send_counts: &[usize],
    recv_counts: &[usize],
    skips: &[usize],
    input: &[T],
    round_base: u64,
) -> Result<Vec<T>, CollectiveError> {
    let p = ep.p;
    let r = ep.rank;
    if send_counts.len() != p || recv_counts.len() != p {
        return Err(CollectiveError::BadBuffer { rank: r, got: send_counts.len(), want: p });
    }
    let send_part = BlockPartition::from_counts(send_counts);
    validate(p, skips)
        .map_err(|e| CollectiveError::InvalidSchedule { rank: r, source: e.into() })?;
    if input.len() != send_part.total() {
        return Err(CollectiveError::BadBuffer {
            rank: r,
            got: input.len(),
            want: send_part.total(),
        });
    }
    let mut slots: Vec<Vec<Entry<T>>> = (0..p)
        .map(|i| {
            let dest = (r + i) % p;
            vec![Entry { source: r, data: input[send_part.range(dest)].to_vec() }]
        })
        .collect();
    let mut prev = p;
    for (k, &s) in skips.iter().enumerate() {
        let len = prev - s;
        let to = (r + s) % p;
        let from = (r + p - s) % p;
        let mut payload = ep.acquire(to, packed_len(&slots[s..prev]));
        pack_into(&mut payload, &slots[s..prev]);
        let received = ep
            .sendrecv_owned(Some((to, payload)), Some(from), round_base + k as u64)?
            .expect("recv requested");
        let incoming = unpack(&received, len, r, k)?;
        ep.release(from, received);
        for (j, entries) in incoming.into_iter().enumerate() {
            slots[j].extend(entries);
            slots[s + j].clear();
        }
        prev = s;
    }
    let recv_part = BlockPartition::from_counts(recv_counts);
    let mut out = vec![T::zero(); recv_part.total()];
    let mut seen = vec![false; p];
    for e in &slots[0] {
        let range = recv_part.range(e.source);
        if e.data.len() != range.len() || seen[e.source] {
            return Err(CollectiveError::BadPayload {
                rank: r,
                got: e.data.len(),
                want: range.len(),
                round: skips.len(),
            });
        }
        seen[e.source] = true;
        out[range].copy_from_slice(&e.data);
    }
    if !seen.iter().all(|&s| s) {
        return Err(CollectiveError::BadPayload {
            rank: r,
            got: slots[0].len(),
            want: p,
            round: skips.len(),
        });
    }
    Ok(out)
}

/// Total elements a rank sends over the whole all-to-all (the §4 volume
/// observation): sum over rounds of the migrated subtree payloads.
/// Computed from the spanning forest, excluding framing overhead.
pub fn alltoall_send_volume(part: &BlockPartition, skips: &[usize]) -> usize {
    use crate::topology::spanning::SpanningTree;
    let p = part.p();
    if p == 1 {
        return 0;
    }
    let tree = SpanningTree::build(p, skips);
    let sizes = tree.subtree_sizes();
    // Block at distance d carries `sizes[d]` block payloads when sent; for
    // a regular partition each payload is m/p elements. For irregular
    // partitions each entry keeps its destination's size; we approximate
    // with the average (exact for regular partitions; benches use regular).
    let avg = part.total() as f64 / p as f64;
    let blocks: usize = (1..p).map(|d| sizes[d]).sum();
    (blocks as f64 * avg).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::SkipScheme;
    use crate::transport::{run_ranks, run_ranks_typed};
    use std::sync::Arc;

    /// Reference all-to-all: out[r][g] = in[g][r-block].
    fn run_alltoall(p: usize, block: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let part = BlockPartition::uniform(p, block);
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|r| {
                (0..part.total())
                    .map(|j| (r * 1000 + j) as f32) // globally unique values
                    .collect()
            })
            .collect();
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let part2 = Arc::new(part.clone());
        let skips2 = Arc::new(skips);
        let inputs2 = Arc::new(inputs.clone());
        let outs = run_ranks(p, move |rank, ep| {
            alltoall_rank(ep, &part2, &skips2, &inputs2[rank], 0).unwrap()
        });
        (inputs, outs)
    }

    #[test]
    fn alltoall_is_the_transpose() {
        for p in [2usize, 3, 5, 8, 22] {
            let block = 3;
            let part = BlockPartition::uniform(p, block);
            let (inputs, outs) = run_alltoall(p, block);
            for r in 0..p {
                for g in 0..p {
                    let got = &outs[r][r * 0 + g * block..(g + 1) * block];
                    let want = &inputs[g][part.range(r)];
                    assert_eq!(got, want, "p={p} r={r} g={g}");
                }
            }
        }
    }

    #[test]
    fn alltoall_transpose_in_i64_is_exact() {
        // Same transpose over an integer network — headers and payloads
        // share the i64 dtype; values exceed 2^24 to prove the framing is
        // not float-limited.
        let p = 5usize;
        let block = 2;
        let part = BlockPartition::uniform(p, block);
        let base = 1i64 << 40;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..part.total()).map(|j| base + (r as i64) * 1000 + j as i64).collect())
            .collect();
        let skips = Arc::new(SkipScheme::HalvingUp.skips(p).unwrap());
        let part2 = Arc::new(part.clone());
        let inputs2 = Arc::new(inputs.clone());
        let outs = run_ranks_typed::<i64, _, _>(p, move |rank, ep| {
            alltoall_rank(ep, &part2, &skips, &inputs2[rank], 0).unwrap()
        });
        for r in 0..p {
            for g in 0..p {
                assert_eq!(
                    &outs[r][g * block..(g + 1) * block],
                    &inputs[g][part.range(r)],
                    "p={p} r={r} g={g}"
                );
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let slots = vec![
            vec![Entry { source: 3, data: vec![1.0, 2.0] }],
            vec![],
            vec![
                Entry { source: 0, data: vec![] },
                Entry { source: 7, data: vec![9.0] },
            ],
        ];
        let packed = pack(&slots);
        let back = unpack(&packed, 3, 0, 0).unwrap();
        assert_eq!(back, slots);
    }

    #[test]
    fn unpack_rejects_truncation() {
        let slots = vec![vec![Entry { source: 1, data: vec![1.0, 2.0, 3.0] }]];
        let packed = pack(&slots);
        assert!(unpack(&packed[..packed.len() - 1], 1, 0, 0).is_err());
        assert!(unpack(&packed, 2, 0, 0).is_err());
    }

    #[test]
    fn alltoallv_irregular_counts() {
        // count matrix C[src][dst] = (src + 2·dst) % 5 — includes zeros.
        for p in [2usize, 4, 7, 11] {
            let cnt = |src: usize, dst: usize| (src + 2 * dst) % 5;
            let skips = Arc::new(SkipScheme::HalvingUp.skips(p).unwrap());
            let outs = run_ranks(p, move |rank, ep| {
                let send_counts: Vec<usize> = (0..p).map(|d| cnt(rank, d)).collect();
                let recv_counts: Vec<usize> = (0..p).map(|s| cnt(s, rank)).collect();
                // element value encodes (src, dst, index) uniquely
                let mut input = Vec::new();
                for d in 0..p {
                    for i in 0..cnt(rank, d) {
                        input.push((rank * 10_000 + d * 100 + i) as f32);
                    }
                }
                alltoallv_rank(ep, &send_counts, &recv_counts, &skips, &input, 0).unwrap()
            });
            for (r, out) in outs.iter().enumerate() {
                let mut off = 0;
                for s in 0..p {
                    for i in 0..cnt(s, r) {
                        assert_eq!(out[off], (s * 10_000 + r * 100 + i) as f32, "p={p} r={r} s={s}");
                        off += 1;
                    }
                }
                assert_eq!(off, out.len());
            }
        }
    }

    #[test]
    fn alltoallv_rejects_bad_counts() {
        let skips = Arc::new(SkipScheme::HalvingUp.skips(2).unwrap());
        let outs = run_ranks(2, move |rank, ep| {
            if rank == 0 {
                // claims to expect 3 elems from rank 1, which sends 2
                alltoallv_rank(ep, &[0, 2], &[0, 3], &skips, &[1.0, 2.0], 0).is_err()
            } else {
                let _ = alltoallv_rank(ep, &[2, 0], &[2, 0], &skips, &[9.0, 8.0], 0);
                true
            }
        });
        assert!(outs[0], "mismatched recv_counts must be detected");
    }

    #[test]
    fn volume_grows_like_half_m_log_p() {
        // §4: total payload ≈ (m/2)·⌈log2 p⌉ per rank for regular blocks —
        // within a factor accounting for non-power-of-two rounding.
        for p in [16usize, 64, 100] {
            let part = BlockPartition::uniform(p, 8);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let vol = alltoall_send_volume(&part, &skips) as f64;
            let m = part.total() as f64;
            let q = skips.len() as f64;
            assert!(vol > 0.3 * m / 2.0 * q, "p={p} vol={vol}");
            assert!(vol < 1.5 * m / 2.0 * q, "p={p} vol={vol}");
        }
    }
}
