//! Hierarchical (two-level) allreduce — the §3 extension.
//!
//! The paper's §3 warns that "the doubling and halving schemes lead to
//! latency contention and communication redundancy when run as written on
//! clustered, hierarchical systems with constrained per node bandwidth
//! [21]". The standard remedy (Träff & Hunold [21]) is decomposition:
//!
//!   1. intra-node reduce to a node leader (binomial tree, node-local
//!      edges only),
//!   2. the paper's circulant allreduce (Algorithm 2) among the `L`
//!      leaders, over the vector split into `L` block groups,
//!   3. intra-node broadcast from the leader.
//!
//! Every phase is expressible in the shared schedule IR, so the same
//! executor, simulator and property tests apply. The companion two-level
//! cost model lives in `sim::hier`; the ablation bench is
//! `rust/benches/t6_hierarchical.rs`.

use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Schedule, Transfer};
use crate::topology::skips::SkipScheme;
use crate::util::ceil_log2;

/// Two-level allreduce schedule for `p` ranks in nodes of `node_size`
/// consecutive ranks (the last node may be smaller). Leaders are the first
/// rank of each node.
pub fn hierarchical_allreduce_schedule(
    p: usize,
    node_size: usize,
    scheme: &SkipScheme,
) -> Schedule {
    assert!(node_size >= 1);
    let mut sched = Schedule::new(p, format!("hier-allreduce(node={node_size},{})", scheme.name()));
    if p == 1 {
        return sched;
    }
    let node_of = |r: usize| r / node_size;
    let leader_of = |r: usize| node_of(r) * node_size;
    let num_nodes = p.div_ceil(node_size);
    let node_len = |n: usize| (p - n * node_size).min(node_size);

    // ---- phase 1: intra-node binomial reduce to the leader -------------
    let max_node = (0..num_nodes).map(node_len).max().unwrap();
    let q_intra = ceil_log2(max_node) as usize;
    for k in 0..q_intra {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for r in 0..p {
            let off = r - leader_of(r);
            if off & ((bit << 1) - 1) == bit {
                let parent = r - bit;
                round.steps[r] = RankStep {
                    send: Some(Transfer { peer: parent, blocks: BlockRange::new(0, p) }),
                    recv: None,
                };
                round.steps[parent] = RankStep {
                    send: None,
                    recv: Some(Recv {
                        peer: r,
                        blocks: BlockRange::new(0, p),
                        action: RecvAction::Combine,
                    }),
                };
            }
        }
        sched.rounds.push(round);
    }

    // ---- phase 2: circulant Algorithm 2 among leaders ------------------
    // The p-block space is grouped into `num_nodes` contiguous block
    // groups; leader i plays rank i over groups (cf. Rabenseifner's
    // grouping, but with the paper's uniform-in-L circulant schedule, so
    // L need not be a power of two).
    if num_nodes > 1 {
        let skips = scheme.skips(num_nodes).expect("valid scheme for leader count");
        let group_start = |g: usize| -> usize { (g % num_nodes) * p / num_nodes };
        // A run of `len` consecutive groups starting at group `a` (mod L)
        // covers a circular, contiguous run of global blocks: group g is
        // blocks [g·p/L, (g+1)·p/L), and consecutive groups abut (wrapping
        // at L back to block 0).
        let group_range = |a: usize, len: usize| -> BlockRange {
            let start = group_start(a);
            let mut len_blocks = 0usize;
            for j in 0..len {
                let g = (a + j) % num_nodes;
                len_blocks += (g + 1) * p / num_nodes - g * p / num_nodes;
            }
            BlockRange::new(start, len_blocks)
        };
        // reduce-scatter phase over groups
        let mut prev = num_nodes;
        for &s in &skips {
            let len = prev - s;
            let mut round = Round::idle(p);
            for i in 0..num_nodes {
                let r = i * node_size;
                let to = ((i + s) % num_nodes) * node_size;
                let from = ((i + num_nodes - s) % num_nodes) * node_size;
                round.steps[r] = RankStep {
                    send: Some(Transfer { peer: to, blocks: group_range((i + s) % num_nodes, len) }),
                    recv: Some(Recv {
                        peer: from,
                        blocks: group_range(i, len),
                        action: RecvAction::Combine,
                    }),
                };
            }
            sched.rounds.push(round);
            prev = s;
        }
        // mirrored allgather phase
        for k in (0..skips.len()).rev() {
            let s = skips[k];
            let prev = if k == 0 { num_nodes } else { skips[k - 1] };
            let len = prev - s;
            let mut round = Round::idle(p);
            for i in 0..num_nodes {
                let r = i * node_size;
                let to = ((i + num_nodes - s) % num_nodes) * node_size;
                let from = ((i + s) % num_nodes) * node_size;
                round.steps[r] = RankStep {
                    send: Some(Transfer { peer: to, blocks: group_range(i, len) }),
                    recv: Some(Recv {
                        peer: from,
                        blocks: group_range((i + s) % num_nodes, len),
                        action: RecvAction::Store,
                    }),
                };
            }
            sched.rounds.push(round);
        }
    }

    // ---- phase 3: intra-node binomial broadcast from the leader --------
    for k in (0..q_intra).rev() {
        let bit = 1usize << k;
        let mut round = Round::idle(p);
        for r in 0..p {
            let off = r - leader_of(r);
            if off & ((bit << 1) - 1) == bit {
                let parent = r - bit;
                round.steps[parent] = RankStep {
                    send: Some(Transfer { peer: r, blocks: BlockRange::new(0, p) }),
                    recv: None,
                };
                round.steps[r] = RankStep {
                    send: None,
                    recv: Some(Recv {
                        peer: parent,
                        blocks: BlockRange::new(0, p),
                        action: RecvAction::Store,
                    }),
                };
            }
        }
        sched.rounds.push(round);
    }
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::analysis as symbolic;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    #[test]
    fn hierarchical_allreduce_correct() {
        for (p, node) in [(4usize, 2usize), (8, 4), (12, 3), (22, 4), (9, 4), (7, 3)] {
            let sched = hierarchical_allreduce_schedule(p, node, &SkipScheme::HalvingUp);
            sched.assert_valid();
            symbolic::verify_allreduce(&sched)
                .unwrap_or_else(|e| panic!("p={p} node={node}: {e}"));
            let part = BlockPartition::regular(p, 3 * p + 1);
            let mut rng = SplitMix64::new((p * node) as u64);
            let inputs: Vec<Vec<f32>> =
                (0..p).map(|_| rng.int_valued_vec(part.total(), -5, 6)).collect();
            let mut want = vec![0.0f32; part.total()];
            for v in &inputs {
                for (a, x) in want.iter_mut().zip(v) {
                    *a += x;
                }
            }
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} node={node} r={r}");
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        // node_size = 1 → pure circulant Alg 2 (plus empty intra phases)
        let flat = hierarchical_allreduce_schedule(8, 1, &SkipScheme::HalvingUp);
        flat.assert_valid();
        symbolic::verify_allreduce(&flat).unwrap();
        // node_size ≥ p → pure reduce+bcast within one node
        let one = hierarchical_allreduce_schedule(8, 8, &SkipScheme::HalvingUp);
        one.assert_valid();
        symbolic::verify_allreduce(&one).unwrap();
    }

    #[test]
    fn inter_node_traffic_is_leaders_only() {
        let p = 16;
        let node = 4;
        let sched = hierarchical_allreduce_schedule(p, node, &SkipScheme::HalvingUp);
        for round in &sched.rounds {
            for (r, step) in round.steps.iter().enumerate() {
                if let Some(send) = &step.send {
                    let cross = r / node != send.peer / node;
                    if cross {
                        assert_eq!(r % node, 0, "non-leader {r} sent across nodes");
                        assert_eq!(send.peer % node, 0);
                    }
                }
            }
        }
    }
}
