//! The collective algorithms: the paper's circulant-graph schedules plus
//! every baseline, behind a single [`Algorithm`] selector.

pub mod alltoall;
pub mod baselines;
pub mod derived;
pub mod exec;
pub mod generators;
pub mod hierarchical;
pub mod symbolic;

pub use exec::{execute_rank, run_schedule_threads, CollectiveError};
pub use generators::{allgather_schedule, allreduce_schedule, reduce_scatter_schedule};

use crate::schedule::Schedule;
use crate::topology::skips::SkipScheme;

/// Every schedule-expressible algorithm in the library, for the CLI,
/// benches and the simulator. (All-to-all is separate — `alltoall` — since
/// its payloads grow per round.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 1 with a skip scheme (default: halving-up).
    CirculantReduceScatter(SkipScheme),
    /// Algorithm 2 (reduce-scatter + mirrored allgather).
    CirculantAllreduce(SkipScheme),
    /// The mirrored allgather alone.
    CirculantAllgather(SkipScheme),
    RingReduceScatter,
    RingAllreduce,
    RingAllgather,
    /// Power-of-two only.
    RecursiveHalvingReduceScatter,
    RecursiveDoublingAllreduce,
    RabenseifnerAllreduce,
    BinomialReduce { root: usize },
    BinomialBcast { root: usize },
    BinomialAllreduce,
    BruckAllgather,
}

impl Algorithm {
    /// Parse a CLI/config name. Circulant variants accept an optional
    /// `:scheme` suffix, e.g. `allreduce:pow2` or `reduce-scatter:sqrt`.
    pub fn parse(s: &str) -> Option<Algorithm> {
        let (head, scheme) = match s.split_once(':') {
            Some((h, sch)) => (h, SkipScheme::parse(sch).ok()?),
            None => (s, SkipScheme::HalvingUp),
        };
        Some(match head {
            "reduce-scatter" | "rs" => Algorithm::CirculantReduceScatter(scheme),
            "allreduce" | "ar" => Algorithm::CirculantAllreduce(scheme),
            "allgather" | "ag" => Algorithm::CirculantAllgather(scheme),
            "ring-rs" => Algorithm::RingReduceScatter,
            "ring-allreduce" => Algorithm::RingAllreduce,
            "ring-ag" => Algorithm::RingAllgather,
            "rec-halving-rs" => Algorithm::RecursiveHalvingReduceScatter,
            "rec-doubling-allreduce" => Algorithm::RecursiveDoublingAllreduce,
            "rabenseifner" => Algorithm::RabenseifnerAllreduce,
            "binomial-allreduce" => Algorithm::BinomialAllreduce,
            "bruck-ag" => Algorithm::BruckAllgather,
            _ => return None,
        })
    }

    /// Short display name.
    pub fn name(&self) -> String {
        match self {
            Algorithm::CirculantReduceScatter(s) => format!("circulant-rs({})", s.name()),
            Algorithm::CirculantAllreduce(s) => format!("circulant-allreduce({})", s.name()),
            Algorithm::CirculantAllgather(s) => format!("circulant-ag({})", s.name()),
            Algorithm::RingReduceScatter => "ring-rs".into(),
            Algorithm::RingAllreduce => "ring-allreduce".into(),
            Algorithm::RingAllgather => "ring-ag".into(),
            Algorithm::RecursiveHalvingReduceScatter => "rec-halving-rs".into(),
            Algorithm::RecursiveDoublingAllreduce => "rec-doubling-allreduce".into(),
            Algorithm::RabenseifnerAllreduce => "rabenseifner".into(),
            Algorithm::BinomialReduce { root } => format!("binomial-reduce({root})"),
            Algorithm::BinomialBcast { root } => format!("binomial-bcast({root})"),
            Algorithm::BinomialAllreduce => "binomial-allreduce".into(),
            Algorithm::BruckAllgather => "bruck-ag".into(),
        }
    }

    /// Build the schedule for `p` ranks.
    pub fn schedule(&self, p: usize) -> Schedule {
        match self {
            Algorithm::CirculantReduceScatter(s) => {
                generators::reduce_scatter_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::CirculantAllreduce(s) => {
                generators::allreduce_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::CirculantAllgather(s) => {
                generators::allgather_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::RingReduceScatter => baselines::ring_reduce_scatter_schedule(p),
            Algorithm::RingAllreduce => baselines::ring_allreduce_schedule(p),
            Algorithm::RingAllgather => baselines::ring_allgather_schedule(p),
            Algorithm::RecursiveHalvingReduceScatter => {
                baselines::recursive_halving_rs_schedule(p)
            }
            Algorithm::RecursiveDoublingAllreduce => {
                baselines::recursive_doubling_allreduce_schedule(p)
            }
            Algorithm::RabenseifnerAllreduce => baselines::rabenseifner_allreduce_schedule(p),
            Algorithm::BinomialReduce { root } => baselines::binomial_reduce_schedule(p, *root),
            Algorithm::BinomialBcast { root } => baselines::binomial_bcast_schedule(p, *root),
            Algorithm::BinomialAllreduce => baselines::binomial_allreduce_schedule(p),
            Algorithm::BruckAllgather => baselines::bruck_allgather_schedule(p),
        }
    }

    /// Does the result semantics cover the whole vector on every rank?
    pub fn is_allreduce(&self) -> bool {
        matches!(
            self,
            Algorithm::CirculantAllreduce(_)
                | Algorithm::RingAllreduce
                | Algorithm::RecursiveDoublingAllreduce
                | Algorithm::RabenseifnerAllreduce
                | Algorithm::BinomialAllreduce
        )
    }

    /// Reduce-scatter semantics (block `r` finished at rank `r`)?
    pub fn is_reduce_scatter(&self) -> bool {
        matches!(
            self,
            Algorithm::CirculantReduceScatter(_)
                | Algorithm::RingReduceScatter
                | Algorithm::RecursiveHalvingReduceScatter
        )
    }

    /// All allreduce algorithms, for comparison sweeps (F1/F2 benches).
    pub fn allreduce_family() -> Vec<Algorithm> {
        vec![
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
            Algorithm::RingAllreduce,
            Algorithm::RecursiveDoublingAllreduce,
            Algorithm::RabenseifnerAllreduce,
            Algorithm::BinomialAllreduce,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Algorithm::parse("allreduce").unwrap(),
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp)
        );
        assert_eq!(
            Algorithm::parse("rs:pow2").unwrap(),
            Algorithm::CirculantReduceScatter(SkipScheme::PowerOfTwo)
        );
        assert_eq!(Algorithm::parse("ring-allreduce").unwrap(), Algorithm::RingAllreduce);
        assert!(Algorithm::parse("nope").is_none());
        assert!(Algorithm::parse("rs:nope").is_none());
    }

    #[test]
    fn all_schedules_structurally_valid() {
        for p in [2usize, 3, 8, 22] {
            for alg in [
                Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
                Algorithm::CirculantAllreduce(SkipScheme::Sqrt),
                Algorithm::CirculantAllgather(SkipScheme::PowerOfTwo),
                Algorithm::RingReduceScatter,
                Algorithm::RingAllreduce,
                Algorithm::RecursiveDoublingAllreduce,
                Algorithm::RabenseifnerAllreduce,
                Algorithm::BinomialAllreduce,
                Algorithm::BruckAllgather,
            ] {
                alg.schedule(p).assert_valid();
            }
            if p.is_power_of_two() {
                Algorithm::RecursiveHalvingReduceScatter.schedule(p).assert_valid();
            }
        }
    }
}
