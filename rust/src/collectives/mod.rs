//! The collective algorithms: the paper's circulant-graph schedules plus
//! every baseline, behind a single [`Algorithm`] selector.

pub mod alltoall;
pub mod baselines;
pub mod derived;
pub mod exec;
pub mod generators;
pub mod hierarchical;

pub use exec::{
    execute_rank, pipeline_chunk_sizes, run_schedule_threads, run_schedule_threads_tiered,
    run_schedule_threads_tiered_typed, run_schedule_threads_typed,
    run_schedule_threads_with_counters, CollectiveError, OpCursor, PipelinedCursor, Progress,
    DEFAULT_PIPELINE_WINDOW,
};
pub use generators::{
    allgather_schedule, allreduce_schedule, reduce_scatter_schedule, try_allgather_schedule,
    try_allreduce_schedule, try_reduce_scatter_schedule,
};

use std::sync::Arc;

use crate::schedule::Schedule;
use crate::topology::skips::SkipScheme;

/// Precomputed circulant planning vocabulary for a fixed `(scheme, p)`:
/// the canonical algorithm names (`Arc<str>`, so a plan-cache key costs a
/// refcount bump instead of a `String` allocation) plus the validated
/// skip sequence. Built once at construction by **both**
/// [`crate::coordinator::Communicator`] and
/// [`crate::engine::CollectiveEngine`] — one derivation site, so their
/// shared `PlanCache` key spaces can never drift apart.
#[derive(Debug, Clone)]
pub struct CirculantPlans {
    pub allreduce: Arc<str>,
    pub reduce_scatter: Arc<str>,
    pub allgather: Arc<str>,
    /// The scheme's skip sequence for `p` (`Arc` so miss-path build
    /// closures can hold it without borrowing their owner).
    pub skips: Arc<Vec<usize>>,
}

impl CirculantPlans {
    /// Derive the vocabulary; panics on an invalid `(scheme, p)` — this
    /// runs once at communicator/engine construction, where a bad scheme
    /// must fail loudly rather than on the Nth collective.
    pub fn new(scheme: &SkipScheme, p: usize) -> Self {
        let skips = scheme
            .skips(p)
            .unwrap_or_else(|e| panic!("invalid skip scheme for p={p}: {e}"));
        Self {
            allreduce: Algorithm::CirculantAllreduce(scheme.clone()).name().into(),
            reduce_scatter: Algorithm::CirculantReduceScatter(scheme.clone()).name().into(),
            allgather: Algorithm::CirculantAllgather(scheme.clone()).name().into(),
            skips: Arc::new(skips),
        }
    }
}

/// Every schedule-expressible algorithm in the library, for the CLI,
/// benches and the simulator. (All-to-all is separate — `alltoall` — since
/// its payloads grow per round.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 1 with a skip scheme (default: halving-up).
    CirculantReduceScatter(SkipScheme),
    /// Algorithm 2 (reduce-scatter + mirrored allgather).
    CirculantAllreduce(SkipScheme),
    /// The mirrored allgather alone.
    CirculantAllgather(SkipScheme),
    RingReduceScatter,
    RingAllreduce,
    RingAllgather,
    /// Power-of-two only.
    RecursiveHalvingReduceScatter,
    RecursiveDoublingAllreduce,
    RabenseifnerAllreduce,
    BinomialReduce { root: usize },
    BinomialBcast { root: usize },
    BinomialAllreduce,
    BruckAllgather,
}

impl Algorithm {
    /// Grammar of every name [`Algorithm::parse`] accepts — surfaced by
    /// CLI/config diagnostics so an unknown value lists its alternatives.
    pub const NAMES_HELP: &'static str = "reduce-scatter|rs[:scheme], allreduce|ar[:scheme], \
         allgather|ag[:scheme], ring-rs, ring-allreduce, ring-ag, rec-halving-rs, \
         rec-doubling-allreduce, rabenseifner, binomial-reduce[:root], \
         binomial-bcast[:root], binomial-allreduce, bruck-ag";

    /// Parse a CLI/config name. Circulant variants accept an optional
    /// `:scheme` suffix (e.g. `allreduce:pow2`, `reduce-scatter:sqrt`);
    /// rooted binomial variants accept an optional `:root` suffix
    /// (e.g. `binomial-reduce:3`, default root 0). Suffixes on algorithms
    /// that take none are rejected. Every [`Algorithm::name`] output
    /// parses back to the same variant (round-trip tested below).
    pub fn parse(s: &str) -> Option<Algorithm> {
        let (head, suffix) = match s.split_once(':') {
            Some((h, x)) => (h, Some(x)),
            None => (s, None),
        };
        let scheme = || match suffix {
            Some(x) => SkipScheme::parse(x).ok(),
            None => Some(SkipScheme::HalvingUp),
        };
        let root = || match suffix {
            Some(x) => x.parse::<usize>().ok(),
            None => Some(0),
        };
        // Arms that take no suffix go through `bare`, so each arm states
        // its own suffix policy — there is no separate allowlist to keep
        // in sync.
        let bare = |alg: Algorithm| if suffix.is_none() { Some(alg) } else { None };
        Some(match head {
            "reduce-scatter" | "rs" => Algorithm::CirculantReduceScatter(scheme()?),
            "allreduce" | "ar" => Algorithm::CirculantAllreduce(scheme()?),
            "allgather" | "ag" => Algorithm::CirculantAllgather(scheme()?),
            "ring-rs" => bare(Algorithm::RingReduceScatter)?,
            "ring-allreduce" => bare(Algorithm::RingAllreduce)?,
            "ring-ag" => bare(Algorithm::RingAllgather)?,
            "rec-halving-rs" => bare(Algorithm::RecursiveHalvingReduceScatter)?,
            "rec-doubling-allreduce" => bare(Algorithm::RecursiveDoublingAllreduce)?,
            "rabenseifner" => bare(Algorithm::RabenseifnerAllreduce)?,
            "binomial-reduce" => Algorithm::BinomialReduce { root: root()? },
            "binomial-bcast" => Algorithm::BinomialBcast { root: root()? },
            "binomial-allreduce" => bare(Algorithm::BinomialAllreduce)?,
            "bruck-ag" => bare(Algorithm::BruckAllgather)?,
            _ => return None,
        })
    }

    /// Canonical display name — always re-parseable by [`Algorithm::parse`]
    /// (`parse(&alg.name()) == Some(alg)` for every variant).
    pub fn name(&self) -> String {
        match self {
            Algorithm::CirculantReduceScatter(s) => format!("reduce-scatter:{}", s.name()),
            Algorithm::CirculantAllreduce(s) => format!("allreduce:{}", s.name()),
            Algorithm::CirculantAllgather(s) => format!("allgather:{}", s.name()),
            Algorithm::RingReduceScatter => "ring-rs".into(),
            Algorithm::RingAllreduce => "ring-allreduce".into(),
            Algorithm::RingAllgather => "ring-ag".into(),
            Algorithm::RecursiveHalvingReduceScatter => "rec-halving-rs".into(),
            Algorithm::RecursiveDoublingAllreduce => "rec-doubling-allreduce".into(),
            Algorithm::RabenseifnerAllreduce => "rabenseifner".into(),
            Algorithm::BinomialReduce { root } => format!("binomial-reduce:{root}"),
            Algorithm::BinomialBcast { root } => format!("binomial-bcast:{root}"),
            Algorithm::BinomialAllreduce => "binomial-allreduce".into(),
            Algorithm::BruckAllgather => "bruck-ag".into(),
        }
    }

    /// Build the schedule for `p` ranks.
    pub fn schedule(&self, p: usize) -> Schedule {
        match self {
            Algorithm::CirculantReduceScatter(s) => {
                generators::reduce_scatter_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::CirculantAllreduce(s) => {
                generators::allreduce_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::CirculantAllgather(s) => {
                generators::allgather_schedule(p, &s.skips(p).expect("valid scheme"))
            }
            Algorithm::RingReduceScatter => baselines::ring_reduce_scatter_schedule(p),
            Algorithm::RingAllreduce => baselines::ring_allreduce_schedule(p),
            Algorithm::RingAllgather => baselines::ring_allgather_schedule(p),
            Algorithm::RecursiveHalvingReduceScatter => {
                baselines::recursive_halving_rs_schedule(p)
            }
            Algorithm::RecursiveDoublingAllreduce => {
                baselines::recursive_doubling_allreduce_schedule(p)
            }
            Algorithm::RabenseifnerAllreduce => baselines::rabenseifner_allreduce_schedule(p),
            Algorithm::BinomialReduce { root } => baselines::binomial_reduce_schedule(p, *root),
            Algorithm::BinomialBcast { root } => baselines::binomial_bcast_schedule(p, *root),
            Algorithm::BinomialAllreduce => baselines::binomial_allreduce_schedule(p),
            Algorithm::BruckAllgather => baselines::bruck_allgather_schedule(p),
        }
    }

    /// Does the result semantics cover the whole vector on every rank?
    pub fn is_allreduce(&self) -> bool {
        matches!(
            self,
            Algorithm::CirculantAllreduce(_)
                | Algorithm::RingAllreduce
                | Algorithm::RecursiveDoublingAllreduce
                | Algorithm::RabenseifnerAllreduce
                | Algorithm::BinomialAllreduce
        )
    }

    /// Reduce-scatter semantics (block `r` finished at rank `r`)?
    pub fn is_reduce_scatter(&self) -> bool {
        matches!(
            self,
            Algorithm::CirculantReduceScatter(_)
                | Algorithm::RingReduceScatter
                | Algorithm::RecursiveHalvingReduceScatter
        )
    }

    /// All allreduce algorithms, for comparison sweeps (F1/F2 benches).
    pub fn allreduce_family() -> Vec<Algorithm> {
        vec![
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
            Algorithm::RingAllreduce,
            Algorithm::RecursiveDoublingAllreduce,
            Algorithm::RabenseifnerAllreduce,
            Algorithm::BinomialAllreduce,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Algorithm::parse("allreduce").unwrap(),
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp)
        );
        assert_eq!(
            Algorithm::parse("rs:pow2").unwrap(),
            Algorithm::CirculantReduceScatter(SkipScheme::PowerOfTwo)
        );
        assert_eq!(Algorithm::parse("ring-allreduce").unwrap(), Algorithm::RingAllreduce);
        assert!(Algorithm::parse("nope").is_none());
        assert!(Algorithm::parse("rs:nope").is_none());
    }

    #[test]
    fn parse_binomial_rooted_variants() {
        assert_eq!(
            Algorithm::parse("binomial-reduce").unwrap(),
            Algorithm::BinomialReduce { root: 0 }
        );
        assert_eq!(
            Algorithm::parse("binomial-reduce:3").unwrap(),
            Algorithm::BinomialReduce { root: 3 }
        );
        assert_eq!(
            Algorithm::parse("binomial-bcast:7").unwrap(),
            Algorithm::BinomialBcast { root: 7 }
        );
        assert!(Algorithm::parse("binomial-reduce:x").is_none());
        // Suffixes on suffix-less algorithms are rejected, not ignored.
        assert!(Algorithm::parse("ring-rs:pow2").is_none());
        assert!(Algorithm::parse("binomial-allreduce:3").is_none());
    }

    #[test]
    fn name_parse_roundtrip_every_variant() {
        let all = vec![
            Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
            Algorithm::CirculantReduceScatter(SkipScheme::Sqrt),
            Algorithm::CirculantReduceScatter(SkipScheme::Custom(vec![4, 2, 1])),
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
            Algorithm::CirculantAllreduce(SkipScheme::PowerOfTwo),
            Algorithm::CirculantAllgather(SkipScheme::FullyConnected),
            Algorithm::RingReduceScatter,
            Algorithm::RingAllreduce,
            Algorithm::RingAllgather,
            Algorithm::RecursiveHalvingReduceScatter,
            Algorithm::RecursiveDoublingAllreduce,
            Algorithm::RabenseifnerAllreduce,
            Algorithm::BinomialReduce { root: 0 },
            Algorithm::BinomialReduce { root: 5 },
            Algorithm::BinomialBcast { root: 0 },
            Algorithm::BinomialBcast { root: 2 },
            Algorithm::BinomialAllreduce,
            Algorithm::BruckAllgather,
        ];
        for alg in all {
            let name = alg.name();
            assert_eq!(Algorithm::parse(&name), Some(alg), "round-trip of {name:?}");
        }
    }

    #[test]
    fn all_schedules_structurally_valid() {
        for p in [2usize, 3, 8, 22] {
            for alg in [
                Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
                Algorithm::CirculantAllreduce(SkipScheme::Sqrt),
                Algorithm::CirculantAllgather(SkipScheme::PowerOfTwo),
                Algorithm::RingReduceScatter,
                Algorithm::RingAllreduce,
                Algorithm::RecursiveDoublingAllreduce,
                Algorithm::RabenseifnerAllreduce,
                Algorithm::BinomialAllreduce,
                Algorithm::BruckAllgather,
            ] {
                alg.schedule(p).assert_valid();
            }
            if p.is_power_of_two() {
                Algorithm::RecursiveHalvingReduceScatter.schedule(p).assert_valid();
            }
        }
    }
}
