//! Schedule executor: run any [`Schedule`] with real data over the thread
//! transport.
//!
//! Each rank keeps its working vector in **global layout** (block `g` lives
//! at the partition offset of `g`, for every rank). A circular block range
//! resolves to at most two contiguous slices; sends *gather* those slices
//! into the outgoing message and receives *scatter/combine* them back —
//! no rotated copy of the input is ever made (cf. paper §3 on avoiding
//! copies / MPI datatypes).

use crate::datatypes::BlockPartition;
use crate::ops::ReduceOp;
use crate::schedule::{RecvAction, Schedule};
use crate::transport::{Endpoint, TransportError};

/// Errors surfaced by collective execution.
#[derive(Debug, thiserror::Error)]
pub enum CollectiveError {
    #[error(transparent)]
    Transport(#[from] TransportError),
    #[error("rank {rank}: buffer has {got} elements, partition needs {want}")]
    BadBuffer { rank: usize, got: usize, want: usize },
    #[error("rank {rank}: received {got} elements, expected {want} (round {round})")]
    BadPayload { rank: usize, got: usize, want: usize, round: usize },
}

/// Execute `schedule` for this endpoint's rank.
///
/// `buf` is the rank's working vector (`part.total()` elements, global
/// layout). On return it contains whatever the schedule semantics leave
/// behind: for reduce-scatter, block `rank` is the finished `W`; for
/// allreduce, the whole buffer; for allgather, all blocks.
///
/// `round_base` offsets the transport round tags so several collectives
/// can run back-to-back on one endpoint (the coordinator uses this).
pub fn execute_rank(
    ep: &mut Endpoint,
    schedule: &Schedule,
    part: &BlockPartition,
    op: &dyn ReduceOp,
    buf: &mut [f32],
    round_base: u64,
) -> Result<u64, CollectiveError> {
    let p = schedule.p;
    let r = ep.rank;
    if buf.len() != part.total() {
        return Err(CollectiveError::BadBuffer { rank: r, got: buf.len(), want: part.total() });
    }
    let mut scratch: Vec<f32> = Vec::new();
    for (k, round) in schedule.rounds.iter().enumerate() {
        let step = &round.steps[r];
        if step.is_idle() {
            continue;
        }
        let tag = round_base + k as u64;

        // Pack the outgoing payload (gather ≤2 slices).
        let send = step.send.as_ref().map(|t| {
            let b = t.blocks.normalized(p);
            let (a, rest) = part.circular_ranges(b.start, b.len);
            scratch.clear();
            scratch.extend_from_slice(&buf[a]);
            if let Some(rest) = rest {
                scratch.extend_from_slice(&buf[rest]);
            }
            (t.peer, std::mem::take(&mut scratch))
        });

        let recv_from = step.recv.as_ref().map(|rv| rv.peer);
        let payload = ep.sendrecv(send, recv_from, tag)?;

        if let (Some(rv), Some(payload)) = (step.recv.as_ref(), payload) {
            let b = rv.blocks.normalized(p);
            let want = part.circular_elems(b.start, b.len);
            if payload.len() != want {
                return Err(CollectiveError::BadPayload {
                    rank: r,
                    got: payload.len(),
                    want,
                    round: k,
                });
            }
            let (a, rest) = part.circular_ranges(b.start, b.len);
            let split = a.len();
            match rv.action {
                RecvAction::Combine => {
                    op.combine(&mut buf[a], &payload[..split]);
                    if let Some(rest) = rest {
                        op.combine(&mut buf[rest], &payload[split..]);
                    }
                }
                RecvAction::Store => {
                    buf[a].copy_from_slice(&payload[..split]);
                    if let Some(rest) = rest {
                        buf[rest].copy_from_slice(&payload[split..]);
                    }
                }
            }
            // Reuse the received allocation for the next round's packing.
            scratch = payload;
        }
    }
    Ok(round_base + schedule.rounds.len() as u64)
}

/// Convenience driver for tests/benches: run `schedule` over `p` threads
/// with per-rank input vectors, returning the final per-rank buffers.
pub fn run_schedule_threads(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
) -> Vec<Vec<f32>> {
    use crate::transport::run_ranks;
    assert_eq!(inputs.len(), schedule.p);
    let schedule = std::sync::Arc::new(schedule.clone());
    let part = std::sync::Arc::new(part.clone());
    let inputs = std::sync::Arc::new(std::sync::Mutex::new(
        inputs.into_iter().map(Some).collect::<Vec<_>>(),
    ));
    run_ranks(schedule.p, move |rank, ep| {
        let mut buf = inputs.lock().unwrap()[rank].take().expect("input taken once");
        execute_rank(ep, &schedule, &part, op.as_ref(), &mut buf, 0)
            .unwrap_or_else(|e| panic!("rank {rank}: {e}"));
        buf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::ops::SumOp;
    use crate::topology::skips::SkipScheme;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    /// Scalar oracle: elementwise sum over all rank inputs.
    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..p).map(|_| rng.int_valued_vec(m, -8, 9)).collect()
    }

    #[test]
    fn reduce_scatter_matches_oracle_small() {
        for p in [2usize, 3, 5, 8, 22] {
            let part = BlockPartition::regular(p, 4 * p + 3);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = reduce_scatter_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &want[range], "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn allreduce_matches_oracle_small() {
        for p in [2usize, 4, 7, 22] {
            let part = BlockPartition::regular(p, 3 * p + 1);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = allreduce_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), 100 + p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn bad_buffer_rejected() {
        let part = BlockPartition::uniform(2, 4);
        let sched = reduce_scatter_schedule(2, &[1]);
        let out = crate::transport::run_ranks(2, move |_rank, ep| {
            let mut buf = vec![0.0f32; 3]; // wrong size
            execute_rank(ep, &sched, &part, &SumOp, &mut buf, 0).is_err()
        });
        assert!(out.iter().all(|&e| e));
    }
}
