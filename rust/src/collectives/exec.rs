//! Schedule executor: run any [`Schedule`] with real data over the thread
//! transport.
//!
//! Each rank keeps its working vector in **global layout** (block `g` lives
//! at the partition offset of `g`, for every rank). A circular block range
//! resolves to at most two contiguous slices; sends *gather* those slices
//! into the outgoing message and receives *scatter/combine* them back —
//! no rotated copy of the input is ever made (cf. paper §3 on avoiding
//! copies / MPI datatypes).
//!
//! # Borrow-pack `sendrecv` contract
//!
//! The executor owns no scratch buffer. Per round it hands the transport
//! the (≤ 2) working-vector slices of the outgoing circular range; the
//! transport gathers them directly into a buffer checked out of its
//! per-peer pool ([`Endpoint::acquire`]). Received payloads are combined /
//! stored into the working vector and immediately handed back with
//! [`Endpoint::release`], returning the buffer to *its sender's* pool.
//! Send-only rounds (tree schedules such as binomial reduce) follow the
//! identical loan protocol, so after warm-up the executor performs zero
//! payload allocations per round regardless of schedule shape — the
//! allocation ablation in `benches/perf_hotpath.rs` measures this.

use crate::datatypes::BlockPartition;
use crate::ops::ReduceOp;
use crate::schedule::{RecvAction, Schedule};
use crate::transport::{Counters, Endpoint, TransportError};

/// Errors surfaced by collective execution.
#[derive(Debug, thiserror::Error)]
pub enum CollectiveError {
    #[error(transparent)]
    Transport(#[from] TransportError),
    #[error("rank {rank}: buffer has {got} elements, partition needs {want}")]
    BadBuffer { rank: usize, got: usize, want: usize },
    #[error("rank {rank}: received {got} elements, expected {want} (round {round})")]
    BadPayload { rank: usize, got: usize, want: usize, round: usize },
}

/// Execute `schedule` for this endpoint's rank.
///
/// `buf` is the rank's working vector (`part.total()` elements, global
/// layout). On return it contains whatever the schedule semantics leave
/// behind: for reduce-scatter, block `rank` is the finished `W`; for
/// allreduce, the whole buffer; for allgather, all blocks.
///
/// `round_base` offsets the transport round tags so several collectives
/// can run back-to-back on one endpoint (the coordinator uses this).
pub fn execute_rank(
    ep: &mut Endpoint,
    schedule: &Schedule,
    part: &BlockPartition,
    op: &dyn ReduceOp,
    buf: &mut [f32],
    round_base: u64,
) -> Result<u64, CollectiveError> {
    let p = schedule.p;
    let r = ep.rank;
    if buf.len() != part.total() {
        return Err(CollectiveError::BadBuffer { rank: r, got: buf.len(), want: part.total() });
    }
    for (k, round) in schedule.rounds.iter().enumerate() {
        let step = &round.steps[r];
        if step.is_idle() {
            continue;
        }
        let tag = round_base + k as u64;

        // Borrow-pack the outgoing payload: hand the transport the ≤2
        // slices of the circular range; it gathers them into a pooled
        // buffer (no local scratch, no per-round allocation).
        let send = match step.send.as_ref() {
            Some(t) => {
                let b = t.blocks.normalized(p);
                let (a, rest) = part.circular_ranges(b.start, b.len);
                let tail: &[f32] = match rest {
                    Some(rest) => &buf[rest],
                    None => &[],
                };
                Some((t.peer, &buf[a], tail))
            }
            None => None,
        };

        let recv_from = step.recv.as_ref().map(|rv| rv.peer);
        let payload = ep.sendrecv(send, recv_from, tag)?;

        if let (Some(rv), Some(payload)) = (step.recv.as_ref(), payload) {
            let b = rv.blocks.normalized(p);
            let want = part.circular_elems(b.start, b.len);
            if payload.len() != want {
                return Err(CollectiveError::BadPayload {
                    rank: r,
                    got: payload.len(),
                    want,
                    round: k,
                });
            }
            let (a, rest) = part.circular_ranges(b.start, b.len);
            let split = a.len();
            match rv.action {
                RecvAction::Combine => {
                    op.combine(&mut buf[a], &payload[..split]);
                    if let Some(rest) = rest {
                        op.combine(&mut buf[rest], &payload[split..]);
                    }
                }
                RecvAction::Store => {
                    buf[a].copy_from_slice(&payload[..split]);
                    if let Some(rest) = rest {
                        buf[rest].copy_from_slice(&payload[split..]);
                    }
                }
            }
            // Loan protocol: hand the buffer back to its sender's pool.
            ep.release(rv.peer, payload);
        }
    }
    Ok(round_base + schedule.rounds.len() as u64)
}

/// Convenience driver for tests/benches: run `schedule` over `p` threads
/// with per-rank input vectors, returning the final per-rank buffers.
pub fn run_schedule_threads(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
) -> Vec<Vec<f32>> {
    run_schedule_threads_with_counters(schedule, part, op, inputs)
        .into_iter()
        .map(|(buf, _)| buf)
        .collect()
}

/// Like [`run_schedule_threads`] but also returns each rank's transport
/// [`Counters`] (volume + pool hit/miss — the allocation-regression tests
/// read these).
pub fn run_schedule_threads_with_counters(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
) -> Vec<(Vec<f32>, Counters)> {
    use crate::transport::run_ranks;
    assert_eq!(inputs.len(), schedule.p);
    let schedule = std::sync::Arc::new(schedule.clone());
    let part = std::sync::Arc::new(part.clone());
    let inputs = std::sync::Arc::new(std::sync::Mutex::new(
        inputs.into_iter().map(Some).collect::<Vec<_>>(),
    ));
    run_ranks(schedule.p, move |rank, ep| {
        let mut buf = inputs.lock().unwrap()[rank].take().expect("input taken once");
        execute_rank(ep, &schedule, &part, op.as_ref(), &mut buf, 0)
            .unwrap_or_else(|e| panic!("rank {rank}: {e}"));
        (buf, ep.counters.clone())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::ops::SumOp;
    use crate::topology::skips::SkipScheme;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    /// Scalar oracle: elementwise sum over all rank inputs.
    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..p).map(|_| rng.int_valued_vec(m, -8, 9)).collect()
    }

    #[test]
    fn reduce_scatter_matches_oracle_small() {
        for p in [2usize, 3, 5, 8, 22] {
            let part = BlockPartition::regular(p, 4 * p + 3);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = reduce_scatter_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &want[range], "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn allreduce_matches_oracle_small() {
        for p in [2usize, 4, 7, 22] {
            let part = BlockPartition::regular(p, 3 * p + 1);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = allreduce_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), 100 + p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn bad_buffer_rejected() {
        let part = BlockPartition::uniform(2, 4);
        let sched = reduce_scatter_schedule(2, &[1]);
        let out = crate::transport::run_ranks(2, move |_rank, ep| {
            let mut buf = vec![0.0f32; 3]; // wrong size
            execute_rank(ep, &sched, &part, &SumOp, &mut buf, 0).is_err()
        });
        assert!(out.iter().all(|&e| e));
    }

    #[test]
    fn pooled_transport_zero_alloc_steady_state() {
        // Allocation regression: back-to-back allreduces on ONE network.
        // After the warm-up iterations the pools must serve every payload
        // (pool misses stop growing — zero steady-state allocations).
        let p = 2usize;
        let m = 64usize;
        let part = Arc::new(BlockPartition::regular(p, m));
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = Arc::new(allreduce_schedule(p, &skips));
        let (warm, total) = (10u64, 50u64);
        let out = crate::transport::run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32 + 1.0; m];
            let mut tag = 0u64;
            for _ in 0..warm {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            let misses_after_warm = ep.counters.pool_misses;
            for _ in warm..total {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            (misses_after_warm, ep.counters.clone())
        });
        for (rank, (warm_misses, c)) in out.iter().enumerate() {
            // Supply only grows on a miss, and a just-released buffer can
            // race the next acquire, so allow the bounded tail of that
            // race (≤ 2 per capacity class) — a real regression allocates
            // every round, i.e. ~(total−warm)·2 = 80 extra misses here.
            let steady_misses = c.pool_misses - warm_misses;
            assert!(
                steady_misses <= 2,
                "rank {rank}: {steady_misses} pool misses after warm-up (steady-state allocation)"
            );
            assert!(c.pool_hits > 0, "rank {rank}: the pool never served a buffer");
            assert!(c.bufs_recycled > 0, "rank {rank}: no buffer ever returned");
            let acquires = c.pool_hits + c.pool_misses;
            assert!(acquires >= total * 2, "rank {rank}: not enough acquires measured");
        }
    }

    #[test]
    fn send_only_rounds_recycle_buffers() {
        // Binomial allreduce = reduce + bcast: every non-root rank has
        // send-only rounds (tree edges). The old executor only restored
        // its scratch when a recv happened, so these rounds allocated
        // every time; the loan protocol must recycle them identically.
        let p = 4usize;
        let m = 32usize;
        let part = Arc::new(BlockPartition::regular(p, m));
        let sched = Arc::new(crate::collectives::baselines::binomial_allreduce_schedule(p));
        let (warm, total) = (5u64, 30u64);
        let out = crate::transport::run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32; m];
            let mut tag = 0u64;
            for _ in 0..warm {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            let misses_after_warm = ep.counters.pool_misses;
            for _ in warm..total {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            (misses_after_warm, ep.counters.clone())
        });
        for (rank, (warm_misses, c)) in out.iter().enumerate() {
            // Tolerate the bounded release/acquire race (see the zero-alloc
            // test above); a per-round leak would show ~25+ extra misses.
            let steady_misses = c.pool_misses - warm_misses;
            assert!(
                steady_misses <= 4,
                "rank {rank}: {steady_misses} misses after warm-up — send-only rounds still allocate"
            );
        }
    }
}
