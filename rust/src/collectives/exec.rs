//! Schedule executor: run any [`Schedule`] with real data over any
//! [`Transport`] backend, generic over the element type.
//!
//! The execution core is the resumable [`OpCursor`] — one rank's driver
//! for one collective, advanced by [`OpCursor::step`] in either blocking
//! mode (the classic one-shot executor, [`execute_rank`]) or non-blocking
//! mode (the [`crate::engine`] worker loop, which interleaves many
//! cursors on one thread so several collectives can be in flight and
//! complete out of submission order). Each cursor tags its traffic with
//! its own operation epoch, so concurrent schedules on the same endpoints
//! never cross-match (`crate::transport` docs, "Op tags").
//!
//! The cursor is generic over `C:`[`Transport`]`<T>` — the in-process
//! [`crate::transport::ThreadTransport`] and the cross-process
//! [`crate::transport::uds::UdsTransport`] run the identical state
//! machine. Backend differences are expressed as capability flags, not
//! code paths: the rendezvous verdict below consults
//! [`Transport::caps`], so a backend without a shared address space
//! simply sees every round fall back to its copy tier.
//!
//! Each rank keeps its working vector in **global layout** (block `g` lives
//! at the partition offset of `g`, for every rank). A circular block range
//! resolves to at most two contiguous slices; sends *gather* those slices
//! into the outgoing message and receives *scatter/combine* them back —
//! no rotated copy of the input is ever made (cf. paper §3 on avoiding
//! copies / MPI datatypes).
//!
//! # Element types
//!
//! [`execute_rank`] is generic over `T:`[`Elem`]: the endpoint, operator
//! and working vector must agree on one dtype, enforced at compile time.
//! The f32 drivers ([`run_schedule_threads`], [`run_schedule_threads_tiered`],
//! [`run_schedule_threads_with_counters`]) keep their original signatures;
//! the `_typed` variants run any dtype. Copy-volume accounting is credited
//! at `size_of::<T>()` bytes per element throughout.
//!
//! # The three-tier copy discipline (transport docs have the full story)
//!
//! Per round the executor hands the transport the (≤ 2) working-vector
//! slices of the outgoing circular range and a verdict on whether the
//! round may run **rendezvous** (tier 1, zero-copy): the receiver then
//! combines/stores *directly from this rank's working vector* in one
//! fused pass and acks; [`Transport::finish_round`] holds this rank at
//! the end of the round until that ack, so the published region is never
//! read after it can change. The verdict requires the backend capability
//! (`caps().supports_rendezvous`) **and** the §3-style precondition that
//! the round's send and recv block ranges are **disjoint**
//! ([`crate::schedule::BlockRange::overlaps`]; whole schedules can be
//! checked with [`Schedule::rendezvous_safe`]) — full-vector
//! recursive-doubling rounds fail it and fall back to **pooled** (tier 2):
//! the transport gathers the slices into a buffer checked out of its
//! per-peer pool ([`Transport::acquire`]), and consumed payloads are
//! handed back with [`Transport::complete_tagged`], returning the buffer
//! to *its sender's* pool. Payloads that must be built rather than gathered (the
//! framed all-to-all) travel **owned** (tier 3). Send-only rounds (tree
//! schedules such as binomial reduce) follow the identical protocols, so
//! after warm-up the executor performs zero payload allocations per round
//! regardless of schedule shape and tier — the allocation and copy-volume
//! ablations live in `benches/perf_hotpath.rs`.
//!
//! Combines dispatch through the monomorphized [`Kernel`] when the
//! operator exposes one ([`ReduceOp::kernel`], the four native ops): one
//! enum branch per payload instead of a virtual call per slice, with the
//! kernel's generic methods monomorphized per `(op, dtype)`.
//!
//! # Commutativity interaction
//!
//! Rendezvous changes *where* the second ⊕ operand lives (the sender's
//! memory instead of a copied payload), never the order or association of
//! ⊕ applications — both tiers fold the received range into the local
//! partial as `R[range] ⊕= payload` at the same point in the round
//! sequence, so the schedule's commutativity assumption (⊕ applied in
//! skip order, paper §2.1) is exactly as strong on either tier, and the
//! two produce bit-identical results (asserted by the oracle tests in
//! `rust/tests/rendezvous.rs` for f32, and in exact integer arithmetic
//! for every schedule generator in `rust/tests/dtype_oracles.rs`).

use std::ops::Range;
use std::sync::Arc;

use crate::datatypes::{BlockPartition, Elem};
use crate::ops::ReduceOp;
use crate::schedule::{Plan, RecvAction, Schedule};
use crate::transport::{Counters, Payload, SendSlices, Tag, Transport, TransportError};

/// Read-only view of `base[r]`.
///
/// # Safety
///
/// `r` must be in bounds of the allocation `base` points into, and no
/// `&mut` spanning `r` may be created while the view lives.
unsafe fn view<'v, T>(base: *const T, r: &Range<usize>) -> &'v [T] {
    std::slice::from_raw_parts(base.add(r.start), r.len())
}

/// Mutable view of `base[r]`.
///
/// # Safety
///
/// `r` must be in bounds, and nothing else — local or a rendezvous peer —
/// may access `base[r]` while the view lives.
unsafe fn view_mut<'v, T>(base: *mut T, r: &Range<usize>) -> &'v mut [T] {
    std::slice::from_raw_parts_mut(base.add(r.start), r.len())
}

/// Errors surfaced by collective execution.
#[derive(Debug, thiserror::Error)]
pub enum CollectiveError {
    #[error(transparent)]
    Transport(#[from] TransportError),
    #[error("rank {rank}: buffer has {got} elements, partition needs {want}")]
    BadBuffer { rank: usize, got: usize, want: usize },
    #[error("rank {rank}: received {got} elements, expected {want} (round {round})")]
    BadPayload { rank: usize, got: usize, want: usize, round: usize },
    #[error(
        "rank {rank}: unknown op {name:?} for dtype {dtype} on this backend \
         (native ops: sum|prod|min|max for every dtype; the pjrt backend \
         supports f32 only)"
    )]
    UnknownOp { rank: usize, name: String, dtype: &'static str },
    #[error("rank {rank}: engine worker gone before the operation was delivered")]
    WorkerLost { rank: usize },
    /// A peer this operation's remaining schedule depends on was
    /// positively detected dead ([`Transport::peer_status`]) — distinct
    /// from `Transport(Timeout)`, where nothing arrived but the peer may
    /// merely be slow. The engine raises this *fast* (next poll pass
    /// after the death notice) instead of burning the liveness watchdog.
    #[error("rank {rank}: peer rank {peer} is down ({detail}) — remaining schedule cannot complete")]
    RankDown { rank: usize, peer: usize, detail: String },
    #[error("fused batch (epoch {fused_op}, {members} member ops): {detail}")]
    FusedBatch { fused_op: u64, members: usize, detail: String },
    /// The schedule (or the skip sequence it would be generated from)
    /// failed static validation — nothing was sent.
    #[error("rank {rank}: invalid schedule: {source}")]
    InvalidSchedule {
        rank: usize,
        #[source]
        source: crate::schedule::ScheduleError,
    },
}

/// Whether a driver made it to the end of its schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// Every round executed; the working vector holds the final result.
    Done,
    /// Waiting on a peer (an incoming payload or a rendezvous ack). Only
    /// non-blocking [`OpCursor::step`]s return this.
    Pending,
}

/// What the cursor is waiting for within its current round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Wait {
    /// Round not yet entered: send side (if any) still to be issued.
    Send,
    /// Send issued; waiting for the round's incoming payload.
    Recv,
    /// Payload consumed (or none expected); waiting for the rendezvous
    /// ack of this round's publish (trivially satisfied for pooled sends).
    Ack,
}

/// Resumable per-operation schedule driver — one rank's execution state
/// for one collective, advanced by [`step`](OpCursor::step).
///
/// The cursor holds **no borrow** of the working vector, the endpoint or
/// the schedule: callers pass them to every `step`, which makes the
/// cursor freely storable in the [`crate::engine`] worker's table of
/// in-flight operations (no self-referential structs). Two modes share
/// one code path:
///
///  * **blocking** (`step(.., true)`) runs the whole schedule in one
///    call, parking on the transport's blocking receives/acks exactly
///    like the pre-engine executor — [`execute_rank`] is now this;
///  * **non-blocking** (`step(.., false)`) advances as far as possible
///    without parking and returns [`Progress::Pending`] at the first
///    wait, so a single worker thread can interleave many cursors and
///    complete operations out of submission order.
///
/// Wire discipline: every message/ack of this operation is tagged
/// `Tag { op: op_tag, round: round_base + k }` — concurrent cursors on
/// one endpoint cannot cross-match as long as their `op_tag`s differ
/// (the engine allocates a fresh epoch per submitted op; the legacy
/// blocking path runs in epoch 0 with the communicator's monotonic
/// round windows).
///
/// # Safety contract (same as the original executor, per `step` call)
///
/// `buf` must be the *same allocation* across every `step` of one
/// cursor whenever a rendezvous publish may be outstanding: published
/// [`RemoteSlices`](crate::transport::RemoteSlices) point into it, and
/// the cursor only returns `Pending`/`Done` in states where either no
/// publish is outstanding or the published region is not mutated until
/// the ack arrives (the `Wait::Ack` gate). Callers must not mutate or
/// move the buffer contents between steps of an unfinished cursor; on
/// error the cursor quiesces its own publishes before returning.
#[derive(Debug, Clone)]
pub struct OpCursor {
    op_tag: u64,
    round_base: u64,
    round: usize,
    wait: Wait,
    /// Monotone count of state advances — the engine's liveness watchdog
    /// compares successive values to detect a stalled operation.
    progress: u64,
}

impl OpCursor {
    /// A cursor for one operation: `op_tag` is the wire epoch (0 = the
    /// legacy single-op space), `round_base` offsets the round tags
    /// within the epoch (the communicator reserves monotonic windows in
    /// epoch 0; tagged engine ops start at 0).
    pub fn new(op_tag: u64, round_base: u64) -> Self {
        Self { op_tag, round_base, round: 0, wait: Wait::Send, progress: 0 }
    }

    /// Monotone progress stamp (see field docs).
    pub fn progress(&self) -> u64 {
        self.progress
    }

    /// The operation epoch this cursor tags its traffic with.
    pub fn op_tag(&self) -> u64 {
        self.op_tag
    }

    fn tag(&self) -> Tag {
        Tag::new(self.op_tag, self.round_base + self.round as u64)
    }

    /// The error a watchdog should report for a cursor stuck in its
    /// current wait — matched to the wait *kind*, mirroring the blocking
    /// executor's distinction: a cursor parked on a rendezvous ack
    /// reports `AckTimeout`, one parked on an incoming payload reports
    /// `Timeout` naming the round's recv peer.
    pub fn timeout_error(&self, schedule: &Schedule, rank: usize) -> CollectiveError {
        let round = self.round_base + self.round as u64;
        match self.wait {
            Wait::Ack => CollectiveError::Transport(TransportError::AckTimeout { rank, round }),
            Wait::Send | Wait::Recv => {
                let from = schedule
                    .rounds
                    .get(self.round)
                    .and_then(|r| r.steps[rank].recv.as_ref().map(|rv| rv.peer))
                    .unwrap_or(rank);
                CollectiveError::Transport(TransportError::Timeout { rank, from, round })
            }
        }
    }

    /// The first peer in this cursor's **remaining** schedule (its
    /// current round onward) that the health bitmap reports down —
    /// `up[r] == false` means rank `r` is dead (the shape
    /// [`Transport::peer_status`] returns). `None` means every rank the
    /// rest of the schedule touches is still up, so the operation can in
    /// principle complete. The engine's fast-fail path calls this per
    /// poll pass once any peer is marked down, so an op that still needs
    /// the dead rank fails with [`CollectiveError::RankDown`] immediately
    /// instead of waiting out the liveness watchdog.
    ///
    /// Deliberately conservative about the current round: even a
    /// partially-completed round (send issued, recv pending, or parked on
    /// the ack) is counted in full, because the remaining wait of the
    /// round involves exactly the round's peers.
    pub fn first_needed_down_peer(
        &self,
        schedule: &Schedule,
        rank: usize,
        up: &[bool],
    ) -> Option<usize> {
        for round in schedule.rounds.iter().skip(self.round) {
            let step = &round.steps[rank];
            if let Some(s) = step.send.as_ref() {
                if !up.get(s.peer).copied().unwrap_or(true) {
                    return Some(s.peer);
                }
            }
            if let Some(rv) = step.recv.as_ref() {
                if !up.get(rv.peer).copied().unwrap_or(true) {
                    return Some(rv.peer);
                }
            }
        }
        None
    }

    /// Quiesce after an error/timeout: block (bounded by the transport
    /// timeout) until no publish of this operation is outstanding, so no
    /// peer can read the working vector after the caller reclaims it.
    /// Best-effort; other interleaved operations' publishes are left
    /// pending.
    pub fn abort<T: Elem, C: Transport<T>>(&mut self, ep: &mut C) {
        let _ = ep.finish_op(self.op_tag);
    }

    /// Advance this operation as far as possible. Blocking mode returns
    /// only `Done` (or an error); non-blocking mode may return `Pending`.
    /// See the type docs for the buffer contract.
    pub fn step<T: Elem, C: Transport<T>>(
        &mut self,
        ep: &mut C,
        schedule: &Schedule,
        part: &BlockPartition,
        op: &dyn ReduceOp<T>,
        buf: &mut [T],
        blocking: bool,
    ) -> Result<Progress, CollectiveError> {
        self.step_with_tiers(ep, schedule, part, op, buf, blocking, None)
    }

    /// [`step`](Self::step), consulting a statically verified
    /// [`crate::analysis::TierMap`] for the per-(round, rank) rendezvous
    /// verdict instead of recomputing `rendezvous_safe` every round. Plans
    /// built by the [`crate::schedule::PlanCache`] carry their tier map;
    /// ad-hoc callers pass `None` and fall back to the online predicate.
    #[allow(clippy::too_many_arguments)]
    pub fn step_with_tiers<T: Elem, C: Transport<T>>(
        &mut self,
        ep: &mut C,
        schedule: &Schedule,
        part: &BlockPartition,
        op: &dyn ReduceOp<T>,
        buf: &mut [T],
        blocking: bool,
        tiers: Option<&crate::analysis::TierMap>,
    ) -> Result<Progress, CollectiveError> {
        let p = schedule.p;
        let r = ep.rank();
        if buf.len() != part.total() {
            return Err(CollectiveError::BadBuffer { rank: r, got: buf.len(), want: part.total() });
        }
        // Resolve the monomorphized kernel once per step call — the
        // combine path then pays one enum branch per payload instead of a
        // dyn call per slice.
        let kern = op.kernel();
        // All per-round views of the working vector are carved from this
        // raw base pointer instead of re-borrowing `buf`: while a
        // rendezvous peer reads our published region, forming a `&mut`
        // that *spans* it (as `&mut buf[..]` indexing would, transiently,
        // over the whole slice) is aliasing UB even if the bytes written
        // are disjoint. Raw-derived disjoint subslices make this rank's
        // accesses per-element non-overlapping with the peer's reads,
        // which is sound. The engine's interleaved *ops* each own a
        // distinct working-vector allocation, so one op's writes can
        // never alias another op's published region; within a single
        // pipelined op the per-chunk views are themselves raw-derived
        // disjoint subslices of the one allocation (see
        // [`PipelinedCursor`]), so chunk epochs cannot alias each other
        // either.
        let base = buf.as_mut_ptr();
        loop {
            if self.round >= schedule.rounds.len() {
                return Ok(Progress::Done);
            }
            let step = &schedule.rounds[self.round].steps[r];
            let tag = self.tag();
            match self.wait {
                Wait::Send => {
                    if step.is_idle() {
                        self.round += 1;
                        self.progress += 1;
                        continue;
                    }
                    // Rendezvous verdict, checked per (rank, round): the
                    // backend must be able to publish at all (capability
                    // flag — a socket transport has no shared address
                    // space), and the region we publish must not be
                    // written before the receiver acks; the only writes
                    // this rank performs during the round target its recv
                    // range — so disjoint send/recv block ranges ⇒ safe
                    // (shared predicate with the Schedule::rendezvous_safe
                    // validator). Backends that fail either test fall
                    // back rendezvous → pooled → framed copy on their own
                    // send path.
                    let block_safe = match tiers {
                        Some(t) => {
                            let safe = t.rendezvous_ok(self.round, r);
                            debug_assert_eq!(
                                safe,
                                step.rendezvous_safe(p),
                                "tier map disagrees with rendezvous_safe (round {}, rank {r})",
                                self.round
                            );
                            safe
                        }
                        None => step.rendezvous_safe(p),
                    };
                    let rendezvous = block_safe && ep.caps().supports_rendezvous;

                    // Borrow-pack the outgoing payload: hand the transport
                    // the ≤2 slices of the circular range; it publishes
                    // descriptors (tier 1) or gathers into a pooled buffer
                    // (tier 2) — either way no local scratch and no
                    // per-round allocation.
                    let send = match step.send.as_ref() {
                        Some(t) => {
                            let b = t.blocks.normalized(p);
                            let (a, rest) = part.circular_ranges(b.start, b.len);
                            // SAFETY: partition ranges are in bounds of
                            // `buf`, and no write overlaps these read-only
                            // views while they are read: with `rendezvous`
                            // the per-step check makes the recv ranges
                            // block-disjoint, and on the pooled tier the
                            // transport copies out of the views inside the
                            // sendrecv call, before any recv-range write.
                            let head = unsafe { view(base, &a) };
                            let tail: &[T] = match &rest {
                                Some(rest) => unsafe { view(base, rest) },
                                None => &[],
                            };
                            Some(SendSlices { to: t.peer, head, tail, rendezvous })
                        }
                        None => None,
                    };

                    if let Err(e) = ep.sendrecv_slices_tagged(send, None, tag) {
                        // Quiesce any publish before surfacing the error so
                        // the peer can never read `buf` after we return it.
                        self.abort(ep);
                        return Err(e.into());
                    }
                    self.progress += 1;
                    self.wait = if step.recv.is_some() { Wait::Recv } else { Wait::Ack };
                }
                Wait::Recv => {
                    let rv = step.recv.as_ref().expect("Recv wait implies a recv step");
                    let payload = if blocking {
                        match ep.recv_payload(rv.peer, tag) {
                            Ok(payload) => payload,
                            Err(e) => {
                                self.abort(ep);
                                return Err(e.into());
                            }
                        }
                    } else {
                        match ep.try_recv_payload(rv.peer, tag) {
                            Some(payload) => payload,
                            None => return Ok(Progress::Pending),
                        }
                    };
                    let b = rv.blocks.normalized(p);
                    let want = part.circular_elems(b.start, b.len);
                    if payload.len() != want {
                        // Validate once per payload (the kernels don't
                        // re-check). Complete the bad payload and quiesce
                        // our own publish so neither side is left waiting
                        // on a buffer we abandon.
                        let got = payload.len();
                        ep.complete_tagged(rv.peer, tag, payload);
                        self.abort(ep);
                        return Err(CollectiveError::BadPayload {
                            rank: r,
                            got,
                            want,
                            round: self.round,
                        });
                    }
                    let (a, rest) = part.circular_ranges(b.start, b.len);
                    let split = a.len();
                    // Resolve the payload to (head, tail) source slices.
                    // Both sides derive the split from the same partition
                    // and block range, so a rendezvous publish lines up.
                    let (src_head, src_tail): (&[T], &[T]) = match &payload {
                        Payload::Copied(v) => (&v[..split], &v[split..]),
                        // SAFETY: sender parks (or polls) until our ack
                        // below; the slices stay valid and unwritten
                        // meanwhile.
                        Payload::Remote(remote) => unsafe { remote.slices() },
                    };
                    debug_assert_eq!(src_head.len(), split, "sender/receiver split mismatch");
                    // SAFETY: the recv ranges are in bounds, disjoint from
                    // each other (head starts past the wrap point the tail
                    // ends at), and — when this round published —
                    // block-disjoint from the region our receiver is
                    // concurrently reading (what `rendezvous` asserted at
                    // send time). Sources live in a different allocation
                    // (the payload Vec or the peer's working vector).
                    let dst_head = unsafe { view_mut(base, &a) };
                    let dst_tail = rest.as_ref().map(|rest| unsafe { view_mut(base, rest) });
                    match rv.action {
                        RecvAction::Combine => match kern {
                            // Fused single pass, monomorphized per
                            // (op, dtype) — the hot path.
                            Some(kern) => {
                                kern.combine_ranges(dst_head, dst_tail, src_head, src_tail)
                            }
                            None => {
                                op.combine(dst_head, src_head);
                                if let Some(dst_tail) = dst_tail {
                                    op.combine(dst_tail, src_tail);
                                }
                            }
                        },
                        RecvAction::Store => {
                            // The one unavoidable copy of allgather-style
                            // rounds; credited through the trait so every
                            // backend's copy volume is accounted the same
                            // way (rendezvous saves the *gather* copy, not
                            // this scatter).
                            ep.credit_copied((std::mem::size_of::<T>() * want) as u64);
                            dst_head.copy_from_slice(src_head);
                            if let Some(dst_tail) = dst_tail {
                                dst_tail.copy_from_slice(src_tail);
                            }
                        }
                    }
                    // Loan protocol: pooled buffers return to their
                    // sender's pool; rendezvous publishes are acked.
                    ep.complete_tagged(rv.peer, tag, payload);
                    self.progress += 1;
                    self.wait = Wait::Ack;
                }
                Wait::Ack => {
                    // If this round published, hold (or poll) here until
                    // the receiver acks — only after that is `buf` ours to
                    // mutate again in the next round.
                    if blocking {
                        ep.finish_op(self.op_tag)?;
                    } else if !ep.try_finish(tag) {
                        return Ok(Progress::Pending);
                    }
                    self.progress += 1;
                    self.round += 1;
                    self.wait = Wait::Send;
                }
            }
        }
    }
}

/// Default bound on how many chunk epochs a [`PipelinedCursor`] advances
/// concurrently. One suffices for correctness; two is the minimum that
/// overlaps chunk k+1's sends with chunk k's combines; a little headroom
/// beyond that rides out per-chunk jitter without flooding the transport
/// with outstanding publishes.
pub const DEFAULT_PIPELINE_WINDOW: usize = 4;

/// Chunk geometry of the pipelined execution tier: split `m` elements
/// into chunks of `chunk_elems`, folding any remainder into the final
/// chunk (so at most **two** distinct chunk lengths — and thus at most
/// two distinct chunk partitions/plans — ever exist). Degenerate
/// requests (`chunk_elems == 0`, or `m < 2·chunk_elems` so no second
/// chunk would fit) return the single-chunk geometry `[m]`, which the
/// dispatcher treats as "run plain".
pub fn pipeline_chunk_sizes(m: usize, chunk_elems: usize) -> Vec<usize> {
    if chunk_elems == 0 || m < 2 * chunk_elems {
        return vec![m];
    }
    let n = m / chunk_elems;
    let mut sizes = vec![chunk_elems; n];
    sizes[n - 1] += m % chunk_elems;
    sizes
}

/// One chunk's slot in a [`PipelinedCursor`]: its schedule driver, the
/// element offset of its working slice within the op buffer, and the
/// (cache-built, statically audited) plan for its chunk partition.
#[derive(Debug, Clone)]
struct ChunkCursor {
    cursor: OpCursor,
    offset: usize,
    plan: Arc<Plan>,
    done: bool,
}

/// Pipelined (chunked) driver for one large-message collective — the
/// bandwidth end of the engine's size-adaptive dispatch (fuse small,
/// plain medium, pipeline large).
///
/// The working vector is split by [`pipeline_chunk_sizes`]; every chunk
/// runs the *same* circulant schedule as its own wire epoch within the
/// op's single `op_tag`: chunk `k` tags its rounds
/// `Tag { op: op_tag, round: k·R + j }` (R = rounds per chunk), so chunk
/// epochs never cross-match on the wire yet `finish_op`/`forget_op`/
/// `op_has_pending_publish` — everything the engine's abort and cleanup
/// paths key on — quiesce the whole op at once. Chunk cursors are
/// advanced non-blockingly over a sliding in-flight window, so chunk
/// k+1's sends overlap chunk k's combines; per chunk round the usual
/// rendezvous verdict applies, so backends without rendezvous caps
/// simply run every chunk on the pooled copy tier.
///
/// Engine-facing surface mirrors [`OpCursor`]: a monotone aggregate
/// [`progress`](Self::progress) stamp (sum of chunk stamps) for the
/// liveness watchdog, [`first_needed_down_peer`](Self::first_needed_down_peer)
/// over the unfinished chunks for PeerDown fast-fail,
/// [`timeout_error`](Self::timeout_error) from the oldest unfinished
/// chunk, and a single-epoch [`abort`](Self::abort).
///
/// # Safety contract
///
/// Same buffer contract as [`OpCursor`] (same allocation across steps
/// while any publish may be outstanding), over the *whole* op buffer:
/// chunk working slices are carved from the buffer's raw base pointer as
/// disjoint subslices, never by re-borrowing the full slice, so one
/// chunk's writes cannot alias another chunk's published region.
#[derive(Debug, Clone)]
pub struct PipelinedCursor {
    op_tag: u64,
    chunks: Vec<ChunkCursor>,
    /// Sliding in-flight bound: only chunks `[oldest, oldest+window)`
    /// advance per step pass. Deadlock-free for any `window ≥ 1`: the
    /// globally oldest unfinished chunk is, at every rank, either
    /// finished (all its sends/acks already issued) or within that
    /// rank's window, so it can always advance.
    window: usize,
    /// Index of the first unfinished chunk.
    oldest: usize,
    /// Total elements across all chunks (the op buffer length).
    total: usize,
}

impl PipelinedCursor {
    /// A pipelined driver for one op epoch. `chunks` is the geometry:
    /// `(element offset, chunk plan)` per chunk, contiguous and in
    /// order, with every plan sharing one schedule shape (chunk plans
    /// differ only in partition). `window` bounds in-flight chunks
    /// ([`DEFAULT_PIPELINE_WINDOW`]).
    pub fn new(op_tag: u64, chunks: Vec<(usize, Arc<Plan>)>, window: usize) -> Self {
        assert!(!chunks.is_empty(), "pipelined op needs at least one chunk");
        let rounds_per_chunk = chunks[0].1.schedule.rounds.len();
        let mut total = 0usize;
        let chunks: Vec<ChunkCursor> = chunks
            .into_iter()
            .enumerate()
            .map(|(k, (offset, plan))| {
                debug_assert_eq!(offset, total, "chunk {k} offset not contiguous");
                debug_assert_eq!(
                    plan.schedule.rounds.len(),
                    rounds_per_chunk,
                    "chunk {k} schedule shape diverges"
                );
                total += plan.part.total();
                ChunkCursor {
                    cursor: OpCursor::new(op_tag, (k * rounds_per_chunk) as u64),
                    offset,
                    plan,
                    done: false,
                }
            })
            .collect();
        Self { op_tag, chunks, window: window.max(1), oldest: 0, total }
    }

    /// The operation epoch every chunk tags its traffic with.
    pub fn op_tag(&self) -> u64 {
        self.op_tag
    }

    /// Number of chunk epochs this op runs.
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Aggregate monotone progress stamp — the sum of the chunk cursors'
    /// stamps, so any chunk advancing registers with the engine watchdog.
    pub fn progress(&self) -> u64 {
        self.chunks.iter().map(|c| c.cursor.progress()).sum()
    }

    /// [`OpCursor::first_needed_down_peer`] over every unfinished chunk.
    pub fn first_needed_down_peer(&self, rank: usize, up: &[bool]) -> Option<usize> {
        self.chunks.iter().skip(self.oldest).filter(|c| !c.done).find_map(|c| {
            c.cursor.first_needed_down_peer(&c.plan.schedule, rank, up)
        })
    }

    /// The watchdog error for a stalled pipelined op — reported from the
    /// oldest unfinished chunk (the one whose wait gates the pipeline).
    pub fn timeout_error(&self, rank: usize) -> CollectiveError {
        let c = self
            .chunks
            .iter()
            .find(|c| !c.done)
            .unwrap_or_else(|| self.chunks.last().expect("pipelined op has at least one chunk"));
        c.cursor.timeout_error(&c.plan.schedule, rank)
    }

    /// Quiesce every chunk's outstanding publishes (one epoch, one call).
    pub fn abort<T: Elem, C: Transport<T>>(&mut self, ep: &mut C) {
        let _ = ep.finish_op(self.op_tag);
    }

    /// Advance the pipeline as far as possible. Non-blocking mode
    /// interleaves the in-flight window's chunk cursors and returns
    /// [`Progress::Pending`] once none of them can complete; blocking
    /// mode runs the chunks to completion in order (no overlap — the
    /// engine's non-blocking worker loop is where pipelining pays).
    pub fn step<T: Elem, C: Transport<T>>(
        &mut self,
        ep: &mut C,
        op: &dyn ReduceOp<T>,
        buf: &mut [T],
        blocking: bool,
    ) -> Result<Progress, CollectiveError> {
        let r = ep.rank();
        if buf.len() != self.total {
            return Err(CollectiveError::BadBuffer { rank: r, got: buf.len(), want: self.total });
        }
        // Chunk views are carved from the raw base pointer (see the
        // aliasing note in `step_with_tiers`): re-borrowing `buf` per
        // chunk would transiently form a `&mut` spanning regions other
        // chunks may have published to rendezvous peers.
        let base = buf.as_mut_ptr();
        loop {
            while self.oldest < self.chunks.len() && self.chunks[self.oldest].done {
                self.oldest += 1;
            }
            if self.oldest == self.chunks.len() {
                return Ok(Progress::Done);
            }
            let horizon = if blocking {
                self.chunks.len()
            } else {
                (self.oldest + self.window).min(self.chunks.len())
            };
            let mut completed = false;
            for k in self.oldest..horizon {
                let c = &mut self.chunks[k];
                if c.done {
                    continue;
                }
                let range = c.offset..c.offset + c.plan.part.total();
                // SAFETY: chunk ranges are contiguous, disjoint and in
                // bounds of `buf` (checked against `total` above); no
                // other chunk's view overlaps this range, and the inner
                // step upholds the per-chunk publish discipline.
                let chunk_buf = unsafe { view_mut(base, &range) };
                match c.cursor.step_with_tiers(
                    ep,
                    &c.plan.schedule,
                    &c.plan.part,
                    op,
                    chunk_buf,
                    blocking,
                    Some(&c.plan.tiers),
                )? {
                    Progress::Done => {
                        c.done = true;
                        completed = true;
                    }
                    Progress::Pending => {}
                }
            }
            if !completed {
                return Ok(Progress::Pending);
            }
            // A chunk finished, so the window slides: poll the newly
            // admitted chunks before yielding back to the caller.
        }
    }
}

/// Execute `schedule` for this transport's rank, blocking until complete.
/// Works over any [`Transport`] backend — threads in-process, Unix-domain
/// sockets across processes (`ccoll launch`).
///
/// `buf` is the rank's working vector (`part.total()` elements, global
/// layout). On return it contains whatever the schedule semantics leave
/// behind: for reduce-scatter, block `rank` is the finished `W`; for
/// allreduce, the whole buffer; for allgather, all blocks.
///
/// `round_base` offsets the transport round tags so several collectives
/// can run back-to-back on one endpoint (the coordinator uses this). All
/// traffic runs in op-epoch 0, the legacy wire space; for *concurrent*
/// operations on one endpoint use an [`OpCursor`] per op with distinct
/// `op_tag`s (what [`crate::engine`] does).
///
/// The zero-copy rendezvous tier engages per round iff the backend
/// supports it ([`Transport::caps`]), the transport opted in
/// ([`Transport::set_rendezvous`]), this rank's send and recv block
/// ranges for the round are disjoint, and the payload meets the
/// transport's small-message threshold
/// ([`Transport::set_rendezvous_min_elems`]); other rounds use the copy
/// tiers. Payload lengths are validated once per round, before any kernel
/// call — the kernels themselves stay on the unchecked fast path
/// (`ReduceOp` docs).
pub fn execute_rank<T: Elem, C: Transport<T>>(
    ep: &mut C,
    schedule: &Schedule,
    part: &BlockPartition,
    op: &dyn ReduceOp<T>,
    buf: &mut [T],
    round_base: u64,
) -> Result<u64, CollectiveError> {
    let mut cursor = OpCursor::new(0, round_base);
    match cursor.step(ep, schedule, part, op, buf, true)? {
        Progress::Done => Ok(round_base + schedule.rounds.len() as u64),
        Progress::Pending => unreachable!("blocking OpCursor::step never yields Pending"),
    }
}

/// Convenience driver for tests/benches: run `schedule` over `p` threads
/// with per-rank f32 input vectors, returning the final per-rank buffers.
/// Runs with the rendezvous tier enabled (the default hot path). See
/// [`run_schedule_threads_typed`] for other dtypes.
pub fn run_schedule_threads(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
) -> Vec<Vec<f32>> {
    run_schedule_threads_typed::<f32>(schedule, part, op, inputs)
}

/// [`run_schedule_threads`] over any element type.
pub fn run_schedule_threads_typed<T: Elem>(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp<T>>,
    inputs: Vec<Vec<T>>,
) -> Vec<Vec<T>> {
    run_schedule_threads_tiered_typed::<T>(schedule, part, op, inputs, true)
        .into_iter()
        .map(|(buf, _)| buf)
        .collect()
}

/// Like [`run_schedule_threads`] but also returns each rank's transport
/// [`Counters`], with the copy tier under caller control: `rendezvous =
/// false` pins every round to the pooled protocol (the PR-1 baseline the
/// pool-accounting tests and the perf ablation measure), `true` enables
/// the zero-copy tier where the schedule allows it.
pub fn run_schedule_threads_tiered(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
    rendezvous: bool,
) -> Vec<(Vec<f32>, Counters)> {
    run_schedule_threads_tiered_typed::<f32>(schedule, part, op, inputs, rendezvous)
}

/// [`run_schedule_threads_tiered`] over any element type.
pub fn run_schedule_threads_tiered_typed<T: Elem>(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp<T>>,
    inputs: Vec<Vec<T>>,
    rendezvous: bool,
) -> Vec<(Vec<T>, Counters)> {
    use crate::transport::run_ranks_inputs_typed;
    assert_eq!(inputs.len(), schedule.p);
    let schedule = std::sync::Arc::new(schedule.clone());
    let part = std::sync::Arc::new(part.clone());
    // Each rank's input travels by move through its spawn closure — no
    // shared hand-off structure, no lock.
    run_ranks_inputs_typed::<T, Vec<T>, (Vec<T>, Counters), _>(inputs, move |rank, ep, mut buf| {
        ep.rendezvous = rendezvous && crate::transport::rendezvous_env_enabled();
        if ep.rendezvous {
            // Test/bench driver: pin the small-payload threshold to 0 so
            // the zero-copy tier engages deterministically regardless of
            // payload size (the Communicator keeps the latency-tuned
            // default).
            ep.rendezvous_min_elems = 0;
        }
        execute_rank(ep, &schedule, &part, op.as_ref(), &mut buf, 0)
            .unwrap_or_else(|e| panic!("rank {rank}: {e}"));
        (buf, ep.counters.clone())
    })
}

/// Like [`run_schedule_threads`] but also returns each rank's transport
/// [`Counters`] (volume + pool hit/miss — the allocation-regression tests
/// read these). Pinned to the pooled tier so the pool counters account
/// for every send; use [`run_schedule_threads_tiered`] to measure the
/// rendezvous tier.
pub fn run_schedule_threads_with_counters(
    schedule: &Schedule,
    part: &BlockPartition,
    op: std::sync::Arc<dyn ReduceOp>,
    inputs: Vec<Vec<f32>>,
) -> Vec<(Vec<f32>, Counters)> {
    run_schedule_threads_tiered(schedule, part, op, inputs, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::ops::SumOp;
    use crate::topology::skips::SkipScheme;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    /// Scalar oracle: elementwise sum over all rank inputs.
    fn oracle_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = vec![0.0f32; inputs[0].len()];
        for v in inputs {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        acc
    }

    fn int_inputs(p: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(seed);
        (0..p).map(|_| rng.int_valued_vec(m, -8, 9)).collect()
    }

    #[test]
    fn reduce_scatter_matches_oracle_small() {
        for p in [2usize, 3, 5, 8, 22] {
            let part = BlockPartition::regular(p, 4 * p + 3);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = reduce_scatter_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                let range = part.range(r);
                assert_eq!(&buf[range.clone()], &want[range], "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn allreduce_matches_oracle_small() {
        for p in [2usize, 4, 7, 22] {
            let part = BlockPartition::regular(p, 3 * p + 1);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = allreduce_schedule(p, &skips);
            let inputs = int_inputs(p, part.total(), 100 + p as u64);
            let want = oracle_sum(&inputs);
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn typed_allreduce_matches_wrapping_oracle_i64() {
        use crate::datatypes::elem::int_vec;
        for p in [2usize, 5, 8] {
            let part = BlockPartition::regular(p, 3 * p + 2);
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = allreduce_schedule(p, &skips);
            let mut rng = SplitMix64::new(400 + p as u64);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|_| int_vec(&mut rng, part.total(), -8, 9)).collect();
            let mut want = vec![0i64; part.total()];
            for v in &inputs {
                for (a, b) in want.iter_mut().zip(v) {
                    *a = a.wrapping_add(*b);
                }
            }
            let out = run_schedule_threads_typed::<i64>(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} rank {r}");
            }
        }
    }

    #[test]
    fn cursor_drives_both_ranks_nonblocking_on_one_thread() {
        // The engine worker pattern in miniature: drive BOTH ranks of a
        // p=2 allreduce from a single thread with non-blocking cursors —
        // no call may park, and interleaved polling must converge.
        let p = 2;
        let part = BlockPartition::regular(p, 8);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        let mut eps = crate::transport::network(p);
        let mut bufs = [vec![1.0f32; 8], vec![2.0f32; 8]];
        let mut cursors = [OpCursor::new(7, 0), OpCursor::new(7, 0)];
        let mut done = [false, false];
        let mut polls = 0;
        while !(done[0] && done[1]) {
            for r in 0..p {
                if done[r] {
                    continue;
                }
                match cursors[r]
                    .step(&mut eps[r], &sched, &part, &SumOp, &mut bufs[r], false)
                    .unwrap()
                {
                    Progress::Done => done[r] = true,
                    Progress::Pending => {}
                }
            }
            polls += 1;
            assert!(polls < 10_000, "cursors stopped making progress");
        }
        for buf in &bufs {
            assert_eq!(buf, &vec![3.0f32; 8]);
        }
        assert!(cursors[0].progress() > 0 && cursors[0].op_tag() == 7);
    }

    /// Build the `(offset, plan)` chunk specs for a pipelined op over a
    /// shared schedule, partitioning each chunk regularly.
    fn chunk_specs(sched: &Schedule, m: usize, chunk: usize) -> Vec<(usize, Arc<Plan>)> {
        let mut offset = 0usize;
        pipeline_chunk_sizes(m, chunk)
            .into_iter()
            .map(|len| {
                let spec = (
                    offset,
                    Arc::new(Plan::new(
                        sched.clone(),
                        BlockPartition::regular(sched.p, len),
                    )),
                );
                offset += len;
                spec
            })
            .collect()
    }

    #[test]
    fn pipelined_cursor_interleaves_chunks_on_one_thread() {
        // The pipelined analogue of the cursor interleave test: drive
        // both ranks of a chunked p=2 allreduce from one thread with
        // non-blocking pipelined cursors. With window 2, chunk k+1's
        // sends must interleave with chunk k's combines and the whole
        // pipeline must converge without any call parking.
        let p = 2;
        let m = 35; // not divisible by the chunk: remainder folds into the last chunk
        let chunk = 8;
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = allreduce_schedule(p, &skips);
        assert_eq!(pipeline_chunk_sizes(m, chunk), vec![8, 8, 8, 11]);
        let specs = chunk_specs(&sched, m, chunk);
        let mut eps = crate::transport::network(p);
        let mut bufs = [vec![1.0f32; m], vec![2.0f32; m]];
        let mut cursors = [
            PipelinedCursor::new(9, specs.clone(), 2),
            PipelinedCursor::new(9, specs, 2),
        ];
        assert_eq!(cursors[0].num_chunks(), 4);
        let mut done = [false, false];
        let mut polls = 0;
        while !(done[0] && done[1]) {
            for r in 0..p {
                if done[r] {
                    continue;
                }
                match cursors[r].step(&mut eps[r], &SumOp, &mut bufs[r], false).unwrap() {
                    Progress::Done => done[r] = true,
                    Progress::Pending => {}
                }
            }
            polls += 1;
            assert!(polls < 100_000, "pipelined cursors stopped making progress");
        }
        for buf in &bufs {
            assert_eq!(buf, &vec![3.0f32; m]);
        }
        assert!(cursors[0].progress() > 0 && cursors[0].op_tag() == 9);
    }

    #[test]
    fn pipeline_chunk_geometry() {
        assert_eq!(pipeline_chunk_sizes(32, 8), vec![8, 8, 8, 8]);
        assert_eq!(pipeline_chunk_sizes(35, 8), vec![8, 8, 8, 11], "remainder folds into last");
        assert_eq!(pipeline_chunk_sizes(15, 8), vec![15], "no second chunk fits: plain");
        assert_eq!(pipeline_chunk_sizes(8, 8), vec![8], "chunk == m: plain");
        assert_eq!(pipeline_chunk_sizes(4, 8), vec![4], "chunk > m: plain");
        assert_eq!(pipeline_chunk_sizes(0, 8), vec![0], "zero-length op: plain");
        assert_eq!(pipeline_chunk_sizes(64, 0), vec![64], "chunking disabled: plain");
    }

    #[test]
    fn bad_buffer_rejected() {
        let part = BlockPartition::uniform(2, 4);
        let sched = reduce_scatter_schedule(2, &[1]);
        let out = crate::transport::run_ranks(2, move |_rank, ep| {
            let mut buf = vec![0.0f32; 3]; // wrong size
            execute_rank(ep, &sched, &part, &SumOp, &mut buf, 0).is_err()
        });
        assert!(out.iter().all(|&e| e));
    }

    #[test]
    fn pooled_transport_zero_alloc_steady_state() {
        // Allocation regression: back-to-back allreduces on ONE network.
        // After the warm-up iterations the pools must serve every payload
        // (pool misses stop growing — zero steady-state allocations).
        let p = 2usize;
        let m = 64usize;
        let part = Arc::new(BlockPartition::regular(p, m));
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = Arc::new(allreduce_schedule(p, &skips));
        let (warm, total) = (10u64, 50u64);
        let out = crate::transport::run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32 + 1.0; m];
            let mut tag = 0u64;
            for _ in 0..warm {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            let misses_after_warm = ep.counters.pool_misses;
            for _ in warm..total {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            (misses_after_warm, ep.counters.clone())
        });
        for (rank, (warm_misses, c)) in out.iter().enumerate() {
            // Supply only grows on a miss, and a just-released buffer can
            // race the next acquire, so allow the bounded tail of that
            // race (≤ 2 per capacity class) — a real regression allocates
            // every round, i.e. ~(total−warm)·2 = 80 extra misses here.
            let steady_misses = c.pool_misses - warm_misses;
            assert!(
                steady_misses <= 2,
                "rank {rank}: {steady_misses} pool misses after warm-up (steady-state allocation)"
            );
            assert!(c.pool_hits > 0, "rank {rank}: the pool never served a buffer");
            assert!(c.bufs_recycled > 0, "rank {rank}: no buffer ever returned");
            let acquires = c.pool_hits + c.pool_misses;
            assert!(acquires >= total * 2, "rank {rank}: not enough acquires measured");
        }
    }

    #[test]
    fn send_only_rounds_recycle_buffers() {
        // Binomial allreduce = reduce + bcast: every non-root rank has
        // send-only rounds (tree edges). The old executor only restored
        // its scratch when a recv happened, so these rounds allocated
        // every time; the loan protocol must recycle them identically.
        let p = 4usize;
        let m = 32usize;
        let part = Arc::new(BlockPartition::regular(p, m));
        let sched = Arc::new(crate::collectives::baselines::binomial_allreduce_schedule(p));
        let (warm, total) = (5u64, 30u64);
        let out = crate::transport::run_ranks(p, move |rank, ep| {
            let mut buf = vec![rank as f32; m];
            let mut tag = 0u64;
            for _ in 0..warm {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            let misses_after_warm = ep.counters.pool_misses;
            for _ in warm..total {
                tag = execute_rank(ep, &sched, &part, &SumOp, &mut buf, tag).unwrap();
            }
            (misses_after_warm, ep.counters.clone())
        });
        for (rank, (warm_misses, c)) in out.iter().enumerate() {
            // Tolerate the bounded release/acquire race (see the zero-alloc
            // test above); a per-round leak would show ~25+ extra misses.
            let steady_misses = c.pool_misses - warm_misses;
            assert!(
                steady_misses <= 4,
                "rank {rank}: {steady_misses} misses after warm-up — send-only rounds still allocate"
            );
        }
    }

    #[test]
    fn rendezvous_rounds_send_zero_steady_state_allocations_too() {
        if !crate::transport::rendezvous_env_enabled() {
            return; // CCOLL_NO_RENDEZVOUS: the publish path is off by design
        }
        // The tier-1 analogue of the pooled zero-alloc regression: with
        // rendezvous enabled, sends neither allocate nor even touch the
        // pool — every round publishes descriptors.
        let p = 4usize;
        let m = 64usize;
        let part = Arc::new(BlockPartition::regular(p, m));
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = Arc::new(allreduce_schedule(p, &skips));
        assert!(sched.rendezvous_safe());
        let total = 20u64;
        let (sched2, part2) = (sched.clone(), part.clone());
        let out = crate::transport::run_ranks(p, move |rank, ep| {
            ep.rendezvous = true;
            ep.rendezvous_min_elems = 0;
            let mut buf = vec![rank as f32 + 1.0; m];
            let mut tag = 0u64;
            for _ in 0..total {
                tag = execute_rank(ep, &sched2, &part2, &SumOp, &mut buf, tag).unwrap();
            }
            ep.counters.clone()
        });
        for (rank, c) in out.iter().enumerate() {
            assert_eq!(c.rendezvous_hits, c.msgs_sent, "rank {rank}: every send rendezvous");
            assert_eq!(c.pool_hits + c.pool_misses, 0, "rank {rank}: pool untouched");
            // Copy volume: only the allgather-phase Store scatters remain.
            let sc = sched.counters(&part)[rank].clone();
            let store_elems = sc.elems_recv - sc.elems_combined;
            assert_eq!(c.bytes_copied, 4 * (store_elems as u64) * total, "rank {rank}");
        }
    }
}
