//! Schedule generators for the paper's algorithms.
//!
//! [`reduce_scatter_schedule`] is Algorithm 1 (generalized to any valid
//! skip sequence per Corollary 2), [`allgather_schedule`] is the mirrored
//! allgather of Algorithm 2's second phase (the skip stack run in reverse),
//! and [`allreduce_schedule`] is their concatenation (Algorithm 2).
//!
//! All ranges are **global block ids** (see `crate::schedule`): in round
//! `k` with skip `σ_k` (and `σ_{k−1}` the previous skip, `σ_0 = p`), rank
//! `r` sends the blocks `(r+σ_k … r+σ_{k−1})` — its partials `R[σ_k …
//! σ_{k−1})` — to `(r+σ_k) mod p`, and folds the same-id blocks received
//! from `(r−σ_k) mod p` into its own partials.

use crate::schedule::{
    BlockRange, RankStep, Recv, RecvAction, Round, Schedule, ScheduleError, Transfer,
};
use crate::topology::skips::validate;

/// Algorithm 1: the `⌈log2 p⌉`-round (for halving-up skips) reduce-scatter
/// (partitioned all-reduce) schedule. Panics on an invalid skip sequence;
/// library callers should prefer [`try_reduce_scatter_schedule`].
pub fn reduce_scatter_schedule(p: usize, skips: &[usize]) -> Schedule {
    try_reduce_scatter_schedule(p, skips)
        .unwrap_or_else(|e| panic!("invalid skip sequence: {e}"))
}

/// Fallible variant of [`reduce_scatter_schedule`]: a bad skip sequence
/// comes back as a typed [`ScheduleError`] instead of a panic.
pub fn try_reduce_scatter_schedule(p: usize, skips: &[usize]) -> Result<Schedule, ScheduleError> {
    validate(p, skips)?;
    let mut sched = Schedule::new(p, format!("circulant-rs[{skips:?}]"));
    if p == 1 {
        return Ok(sched);
    }
    let mut prev = p;
    for &s in skips {
        let len = prev - s;
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let to = (r + s) % p;
            let from = (r + p - s) % p;
            *step = RankStep {
                send: Some(Transfer { peer: to, blocks: BlockRange::new(to, len) }),
                recv: Some(Recv {
                    peer: from,
                    blocks: BlockRange::new(r, len),
                    action: RecvAction::Combine,
                }),
            };
        }
        sched.rounds.push(round);
        prev = s;
    }
    Ok(sched)
}

/// Algorithm 2, phase 2: allgather along the same circulant graph with the
/// skip sequence replayed in reverse (the paper's stack), `Store` actions.
/// Precondition: rank `r` holds finished block `r` (e.g. after
/// [`reduce_scatter_schedule`]). Panics on an invalid skip sequence;
/// library callers should prefer [`try_allgather_schedule`].
pub fn allgather_schedule(p: usize, skips: &[usize]) -> Schedule {
    try_allgather_schedule(p, skips).unwrap_or_else(|e| panic!("invalid skip sequence: {e}"))
}

/// Fallible variant of [`allgather_schedule`].
pub fn try_allgather_schedule(p: usize, skips: &[usize]) -> Result<Schedule, ScheduleError> {
    validate(p, skips)?;
    let mut sched = Schedule::new(p, format!("circulant-ag[{skips:?}]"));
    if p == 1 {
        return Ok(sched);
    }
    for k in (0..skips.len()).rev() {
        let s = skips[k];
        let prev = if k == 0 { p } else { skips[k - 1] };
        let len = prev - s;
        let mut round = Round::idle(p);
        for (r, step) in round.steps.iter_mut().enumerate() {
            let to = (r + p - s) % p; // send *backwards* along the circulant
            let from = (r + s) % p;
            *step = RankStep {
                send: Some(Transfer { peer: to, blocks: BlockRange::new(r, len) }),
                recv: Some(Recv {
                    peer: from,
                    blocks: BlockRange::new(from, len),
                    action: RecvAction::Store,
                }),
            };
        }
        sched.rounds.push(round);
    }
    Ok(sched)
}

/// Algorithm 2: allreduce = reduce-scatter followed by the mirrored
/// allgather. `2·len(skips)` rounds; with halving-up skips that is
/// `2⌈log2 p⌉`, with `2(p−1)` blocks sent/received and `p−1` ⊕-applications
/// per processor (Theorem 2). Panics on an invalid skip sequence; library
/// callers should prefer [`try_allreduce_schedule`].
pub fn allreduce_schedule(p: usize, skips: &[usize]) -> Schedule {
    try_allreduce_schedule(p, skips).unwrap_or_else(|e| panic!("invalid skip sequence: {e}"))
}

/// Fallible variant of [`allreduce_schedule`].
pub fn try_allreduce_schedule(p: usize, skips: &[usize]) -> Result<Schedule, ScheduleError> {
    let mut rs = try_reduce_scatter_schedule(p, skips)?;
    let ag = try_allgather_schedule(p, skips)?;
    rs.name = format!("circulant-allreduce[{skips:?}]");
    rs.rounds.extend(ag.rounds);
    Ok(rs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatypes::BlockPartition;
    use crate::topology::skips::SkipScheme;
    use crate::util::ceil_log2;

    fn halving(p: usize) -> Vec<usize> {
        SkipScheme::HalvingUp.skips(p).unwrap()
    }

    #[test]
    fn theorem1_counters_exact() {
        // ⌈log2 p⌉ rounds; exactly p−1 blocks sent, received and combined
        // per processor — for every p.
        for p in 2..=128usize {
            let sched = reduce_scatter_schedule(p, &halving(p));
            sched.assert_valid();
            assert_eq!(sched.num_rounds() as u32, ceil_log2(p), "p={p}");
            let part = BlockPartition::uniform(p, 3);
            for (r, c) in sched.counters(&part).iter().enumerate() {
                assert_eq!(c.blocks_sent, p - 1, "p={p} r={r}");
                assert_eq!(c.blocks_recv, p - 1, "p={p} r={r}");
                assert_eq!(c.blocks_combined, p - 1, "p={p} r={r}");
                assert_eq!(c.elems_sent, (p - 1) * 3);
                assert_eq!(c.active_rounds as u32, ceil_log2(p));
            }
        }
    }

    #[test]
    fn theorem2_counters_exact() {
        // 2⌈log2 p⌉ rounds; 2(p−1) blocks sent/received; p−1 combines.
        for p in 2..=128usize {
            let sched = allreduce_schedule(p, &halving(p));
            sched.assert_valid();
            assert_eq!(sched.num_rounds() as u32, 2 * ceil_log2(p), "p={p}");
            let part = BlockPartition::uniform(p, 2);
            for c in sched.counters(&part) {
                assert_eq!(c.blocks_sent, 2 * (p - 1));
                assert_eq!(c.blocks_recv, 2 * (p - 1));
                assert_eq!(c.blocks_combined, p - 1);
            }
        }
    }

    #[test]
    fn corollary2_other_schemes_valid_and_volume_optimal() {
        for p in [7usize, 22, 100, 257] {
            for scheme in [SkipScheme::PowerOfTwo, SkipScheme::Sqrt, SkipScheme::FullyConnected] {
                let skips = scheme.skips(p).unwrap();
                let sched = reduce_scatter_schedule(p, &skips);
                sched.assert_valid();
                assert_eq!(sched.num_rounds(), skips.len());
                let part = BlockPartition::uniform(p, 1);
                for c in sched.counters(&part) {
                    // Volume optimality holds for *any* valid skip sequence.
                    assert_eq!(c.blocks_sent, p - 1, "{} p={p}", scheme.name());
                    assert_eq!(c.blocks_combined, p - 1);
                }
            }
        }
    }

    #[test]
    fn p22_round1_send_is_11_blocks_at_distance_11() {
        let sched = reduce_scatter_schedule(22, &halving(22));
        let step = &sched.rounds[0].steps[21];
        let send = step.send.unwrap();
        assert_eq!(send.peer, (21 + 11) % 22); // to-processor 10
        assert_eq!(send.blocks.len, 11);
        let recv = step.recv.unwrap();
        assert_eq!(recv.peer, 10); // from-processor 21−11 = 10
        assert_eq!(recv.blocks, BlockRange::new(21, 11));
    }

    #[test]
    fn halving_up_message_runs_at_most_half() {
        // §3: no sequence of blocks longer than ⌈p/2⌉ with halving-up.
        for p in 2..=256usize {
            let sched = allreduce_schedule(p, &halving(p));
            assert!(sched.max_message_blocks() <= p.div_ceil(2), "p={p}");
        }
    }

    #[test]
    fn allgather_is_exact_mirror() {
        let p = 22;
        let rs = reduce_scatter_schedule(p, &halving(p));
        let ag = allgather_schedule(p, &halving(p));
        assert_eq!(rs.num_rounds(), ag.num_rounds());
        // Rounds mirror: AG round j has the lengths of RS round q−1−j and
        // inverted direction.
        for j in 0..ag.num_rounds() {
            let rsr = &rs.rounds[ag.num_rounds() - 1 - j].steps[0];
            let agr = &ag.rounds[j].steps[0];
            assert_eq!(rsr.send.unwrap().blocks.len, agr.send.unwrap().blocks.len);
            assert_eq!(rsr.send.unwrap().peer, agr.recv.unwrap().peer);
        }
    }

    #[test]
    fn try_variants_reject_bad_skips_with_typed_error() {
        // [3, 1] violates the in-place condition σ_{k−1} ≤ 2σ_k.
        let e = try_reduce_scatter_schedule(8, &[3, 1]).unwrap_err();
        assert_eq!(e.code(), "bad-skips");
        assert!(try_allgather_schedule(8, &[3, 1]).is_err());
        assert!(try_allreduce_schedule(8, &[3, 1]).is_err());
        assert!(try_allreduce_schedule(8, &[4, 2, 1]).is_ok());
    }

    #[test]
    fn p1_empty_schedules() {
        assert_eq!(reduce_scatter_schedule(1, &[]).num_rounds(), 0);
        assert_eq!(allreduce_schedule(1, &[]).num_rounds(), 0);
    }
}
