//! Collectives derived by specialization (paper §4 / Corollary 3).
//!
//! * **Reduce to root** — Algorithm 1 on the degenerate single-block
//!   partition (all `m` elements in block `root`): the reduction arrives
//!   at `root` in `⌈log2 p⌉` rounds, cost `≤ ⌈log2 p⌉(α+βm+γm)`
//!   (Corollary 3), attractive for small `m`.
//! * **Broadcast** — the mirrored allgather on the same degenerate
//!   partition: only the messages covering block `root` carry data.
//! * **Gather / Scatter** — single-block specializations of allgather and
//!   of a root-rooted all-to-all row.
//!
//! These return ordinary [`Schedule`]s; empty blocks simply produce empty
//! payloads, and the schedule structure (peers, rounds) is unchanged —
//! which is exactly the paper's "by specialization" observation.

use crate::datatypes::BlockPartition;
use crate::schedule::Schedule;
use crate::topology::skips::SkipScheme;

use super::generators::{allgather_schedule, reduce_scatter_schedule};

/// Reduce-to-root schedule + the partition that makes Algorithm 1 deliver
/// the whole `m`-element result at `root`.
pub fn reduce_schedule(p: usize, m: usize, root: usize, scheme: &SkipScheme) -> (Schedule, BlockPartition) {
    let skips = scheme.skips(p).expect("valid scheme");
    let mut sched = reduce_scatter_schedule(p, &skips);
    sched.name = format!("circulant-reduce(root={root})");
    (sched, BlockPartition::single_block(p, m, root))
}

/// Broadcast-from-root schedule + partition (mirrored allgather on the
/// degenerate partition). Precondition: `root`'s buffer block holds the
/// payload.
pub fn bcast_schedule(p: usize, m: usize, root: usize, scheme: &SkipScheme) -> (Schedule, BlockPartition) {
    let skips = scheme.skips(p).expect("valid scheme");
    let mut sched = allgather_schedule(p, &skips);
    sched.name = format!("circulant-bcast(root={root})");
    (sched, BlockPartition::single_block(p, m, root))
}

/// Gather-to-root: the circulant allgather restricted by a partition where
/// every rank owns a real block; `root` simply keeps the result (other
/// ranks' gathered copies are a by-product of the uniform schedule — the
/// specialization trades no extra rounds for simplicity).
pub fn gather_schedule(p: usize, part: &BlockPartition, root: usize, scheme: &SkipScheme) -> Schedule {
    let _ = root;
    let skips = scheme.skips(p).expect("valid scheme");
    let mut sched = allgather_schedule(p, &skips);
    sched.name = format!("circulant-gather(root={root})");
    assert_eq!(part.p(), p);
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::run_schedule_threads;
    use crate::ops::SumOp;
    use crate::util::ceil_log2;
    use crate::util::rng::SplitMix64;
    use std::sync::Arc;

    #[test]
    fn reduce_to_root_delivers_full_vector() {
        for p in [2usize, 5, 8, 22] {
            for root in [0, p - 1] {
                let m = 33;
                let (sched, part) = reduce_schedule(p, m, root, &SkipScheme::HalvingUp);
                sched.assert_valid();
                assert_eq!(sched.num_rounds() as u32, ceil_log2(p));
                let mut rng = SplitMix64::new((p + root) as u64);
                let inputs: Vec<Vec<f32>> =
                    (0..p).map(|_| rng.int_valued_vec(m, -4, 5)).collect();
                let mut want = vec![0.0f32; m];
                for v in &inputs {
                    for (a, b) in want.iter_mut().zip(v) {
                        *a += b;
                    }
                }
                let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
                assert_eq!(&out[root][part.range(root)], &want[..], "p={p} root={root}");
            }
        }
    }

    #[test]
    fn bcast_reaches_everyone() {
        for p in [2usize, 6, 22] {
            let m = 17;
            let root = p / 2;
            let (sched, part) = bcast_schedule(p, m, root, &SkipScheme::HalvingUp);
            sched.assert_valid();
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|r| {
                    if r == root {
                        (0..m).map(|j| j as f32 + 1.0).collect()
                    } else {
                        vec![0.0; m]
                    }
                })
                .collect();
            let want: Vec<f32> = (0..m).map(|j| j as f32 + 1.0).collect();
            let out = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs);
            for (r, buf) in out.iter().enumerate() {
                assert_eq!(buf, &want, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn reduce_cost_matches_corollary3_bound() {
        use crate::sim::{closed_form, simulate, CostModel};
        let (p, m) = (22, 1000);
        let (sched, part) = reduce_schedule(p, m, 3, &SkipScheme::HalvingUp);
        let c = CostModel::new(1.0, 0.01, 0.001);
        let sim = simulate(&sched, &part, &c);
        let bound = closed_form::corollary3_bound(&c, p, m);
        assert!(sim.total <= bound + 1e-9, "sim {} > bound {}", sim.total, bound);
        // and it is genuinely latency-efficient: far below the ring's cost
        let ring = (p - 1) as f64 * (c.alpha + (c.beta + c.gamma) * m as f64);
        assert!(sim.total < ring);
    }
}
