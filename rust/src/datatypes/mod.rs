//! Vector block partitions — the data layout vocabulary of the collectives
//! — and the scalar element subsystem ([`elem`]).
//!
//! Every processor's input vector of `m` elements is partitioned *in the
//! same way* into `p` consecutive blocks (paper §2.1). Blocks may have
//! equal sizes (MPI_Reduce_scatter_block), arbitrary sizes
//! (MPI_Reduce_scatter, Corollary 3), or be degenerate with all elements in
//! one block (reduce-to-root).

pub mod elem;

pub use elem::{DType, Elem};

use std::ops::Range;

use crate::util::rng::SplitMix64;

/// A partition of `0..m` into `p` consecutive blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockPartition {
    /// `offsets[g]..offsets[g+1]` is block `g`; `offsets.len() == p + 1`.
    offsets: Vec<usize>,
}

impl BlockPartition {
    /// Regular partition: `p` blocks as equal as possible (first `m mod p`
    /// blocks get one extra element), total exactly `m`.
    pub fn regular(p: usize, m: usize) -> Self {
        assert!(p > 0);
        let base = m / p;
        let extra = m % p;
        let mut offsets = Vec::with_capacity(p + 1);
        let mut acc = 0;
        offsets.push(0);
        for g in 0..p {
            acc += base + usize::from(g < extra);
            offsets.push(acc);
        }
        Self { offsets }
    }

    /// Uniform partition where every block has exactly `b` elements.
    pub fn uniform(p: usize, b: usize) -> Self {
        Self::from_counts(&vec![b; p])
    }

    /// Partition from explicit per-block counts (MPI_Reduce_scatter).
    pub fn from_counts(counts: &[usize]) -> Self {
        assert!(!counts.is_empty());
        let mut offsets = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0;
        offsets.push(0);
        for &c in counts {
            acc += c;
            offsets.push(acc);
        }
        Self { offsets }
    }

    /// Degenerate partition: all `m` elements in block `root` (Corollary 3's
    /// reduce-to-root case), all other blocks empty.
    pub fn single_block(p: usize, m: usize, root: usize) -> Self {
        assert!(root < p);
        let mut counts = vec![0usize; p];
        counts[root] = m;
        Self::from_counts(&counts)
    }

    /// Random irregular partition of `m` over `p` blocks (multinomial via
    /// stars-and-bars sampling), deterministic per seed — the T4 workload.
    pub fn random(p: usize, m: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut cuts: Vec<usize> = (0..p - 1).map(|_| rng.next_below(m + 1)).collect();
        cuts.sort_unstable();
        let mut counts = Vec::with_capacity(p);
        let mut prev = 0;
        for &c in &cuts {
            counts.push(c - prev);
            prev = c;
        }
        counts.push(m - prev);
        Self::from_counts(&counts)
    }

    /// Zipf-skewed irregular partition (block g proportional to 1/(g+1)^a,
    /// shuffled) — the heavy-tail T4 workload.
    pub fn zipf(p: usize, m: usize, a: f64, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let weights: Vec<f64> = (0..p).map(|g| 1.0 / ((g + 1) as f64).powf(a)).collect();
        let total: f64 = weights.iter().sum();
        let mut counts: Vec<usize> =
            weights.iter().map(|w| (w / total * m as f64).floor() as usize).collect();
        let mut used: usize = counts.iter().sum();
        while used < m {
            let i = rng.next_below(p);
            counts[i] += 1;
            used += 1;
        }
        let perm = rng.permutation(p);
        let shuffled: Vec<usize> = perm.iter().map(|&i| counts[i]).collect();
        Self::from_counts(&shuffled)
    }

    /// Number of blocks `p`.
    pub fn p(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total element count `m`.
    pub fn total(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Element range of block `g`.
    pub fn range(&self, g: usize) -> Range<usize> {
        self.offsets[g]..self.offsets[g + 1]
    }

    /// Size of block `g` in elements.
    pub fn size(&self, g: usize) -> usize {
        self.offsets[g + 1] - self.offsets[g]
    }

    /// True iff all blocks have the same size.
    pub fn is_uniform(&self) -> bool {
        let p = self.p();
        (1..p).all(|g| self.size(g) == self.size(0))
    }

    /// Largest block size — the worst-case round payload of Corollary 3.
    pub fn max_block(&self) -> usize {
        (0..self.p()).map(|g| self.size(g)).max().unwrap_or(0)
    }

    /// Total elements of the *circular* block range starting at global
    /// block `start`, spanning `len` blocks (wrapping mod p). This is the
    /// payload size of one schedule transfer.
    pub fn circular_elems(&self, start: usize, len: usize) -> usize {
        let p = self.p();
        assert!(len <= p);
        let end = start + len;
        if end <= p {
            self.offsets[end] - self.offsets[start]
        } else {
            (self.total() - self.offsets[start]) + self.offsets[end - p]
        }
    }

    /// Stable 64-bit fingerprint of the exact block layout (FNV-1a over
    /// the offset vector). Used as the partition component of a
    /// [`crate::schedule::PlanKey`]; two partitions with the same `p` and
    /// per-block counts always agree, and the plan cache verifies the full
    /// layout on every hit so a (astronomically unlikely) collision can
    /// never serve a wrong plan.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &o in &self.offsets {
            for b in (o as u64).to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// The (up to two) contiguous element ranges covering the circular
    /// block range `[start, start+len)` — used by the executor to pack /
    /// combine without materializing a rotated copy (DESIGN.md: global
    /// layout + gather, the datatype-style zero-copy choice of §3).
    ///
    /// `len == 0` (a zero-length transfer, as degenerate/irregular
    /// partitions can produce) yields an empty first range and no second —
    /// consistent with `circular_elems(start, 0) == 0`.
    pub fn circular_ranges(&self, start: usize, len: usize) -> (Range<usize>, Option<Range<usize>>) {
        let p = self.p();
        assert!(start < p && len <= p, "start={start} len={len} p={p}");
        if len == 0 {
            return (self.offsets[start]..self.offsets[start], None);
        }
        let end = start + len;
        if end <= p {
            (self.range(start).start..self.range(start + len - 1).end, None)
        } else {
            let first = self.offsets[start]..self.total();
            let second = 0..self.offsets[end - p];
            (first, if second.is_empty() { None } else { Some(second) })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regular_partition_sizes() {
        let part = BlockPartition::regular(5, 17);
        assert_eq!(part.p(), 5);
        assert_eq!(part.total(), 17);
        let sizes: Vec<usize> = (0..5).map(|g| part.size(g)).collect();
        assert_eq!(sizes, vec![4, 4, 3, 3, 3]);
        assert_eq!(part.range(1), 4..8);
    }

    #[test]
    fn uniform_partition() {
        let part = BlockPartition::uniform(4, 8);
        assert!(part.is_uniform());
        assert_eq!(part.total(), 32);
    }

    #[test]
    fn single_block_is_corollary3_degenerate() {
        let part = BlockPartition::single_block(8, 100, 3);
        assert_eq!(part.size(3), 100);
        assert_eq!(part.total(), 100);
        assert_eq!(part.max_block(), 100);
        for g in 0..8 {
            if g != 3 {
                assert_eq!(part.size(g), 0);
            }
        }
    }

    #[test]
    fn random_partition_totals_and_determinism() {
        for seed in 0..20u64 {
            let a = BlockPartition::random(7, 1000, seed);
            let b = BlockPartition::random(7, 1000, seed);
            assert_eq!(a, b);
            assert_eq!(a.total(), 1000);
            assert_eq!(a.p(), 7);
        }
    }

    #[test]
    fn zipf_partition_skewed() {
        let part = BlockPartition::zipf(16, 16_000, 1.5, 1);
        assert_eq!(part.total(), 16_000);
        assert!(part.max_block() > 16_000 / 16, "should be skewed");
    }

    /// Shared invariants for the irregular generators: exactly `p` blocks,
    /// per-block counts sum to `m` (none negative by construction — the
    /// counts are `usize` and `from_counts` asserts nothing else), and the
    /// layout is fully determined by the seed.
    fn assert_partition_invariants(part: &BlockPartition, p: usize, m: usize, what: &str) {
        assert_eq!(part.p(), p, "{what}: block count");
        assert_eq!(part.total(), m, "{what}: total");
        let sum: usize = (0..p).map(|g| part.size(g)).sum();
        assert_eq!(sum, m, "{what}: counts must sum to m");
        for g in 0..p {
            assert!(part.range(g).start <= part.range(g).end, "{what}: block {g} range");
        }
    }

    #[test]
    fn random_partition_invariants_property() {
        for p in [1usize, 2, 3, 5, 7, 22, 64] {
            for m in [0usize, 1, p / 2, p, 3 * p + 1, 1000] {
                for seed in 0..8u64 {
                    let part = BlockPartition::random(p, m, seed);
                    assert_partition_invariants(&part, p, m, &format!("random p={p} m={m} s={seed}"));
                    assert_eq!(part, BlockPartition::random(p, m, seed), "determinism p={p} m={m}");
                }
            }
        }
    }

    #[test]
    fn zipf_partition_invariants_property() {
        for p in [1usize, 2, 5, 16, 22] {
            for m in [0usize, 1, p, 10 * p, 16_000] {
                for &a in &[0.5f64, 1.0, 1.5] {
                    for seed in 0..4u64 {
                        let part = BlockPartition::zipf(p, m, a, seed);
                        assert_partition_invariants(
                            &part,
                            p,
                            m,
                            &format!("zipf p={p} m={m} a={a} s={seed}"),
                        );
                        assert_eq!(part, BlockPartition::zipf(p, m, a, seed), "determinism");
                    }
                }
            }
        }
    }

    #[test]
    fn distinct_seeds_usually_differ() {
        // Not an invariant, but a sanity check that the seed actually
        // drives the layout: across 10 seeds at p=22, at least two
        // distinct partitions must appear for each generator.
        let rand: std::collections::HashSet<Vec<usize>> = (0..10u64)
            .map(|s| (0..22).map(|g| BlockPartition::random(22, 997, s).size(g)).collect())
            .collect();
        assert!(rand.len() > 1, "random ignores its seed");
        let zipf: std::collections::HashSet<Vec<usize>> = (0..10u64)
            .map(|s| (0..22).map(|g| BlockPartition::zipf(22, 997, 1.3, s).size(g)).collect())
            .collect();
        assert!(zipf.len() > 1, "zipf ignores its seed");
    }

    #[test]
    fn circular_elems_wraps() {
        let part = BlockPartition::from_counts(&[2, 3, 5, 7]); // m=17
        assert_eq!(part.circular_elems(1, 2), 8);
        assert_eq!(part.circular_elems(3, 1), 7);
        assert_eq!(part.circular_elems(3, 2), 9); // 7 + 2 wraps
        assert_eq!(part.circular_elems(2, 4), 17); // all of it
        assert_eq!(part.circular_elems(0, 0), 0);
    }

    #[test]
    fn circular_ranges_split_correctly() {
        let part = BlockPartition::from_counts(&[2, 3, 5, 7]);
        let (a, b) = part.circular_ranges(1, 2);
        assert_eq!(a, 2..10);
        assert!(b.is_none());
        let (a, b) = part.circular_ranges(3, 2);
        assert_eq!(a, 10..17);
        assert_eq!(b, Some(0..2));
        // wrap where the second part would be empty
        let (a, b) = part.circular_ranges(3, 1);
        assert_eq!(a, 10..17);
        assert!(b.is_none());
    }

    #[test]
    fn sums_of_circular_ranges_match_elems() {
        let part = BlockPartition::random(9, 313, 5);
        for start in 0..9 {
            for len in 0..=9 {
                let (a, b) = part.circular_ranges(start, len);
                let n = a.len() + b.map_or(0, |r| r.len());
                assert_eq!(n, part.circular_elems(start, len), "start={start} len={len}");
            }
        }
    }

    #[test]
    fn zero_length_circular_range_is_empty_not_a_panic() {
        // start == 0, len == 0 used to underflow (start + len - 1).
        let part = BlockPartition::from_counts(&[2, 3, 5, 7]);
        for start in 0..4 {
            let (a, b) = part.circular_ranges(start, 0);
            assert!(a.is_empty(), "start={start}");
            assert!(b.is_none(), "start={start}");
            assert_eq!(part.circular_elems(start, 0), 0, "start={start}");
        }
        // Degenerate single-block partitions hit the same path with
        // zero-size blocks on every non-root rank.
        let single = BlockPartition::single_block(5, 40, 2);
        let (a, b) = single.circular_ranges(0, 0);
        assert!(a.is_empty() && b.is_none());
    }
}
