//! The scalar element subsystem: the [`Elem`] trait and the [`DType`]
//! runtime tag.
//!
//! The paper's algorithms are datatype-agnostic — MPI_Reduce_scatter /
//! MPI_Allreduce operate over arbitrary `(datatype, op)` pairs — and so is
//! this reproduction: every layer of the hot path (kernels, transport,
//! executor, communicator) is generic over `T: Elem`, with `f32` as the
//! default type parameter so the original API keeps working unchanged.
//!
//! Why it matters beyond generality: float ⊕ is non-associative, so the
//! commutative skip-order reduction the schedules rely on (paper §2.1)
//! produces results that depend on the application order and can only be
//! compared against an oracle with tolerances (or with carefully
//! range-limited integer-valued floats). The integer dtypes here use
//! **wrapping** arithmetic, which is exactly associative and commutative —
//! giving bit-exact cross-tier and cross-algorithm oracles for every
//! schedule generator (see `rust/tests/dtype_oracles.rs`).
//!
//! Supported dtypes: `f32`, `f64`, `i32`, `i64`, `u64`.

use crate::util::rng::SplitMix64;

/// Runtime tag for a supported element type (the `run.dtype` CLI key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
    I64,
    U64,
}

impl DType {
    /// Every supported dtype, in canonical order.
    pub const ALL: [DType; 5] = [DType::F32, DType::F64, DType::I32, DType::I64, DType::U64];

    /// Human-readable list of valid names (for CLI diagnostics).
    pub const NAMES_HELP: &'static str = "f32|f64|i32|i64|u64";

    /// Canonical name; round-trips through [`DType::parse`].
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::U64 => "u64",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            "i32" => Some(DType::I32),
            "i64" => Some(DType::I64),
            "u64" => Some(DType::U64),
            _ => None,
        }
    }

    /// Element size in bytes (what the transport's copy-volume counters
    /// and the rendezvous descriptors account in).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 | DType::U64 => 8,
        }
    }

    /// Unsigned dtype (test-data generators should avoid negative values)?
    pub fn is_unsigned(self) -> bool {
        matches!(self, DType::U64)
    }
}

/// A scalar element the collectives can reduce: raw-bytes-copyable, with
/// the four native ⊕ operations and their identities.
///
/// Integer implementations use **wrapping** add/mul, so every native ⊕ is
/// exactly associative and commutative — reductions are bit-identical
/// regardless of schedule, tier or association, which is what the exact
/// cross-tier oracle tests lean on. Float implementations use IEEE
/// arithmetic (`min`/`max` propagate the non-NaN operand).
///
/// `from_i64`/`from_usize`/`to_usize` exist for exact small-integer
/// round-trips: deterministic test-vector generation and the framed
/// all-to-all headers (values are small and non-negative by construction).
pub trait Elem:
    Copy + Send + Sync + PartialEq + PartialOrd + std::fmt::Debug + std::fmt::Display + Default + 'static
{
    /// The runtime tag of this type.
    const DTYPE: DType;

    fn add(a: Self, b: Self) -> Self;
    fn mul(a: Self, b: Self) -> Self;
    fn min(a: Self, b: Self) -> Self;
    fn max(a: Self, b: Self) -> Self;

    /// Identity of `add`.
    fn zero() -> Self;
    /// Identity of `mul`.
    fn one() -> Self;
    /// Identity of `min` (+∞ / MAX).
    fn min_identity() -> Self;
    /// Identity of `max` (−∞ / MIN).
    fn max_identity() -> Self;

    /// Exact conversion from a small integer (wraps for out-of-range
    /// unsigned targets — deterministic, used only by test generators).
    fn from_i64(v: i64) -> Self;
    /// Exact conversion from a small non-negative integer (framing headers).
    fn from_usize(v: usize) -> Self;
    /// Inverse of [`from_usize`](Elem::from_usize) for valid headers.
    fn to_usize(self) -> usize;

    /// The PJRT compute-service operator for this dtype, if the AOT Pallas
    /// kernels support it. The artifacts are compiled for `f32` only, so
    /// every other dtype returns `None` and the CLI reports the backend as
    /// unsupported instead of failing opaquely.
    fn service_op(
        handle: crate::runtime::ServiceHandle,
        op: &str,
    ) -> Option<Box<dyn crate::ops::ReduceOp<Self>>> {
        let _ = (handle, op);
        None
    }
}

impl Elem for f32 {
    const DTYPE: DType = DType::F32;

    #[inline(always)]
    fn add(a: Self, b: Self) -> Self {
        a + b
    }
    #[inline(always)]
    fn mul(a: Self, b: Self) -> Self {
        a * b
    }
    #[inline(always)]
    fn min(a: Self, b: Self) -> Self {
        a.min(b)
    }
    #[inline(always)]
    fn max(a: Self, b: Self) -> Self {
        a.max(b)
    }
    fn zero() -> Self {
        0.0
    }
    fn one() -> Self {
        1.0
    }
    fn min_identity() -> Self {
        f32::INFINITY
    }
    fn max_identity() -> Self {
        f32::NEG_INFINITY
    }
    fn from_i64(v: i64) -> Self {
        v as f32
    }
    fn from_usize(v: usize) -> Self {
        v as f32
    }
    fn to_usize(self) -> usize {
        self as usize
    }

    fn service_op(
        handle: crate::runtime::ServiceHandle,
        op: &str,
    ) -> Option<Box<dyn crate::ops::ReduceOp<f32>>> {
        crate::runtime::ServiceOp::new(handle, op)
            .map(|o| Box::new(o) as Box<dyn crate::ops::ReduceOp<f32>>)
    }
}

impl Elem for f64 {
    const DTYPE: DType = DType::F64;

    #[inline(always)]
    fn add(a: Self, b: Self) -> Self {
        a + b
    }
    #[inline(always)]
    fn mul(a: Self, b: Self) -> Self {
        a * b
    }
    #[inline(always)]
    fn min(a: Self, b: Self) -> Self {
        a.min(b)
    }
    #[inline(always)]
    fn max(a: Self, b: Self) -> Self {
        a.max(b)
    }
    fn zero() -> Self {
        0.0
    }
    fn one() -> Self {
        1.0
    }
    fn min_identity() -> Self {
        f64::INFINITY
    }
    fn max_identity() -> Self {
        f64::NEG_INFINITY
    }
    fn from_i64(v: i64) -> Self {
        v as f64
    }
    fn from_usize(v: usize) -> Self {
        v as f64
    }
    fn to_usize(self) -> usize {
        self as usize
    }
}

macro_rules! int_elem {
    ($t:ty, $dt:expr) => {
        impl Elem for $t {
            const DTYPE: DType = $dt;

            #[inline(always)]
            fn add(a: Self, b: Self) -> Self {
                a.wrapping_add(b)
            }
            #[inline(always)]
            fn mul(a: Self, b: Self) -> Self {
                a.wrapping_mul(b)
            }
            #[inline(always)]
            fn min(a: Self, b: Self) -> Self {
                // Spelled out to dodge inherent/Ord/Elem method ambiguity.
                if a < b {
                    a
                } else {
                    b
                }
            }
            #[inline(always)]
            fn max(a: Self, b: Self) -> Self {
                if a > b {
                    a
                } else {
                    b
                }
            }
            fn zero() -> Self {
                0
            }
            fn one() -> Self {
                1
            }
            fn min_identity() -> Self {
                <$t>::MAX
            }
            fn max_identity() -> Self {
                <$t>::MIN
            }
            fn from_i64(v: i64) -> Self {
                v as $t
            }
            fn from_usize(v: usize) -> Self {
                v as $t
            }
            fn to_usize(self) -> usize {
                self as usize
            }
        }
    };
}

int_elem!(i32, DType::I32);
int_elem!(i64, DType::I64);
int_elem!(u64, DType::U64);

/// Deterministic vector of small-integer-valued elements in `[lo, hi)` —
/// the generic analogue of `SplitMix64::int_valued_vec`, exact in every
/// dtype. For unsigned dtypes pass `lo >= 0` (negative values wrap —
/// deterministic and bit-exact, but surprising in human-facing output).
pub fn int_vec<T: Elem>(rng: &mut SplitMix64, n: usize, lo: i64, hi: i64) -> Vec<T> {
    assert!(hi > lo);
    let span = (hi - lo) as usize;
    (0..n).map(|_| T::from_i64(lo + rng.next_below(span) as i64)).collect()
}

/// `[lo, hi)` bounds appropriate for exact test data in dtype `dt`
/// (non-negative for unsigned dtypes).
pub fn test_value_bounds(dt: DType) -> (i64, i64) {
    if dt.is_unsigned() {
        (0, 9)
    } else {
        (-8, 9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_names_roundtrip() {
        for dt in DType::ALL {
            assert_eq!(DType::parse(dt.name()), Some(dt), "{dt:?}");
        }
        assert_eq!(DType::parse("f16"), None);
        assert_eq!(DType::parse(""), None);
    }

    #[test]
    fn dtype_sizes_match_mem() {
        assert_eq!(DType::F32.size_bytes(), std::mem::size_of::<f32>());
        assert_eq!(DType::F64.size_bytes(), std::mem::size_of::<f64>());
        assert_eq!(DType::I32.size_bytes(), std::mem::size_of::<i32>());
        assert_eq!(DType::I64.size_bytes(), std::mem::size_of::<i64>());
        assert_eq!(DType::U64.size_bytes(), std::mem::size_of::<u64>());
    }

    fn identities_hold<T: Elem>() {
        let vals: Vec<T> = (-3..4).map(T::from_i64).collect();
        for &v in &vals {
            assert_eq!(T::add(v, T::zero()), v);
            assert_eq!(T::mul(v, T::one()), v);
            assert_eq!(T::min(v, T::min_identity()), v);
            assert_eq!(T::max(v, T::max_identity()), v);
        }
    }

    #[test]
    fn identities_hold_all_dtypes() {
        identities_hold::<f32>();
        identities_hold::<f64>();
        identities_hold::<i32>();
        identities_hold::<i64>();
        // unsigned: negative from_i64 wraps, but identities still hold
        identities_hold::<u64>();
    }

    fn commutative_assoc_ints<T: Elem>() {
        let mut rng = SplitMix64::new(5);
        let xs: Vec<T> = int_vec(&mut rng, 64, -100, 100);
        for w in xs.chunks_exact(3) {
            let (a, b, c) = (w[0], w[1], w[2]);
            assert_eq!(T::add(a, b), T::add(b, a));
            assert_eq!(T::mul(a, b), T::mul(b, a));
            assert_eq!(T::add(T::add(a, b), c), T::add(a, T::add(b, c)));
            assert_eq!(T::mul(T::mul(a, b), c), T::mul(a, T::mul(b, c)));
            assert_eq!(T::min(a, b), T::min(b, a));
            assert_eq!(T::max(a, b), T::max(b, a));
        }
    }

    #[test]
    fn integer_ops_exactly_associative_and_commutative() {
        commutative_assoc_ints::<i32>();
        commutative_assoc_ints::<i64>();
        commutative_assoc_ints::<u64>();
    }

    #[test]
    fn wrapping_sum_never_panics() {
        // Debug builds panic on plain +-overflow; Elem::add must not.
        assert_eq!(i64::MAX.wrapping_add(1), i64::MIN);
        assert_eq!(<i64 as Elem>::add(i64::MAX, 1), i64::MIN);
        assert_eq!(<u64 as Elem>::add(u64::MAX, 1), 0);
        assert_eq!(<i32 as Elem>::mul(i32::MAX, 2), -2);
    }

    #[test]
    fn int_vec_deterministic_and_bounded() {
        let mut a = SplitMix64::new(9);
        let mut b = SplitMix64::new(9);
        let va: Vec<i64> = int_vec(&mut a, 500, -8, 9);
        let vb: Vec<i64> = int_vec(&mut b, 500, -8, 9);
        assert_eq!(va, vb);
        assert!(va.iter().all(|&x| (-8..9).contains(&x)));
        // agrees elementwise with the f32 generator (same rng stream)
        let mut c = SplitMix64::new(9);
        let vf: Vec<f32> = int_vec(&mut c, 500, -8, 9);
        for (x, y) in va.iter().zip(&vf) {
            assert_eq!(*x as f32, *y);
        }
    }

    #[test]
    fn usize_roundtrip_for_headers() {
        for v in [0usize, 1, 7, 1000, 123_456] {
            assert_eq!(f32::from_usize(v).to_usize(), v);
            assert_eq!(f64::from_usize(v).to_usize(), v);
            assert_eq!(i32::from_usize(v).to_usize(), v);
            assert_eq!(i64::from_usize(v).to_usize(), v);
            assert_eq!(u64::from_usize(v).to_usize(), v);
        }
    }
}
