//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repetition + summary for closures, wall-clock helpers
//! for the thread-network collectives, and consistent table output so each
//! bench binary regenerates one table/figure of EXPERIMENTS.md.

use std::time::Instant;

use crate::util::stats::Summary;

/// Benchmark a closure: `warmup` untimed runs, then `reps` timed runs.
/// Returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Benchmark with an adaptive inner loop so very fast closures get
/// aggregated timing: runs the closure in batches until one batch exceeds
/// `min_batch_seconds`, then reports per-iteration time for `reps` batches.
pub fn time_adaptive<F: FnMut()>(min_batch_seconds: f64, reps: usize, mut f: F) -> Summary {
    // calibrate batch size
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_batch_seconds || batch >= 1 << 24 {
            break;
        }
        batch *= 2;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    Summary::of(&samples)
}

/// Standard bench header so outputs are self-describing in the logs.
pub fn bench_header(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
    println!("(harness: in-tree, median-of-reps; see rust/src/bench_harness)");
}

/// Environment knob: `CCOLL_BENCH_FAST=1` shrinks sweeps for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("CCOLL_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let v = time_reps(2, 5, || n += 1);
        assert_eq!(v.len(), 5);
        assert_eq!(n, 7);
        assert!(v.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn adaptive_reports_sane_times() {
        let s = time_adaptive(0.001, 3, || { std::hint::black_box(1 + 1); });
        assert!(s.median > 0.0 && s.median < 1e-3);
    }
}
