//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repetition + summary for closures, wall-clock helpers
//! for the thread-network collectives, consistent table output so each
//! bench binary regenerates one table/figure of EXPERIMENTS.md, and a
//! machine-readable [`BenchReport`] (`BENCH_<name>.json`) so the perf
//! trajectory is tracked across PRs instead of living only in logs.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Accumulates key → value results for one bench binary and persists them
/// as `BENCH_<name>.json` (flat-ish JSON: numbers, strings, arrays) in
/// `CCOLL_BENCH_JSON_DIR` (default: the working directory — note cargo
/// runs bench binaries with cwd set to the *package* root, `rust/`, so CI
/// pins the env var to the workspace root). CI and cross-PR tooling diff
/// these files; keep keys stable.
pub struct BenchReport {
    name: String,
    obj: BTreeMap<String, Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Num(1.0));
        obj.insert("bench".to_string(), Json::Str(name.to_string()));
        obj.insert("fast_mode".to_string(), Json::Bool(fast_mode()));
        // Every report records its element type. Benches that honor
        // CCOLL_BENCH_DTYPE overwrite this with the dtype they actually
        // ran (`report.str("dtype", ...)`); f32-only benches keep the
        // default so the field is never a lie.
        obj.insert("dtype".to_string(), Json::Str("f32".to_string()));
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        obj.insert("unix_time".to_string(), Json::Num(unix));
        Self { name: name.to_string(), obj }
    }

    /// Set an arbitrary JSON value.
    pub fn set(&mut self, key: &str, v: Json) {
        self.obj.insert(key.to_string(), v);
    }

    pub fn num(&mut self, key: &str, v: f64) {
        self.set(key, Json::Num(v));
    }

    pub fn str(&mut self, key: &str, v: &str) {
        self.set(key, Json::Str(v.to_string()));
    }

    /// Set an array of numbers (sweep axes and per-point results).
    pub fn nums<I: IntoIterator<Item = f64>>(&mut self, key: &str, vs: I) {
        self.set(key, Json::Arr(vs.into_iter().map(Json::Num).collect()));
    }

    /// The report as a JSON value (what [`write`](BenchReport::write)
    /// persists).
    pub fn to_json(&self) -> Json {
        Json::Obj(self.obj.clone())
    }

    /// Write `BENCH_<name>.json`, returning its path. Failures are
    /// reported, not fatal — a read-only working directory must not fail
    /// the bench itself.
    pub fn write(&self) -> Option<std::path::PathBuf> {
        let dir = std::env::var("CCOLL_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        let path = std::path::Path::new(&dir).join(format!("BENCH_{}.json", self.name));
        let text = Json::Obj(self.obj.clone()).render();
        match std::fs::write(&path, text + "\n") {
            Ok(()) => {
                println!("[bench json] wrote {}", path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("[bench json] could not write {}: {e}", path.display());
                None
            }
        }
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `reps` timed runs.
/// Returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Benchmark with an adaptive inner loop so very fast closures get
/// aggregated timing: runs the closure in batches until one batch exceeds
/// `min_batch_seconds`, then reports per-iteration time for `reps` batches.
pub fn time_adaptive<F: FnMut()>(min_batch_seconds: f64, reps: usize, mut f: F) -> Summary {
    // calibrate batch size
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_batch_seconds || batch >= 1 << 24 {
            break;
        }
        batch *= 2;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    Summary::of(&samples)
}

/// Achieved bandwidth in GiB/s for `bytes` moved in `secs` — the
/// machine-readable headline number the large-message tier is judged by
/// (recorded by T1/T2/T10 alongside latency). Zero when `secs` is not
/// positive, so a degenerate timing can never report infinite bandwidth.
pub fn gib_per_sec(bytes: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 / secs / (1024.0 * 1024.0 * 1024.0)
}

/// Standard bench header so outputs are self-describing in the logs.
pub fn bench_header(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
    println!("(harness: in-tree, median-of-reps; see rust/src/bench_harness)");
}

/// Environment knob: `CCOLL_BENCH_FAST=1` shrinks sweeps for smoke runs.
/// Parsed once per process by [`crate::env_knobs`] (malformed values
/// abort loudly instead of silently meaning "off").
pub fn fast_mode() -> bool {
    crate::env_knobs::knobs().bench_fast
}

/// Environment knob: `CCOLL_BENCH_DTYPE` selects the element type the
/// dtype-aware benches (T1/T2) run in (default f32; see
/// [`crate::env_knobs`]).
pub fn bench_dtype() -> crate::datatypes::DType {
    crate::env_knobs::knobs().bench_dtype
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let v = time_reps(2, 5, || n += 1);
        assert_eq!(v.len(), 5);
        assert_eq!(n, 7);
        assert!(v.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn adaptive_reports_sane_times() {
        let s = time_adaptive(0.001, 3, || { std::hint::black_box(1 + 1); });
        assert!(s.median > 0.0 && s.median < 1e-3);
    }

    #[test]
    fn gib_per_sec_is_exact_and_degenerate_safe() {
        assert_eq!(gib_per_sec(1 << 30, 1.0), 1.0);
        assert_eq!(gib_per_sec(1 << 31, 0.5), 4.0);
        assert_eq!(gib_per_sec(1 << 30, 0.0), 0.0);
        assert_eq!(gib_per_sec(0, 1.0), 0.0);
    }

    #[test]
    fn bench_report_round_trips_through_json() {
        let mut r = BenchReport::new("unit");
        r.num("elems_per_sec", 1.5e9);
        r.str("winner", "rendezvous");
        r.nums("sweep_p", [2.0, 4.0, 8.0]);
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.req("bench").as_str(), Some("unit"));
        assert_eq!(parsed.req("schema").as_usize(), Some(1));
        assert_eq!(parsed.req("elems_per_sec").as_f64(), Some(1.5e9));
        assert_eq!(parsed.req("sweep_p").as_arr().unwrap().len(), 3);
    }
}
