//! `ccoll` — launcher binary for the circulant-collectives library.
//! See `ccoll help` and DESIGN.md.

use circulant_collectives::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cli::main_with_args(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
