//! Monomorphized, vectorization-friendly reduction kernels, generic over
//! the element type.
//!
//! The four native operators share one inner-loop shape, instantiated per
//! `(operator, dtype)` pair through the zero-sized [`MicroOp`] types below
//! — `rustc` monomorphizes [`Kernel`]'s generic methods so the hot loops
//! contain *no* indirect (`dyn`) call, and the executor pays at most one
//! enum `match` per payload instead of one virtual call per slice. The
//! scalar ⊕ itself comes from [`Elem`] (wrapping arithmetic for integer
//! dtypes — exactly associative, the basis of the bit-exact oracles).
//!
//! Loop discipline (the §Perf "fast single pass" the rendezvous path
//! depends on):
//!   * **cache-blocked** — operands are walked in [`BLOCK`]-element tiles
//!     (16 KiB at 4 bytes/elem, 32 KiB at 8 — L1-resident either way) so
//!     the in-place and out-of-place variants have identical locality
//!     behavior on multi-slice ranges;
//!   * **unrolled** — each tile is processed in [`LANES`]-wide groups via
//!     `chunks_exact`, which LLVM reliably turns into packed SIMD plus an
//!     unrolled scalar tail;
//!   * **unchecked** — operand lengths are validated once per payload by
//!     the executor (`CollectiveError::BadPayload`), not per kernel call;
//!     kernels only `debug_assert!` the contract (see `ops::ReduceOp`).

use std::ops::Range;

use crate::datatypes::Elem;

/// Elements per cache tile (16 KiB for 4-byte, 32 KiB for 8-byte elements
/// — L1-sized either way).
const BLOCK: usize = 4096;
/// Unroll width of the inner loop (two AVX2 vectors of f32; one of f64).
const LANES: usize = 16;

/// One scalar application of ⊕ — the only thing that differs between
/// operators. Zero-sized marker types implement it so every loop below is
/// monomorphized per `(operator, dtype)`.
trait MicroOp: Copy {
    fn apply<T: Elem>(a: T, b: T) -> T;
}

#[derive(Clone, Copy)]
struct SumMicro;
impl MicroOp for SumMicro {
    #[inline(always)]
    fn apply<T: Elem>(a: T, b: T) -> T {
        T::add(a, b)
    }
}

#[derive(Clone, Copy)]
struct ProdMicro;
impl MicroOp for ProdMicro {
    #[inline(always)]
    fn apply<T: Elem>(a: T, b: T) -> T {
        T::mul(a, b)
    }
}

#[derive(Clone, Copy)]
struct MinMicro;
impl MicroOp for MinMicro {
    #[inline(always)]
    fn apply<T: Elem>(a: T, b: T) -> T {
        T::min(a, b)
    }
}

#[derive(Clone, Copy)]
struct MaxMicro;
impl MicroOp for MaxMicro {
    #[inline(always)]
    fn apply<T: Elem>(a: T, b: T) -> T {
        T::max(a, b)
    }
}

/// In-place fold: `acc[i] ← acc[i] ⊕ other[i]`.
#[inline]
fn fold<T: Elem, O: MicroOp>(acc: &mut [T], other: &[T]) {
    debug_assert_eq!(acc.len(), other.len(), "⊕ operands must have equal length");
    for (at, bt) in acc.chunks_mut(BLOCK).zip(other.chunks(BLOCK)) {
        let mut ac = at.chunks_exact_mut(LANES);
        let mut bc = bt.chunks_exact(LANES);
        for (a, b) in ac.by_ref().zip(bc.by_ref()) {
            for i in 0..LANES {
                a[i] = O::apply(a[i], b[i]);
            }
        }
        for (a, b) in ac.into_remainder().iter_mut().zip(bc.remainder()) {
            *a = O::apply(*a, *b);
        }
    }
}

/// Out-of-place fold: `dst[i] ← a[i] ⊕ b[i]` — one fused pass instead of
/// copy-then-combine.
#[inline]
fn fold_into<T: Elem, O: MicroOp>(dst: &mut [T], a: &[T], b: &[T]) {
    debug_assert_eq!(dst.len(), a.len(), "⊕ operands must have equal length");
    debug_assert_eq!(dst.len(), b.len(), "⊕ operands must have equal length");
    for ((dt, at), bt) in dst.chunks_mut(BLOCK).zip(a.chunks(BLOCK)).zip(b.chunks(BLOCK)) {
        let mut dc = dt.chunks_exact_mut(LANES);
        let mut ac = at.chunks_exact(LANES);
        let mut bc = bt.chunks_exact(LANES);
        for ((d, x), y) in dc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
            for i in 0..LANES {
                d[i] = O::apply(x[i], y[i]);
            }
        }
        for ((d, x), y) in
            dc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder())
        {
            *d = O::apply(*x, *y);
        }
    }
}

/// Fold a payload split into (head, tail) source slices into the matching
/// (head, tail) destination slices — the split circular-range shape of
/// every schedule transfer — with ONE monomorphized instantiation covering
/// both legs (a single dispatch per payload).
#[inline]
fn fold_ranges<T: Elem, O: MicroOp>(
    dst_head: &mut [T],
    dst_tail: Option<&mut [T]>,
    src_head: &[T],
    src_tail: &[T],
) {
    fold::<T, O>(dst_head, src_head);
    if let Some(dst_tail) = dst_tail {
        fold::<T, O>(dst_tail, src_tail);
    }
}

/// The four native operators as a copyable value — the executor resolves a
/// `dyn ReduceOp` to a `Kernel` once per collective (`ReduceOp::kernel`)
/// and from then on pays a predictable enum branch instead of a virtual
/// call per slice. The variant is dtype-independent; each generic method
/// monomorphizes per element type at the call site, so one `Kernel` value
/// serves every dtype.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    Sum,
    Prod,
    Min,
    Max,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Sum => "sum",
            Kernel::Prod => "prod",
            Kernel::Min => "min",
            Kernel::Max => "max",
        }
    }

    /// Identity element of ⊕ in dtype `T`.
    pub fn identity<T: Elem>(self) -> T {
        match self {
            Kernel::Sum => T::zero(),
            Kernel::Prod => T::one(),
            Kernel::Min => T::min_identity(),
            Kernel::Max => T::max_identity(),
        }
    }

    /// `acc[i] ← acc[i] ⊕ other[i]` (equal lengths; checked in debug only).
    #[inline]
    pub fn combine<T: Elem>(self, acc: &mut [T], other: &[T]) {
        match self {
            Kernel::Sum => fold::<T, SumMicro>(acc, other),
            Kernel::Prod => fold::<T, ProdMicro>(acc, other),
            Kernel::Min => fold::<T, MinMicro>(acc, other),
            Kernel::Max => fold::<T, MaxMicro>(acc, other),
        }
    }

    /// `dst[i] ← a[i] ⊕ b[i]` — out-of-place fused pass.
    #[inline]
    pub fn combine_into<T: Elem>(self, dst: &mut [T], a: &[T], b: &[T]) {
        match self {
            Kernel::Sum => fold_into::<T, SumMicro>(dst, a, b),
            Kernel::Prod => fold_into::<T, ProdMicro>(dst, a, b),
            Kernel::Min => fold_into::<T, MinMicro>(dst, a, b),
            Kernel::Max => fold_into::<T, MaxMicro>(dst, a, b),
        }
    }

    /// Combine a (head, tail)-split payload into the matching split
    /// destination slices: `dst_head ⊕= src_head; dst_tail ⊕= src_tail`.
    /// This is the executor's receive hot path for a circular block range,
    /// fused into one dispatch. The destinations are separate `&mut`
    /// slices (not a buffer + ranges) so the executor can carve them from
    /// a raw base pointer without ever forming a `&mut` over regions a
    /// rendezvous peer is concurrently reading.
    #[inline]
    pub fn combine_ranges<T: Elem>(
        self,
        dst_head: &mut [T],
        dst_tail: Option<&mut [T]>,
        src_head: &[T],
        src_tail: &[T],
    ) {
        match self {
            Kernel::Sum => fold_ranges::<T, SumMicro>(dst_head, dst_tail, src_head, src_tail),
            Kernel::Prod => fold_ranges::<T, ProdMicro>(dst_head, dst_tail, src_head, src_tail),
            Kernel::Min => fold_ranges::<T, MinMicro>(dst_head, dst_tail, src_head, src_tail),
            Kernel::Max => fold_ranges::<T, MaxMicro>(dst_head, dst_tail, src_head, src_tail),
        }
    }
}

// ---------------------------------------------------------------------
// Fused-batch pack/scatter kernels (the engine's fusion tier)
// ---------------------------------------------------------------------

/// One copy directive of a fused-batch layout: the *member-local* element
/// range and the offset where those elements live in the fused vector.
/// A member participating in a fused collective over `p` ranks has one
/// span per owner block (its block `g` lands inside fused block `g`), so
/// the engine's `FusedLayout` holds `p` spans per member and the spans of
/// all members tile the fused vector exactly once.
pub type SegmentSpan = (Range<usize>, usize);

/// Strided gather of one member's input into the fused vector:
/// `fused[dst .. dst + src.len()] ← member[src]` for every span. Spans
/// with empty source ranges (zero-size blocks, zero-length member ops)
/// copy nothing — the empty-payload audit holds through packing.
#[inline]
pub fn pack_segments<T: Elem>(fused: &mut [T], member: &[T], spans: &[SegmentSpan]) {
    for (src, dst) in spans {
        debug_assert!(src.end <= member.len(), "pack span {src:?} out of member bounds");
        fused[*dst..*dst + src.len()].copy_from_slice(&member[src.clone()]);
    }
}

/// Exact inverse of [`pack_segments`] for the spans given: scatter the
/// fused result segments back into the member's buffer with per-op
/// offsets — `member[src] ← fused[dst .. dst + src.len()]`. A fused
/// allreduce scatters every span (the full member vector); a fused
/// reduce-scatter scatters only the member's owned-block span at each
/// rank.
#[inline]
pub fn scatter_segments<T: Elem>(member: &mut [T], fused: &[T], spans: &[SegmentSpan]) {
    for (src, dst) in spans {
        debug_assert!(src.end <= member.len(), "scatter span {src:?} out of member bounds");
        member[src.clone()].copy_from_slice(&fused[*dst..*dst + src.len()]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datatypes::elem::int_vec;
    use crate::util::rng::SplitMix64;

    fn scalar(k: Kernel, a: f32, b: f32) -> f32 {
        match k {
            Kernel::Sum => a + b,
            Kernel::Prod => a * b,
            Kernel::Min => a.min(b),
            Kernel::Max => a.max(b),
        }
    }

    const ALL: [Kernel; 4] = [Kernel::Sum, Kernel::Prod, Kernel::Min, Kernel::Max];

    /// Lengths that exercise the empty, sub-lane, lane-remainder and
    /// multi-tile paths of the blocked/unrolled loops.
    const LENS: [usize; 8] = [0, 1, 15, 16, 17, 255, 4096, 4096 + 33];

    #[test]
    fn combine_matches_scalar_fold_all_kernels_all_shapes() {
        let mut rng = SplitMix64::new(21);
        for k in ALL {
            for n in LENS {
                let a0 = rng.normal_vec(n);
                let b = rng.normal_vec(n);
                let mut acc = a0.clone();
                k.combine(&mut acc, &b);
                for i in 0..n {
                    assert_eq!(acc[i], scalar(k, a0[i], b[i]), "{} n={n} i={i}", k.name());
                }
            }
        }
    }

    #[test]
    fn combine_into_is_copy_then_combine() {
        let mut rng = SplitMix64::new(22);
        for k in ALL {
            for n in LENS {
                let a = rng.normal_vec(n);
                let b = rng.normal_vec(n);
                let mut dst = vec![f32::NAN; n];
                k.combine_into(&mut dst, &a, &b);
                let mut want = a.clone();
                k.combine(&mut want, &b);
                assert_eq!(dst, want, "{} n={n}", k.name());
            }
        }
    }

    #[test]
    fn combine_ranges_covers_split_payloads() {
        let mut rng = SplitMix64::new(23);
        for k in ALL {
            let base = rng.normal_vec(100);
            let src = rng.normal_vec(100);
            // head = 60..100, tail = 0..25 (a wrapped circular range)
            let mut buf = base.clone();
            {
                let (lo, hi) = buf.split_at_mut(60);
                k.combine_ranges(hi, Some(&mut lo[0..25]), &src[..40], &src[40..65]);
            }
            let mut want = base.clone();
            k.combine(&mut want[60..100], &src[..40]);
            k.combine(&mut want[0..25], &src[40..65]);
            assert_eq!(buf, want, "{}", k.name());
            // no tail
            let mut buf = base.clone();
            k.combine_ranges(&mut buf[10..30], None, &src[..20], &[]);
            let mut want = base.clone();
            k.combine(&mut want[10..30], &src[..20]);
            assert_eq!(buf, want, "{}", k.name());
        }
    }

    #[test]
    fn identities_match_ops() {
        for k in ALL {
            let mut acc = vec![k.identity(); 33];
            let data: Vec<f32> = (0..33).map(|i| i as f32 - 16.0).collect();
            k.combine(&mut acc, &data);
            assert_eq!(acc, data, "{} identity not neutral", k.name());
        }
    }

    #[test]
    fn names_and_identities_are_distinct() {
        for (i, a) in ALL.iter().enumerate() {
            for b in ALL.iter().skip(i + 1) {
                assert_ne!(a.name(), b.name());
            }
        }
    }

    /// Generic cross-dtype check: every kernel, every loop shape, against
    /// a scalar wrapping fold in dtype `T` — exact equality.
    fn combine_matches_scalar_generic<T: Elem>(seed: u64) {
        let mut rng = SplitMix64::new(seed);
        for k in ALL {
            for n in LENS {
                let a0: Vec<T> = int_vec(&mut rng, n, -50, 50);
                let b: Vec<T> = int_vec(&mut rng, n, -50, 50);
                let mut acc = a0.clone();
                k.combine(&mut acc, &b);
                for i in 0..n {
                    let want = match k {
                        Kernel::Sum => T::add(a0[i], b[i]),
                        Kernel::Prod => T::mul(a0[i], b[i]),
                        Kernel::Min => T::min(a0[i], b[i]),
                        Kernel::Max => T::max(a0[i], b[i]),
                    };
                    assert_eq!(acc[i], want, "{} {:?} n={n} i={i}", k.name(), T::DTYPE);
                }
                // identity neutrality in T
                let mut idacc = vec![k.identity::<T>(); n];
                k.combine(&mut idacc, &a0);
                assert_eq!(idacc, a0, "{} {:?} identity", k.name(), T::DTYPE);
            }
        }
    }

    #[test]
    fn kernels_exact_in_every_dtype() {
        combine_matches_scalar_generic::<f32>(31);
        combine_matches_scalar_generic::<f64>(32);
        combine_matches_scalar_generic::<i32>(33);
        combine_matches_scalar_generic::<i64>(34);
        combine_matches_scalar_generic::<u64>(35);
    }

    #[test]
    fn combine_into_matches_in_place_i64() {
        let mut rng = SplitMix64::new(36);
        for k in ALL {
            let a: Vec<i64> = int_vec(&mut rng, 97, -9, 9);
            let b: Vec<i64> = int_vec(&mut rng, 97, -9, 9);
            let mut dst = vec![0i64; 97];
            k.combine_into(&mut dst, &a, &b);
            let mut want = a.clone();
            k.combine(&mut want, &b);
            assert_eq!(dst, want, "{}", k.name());
        }
    }

    /// Hand-build the fused block-major layout for members with regular
    /// partitions — the same geometry `engine::fusion::FusedLayout`
    /// derives — so the kernels are testable in isolation.
    fn block_major_spans(lens: &[usize], p: usize) -> (Vec<Vec<SegmentSpan>>, usize) {
        let parts: Vec<crate::datatypes::BlockPartition> =
            lens.iter().map(|&m| crate::datatypes::BlockPartition::regular(p, m)).collect();
        let total: usize = lens.iter().sum();
        let mut spans: Vec<Vec<SegmentSpan>> = vec![Vec::with_capacity(p); lens.len()];
        let mut cursor = 0usize;
        for g in 0..p {
            for (j, part) in parts.iter().enumerate() {
                spans[j].push((part.range(g), cursor));
                cursor += part.size(g);
            }
        }
        assert_eq!(cursor, total);
        (spans, total)
    }

    #[test]
    fn pack_then_scatter_is_identity_mixed_lengths() {
        // Three members of mixed lengths, including a zero-length one:
        // pack tiles the fused vector exactly, scatter inverts exactly.
        let mut rng = SplitMix64::new(40);
        let p = 4;
        let lens = [13usize, 0, 7];
        let (spans, total) = block_major_spans(&lens, p);
        let members: Vec<Vec<i64>> =
            lens.iter().map(|&m| int_vec(&mut rng, m, -99, 99)).collect();
        let mut fused = vec![i64::MIN; total];
        for (j, m) in members.iter().enumerate() {
            pack_segments(&mut fused, m, &spans[j]);
        }
        assert!(!fused.contains(&i64::MIN), "pack must cover the whole fused vector");
        for (j, m) in members.iter().enumerate() {
            let mut back = vec![0i64; m.len()];
            scatter_segments(&mut back, &fused, &spans[j]);
            assert_eq!(&back, m, "member {j} did not round-trip");
        }
    }

    #[test]
    fn combine_over_fused_segments_matches_per_member_combines() {
        // ⊕ applied to the packed fused vectors equals ⊕ applied to each
        // member separately — the algebraic fact the fusion tier rests on.
        let mut rng = SplitMix64::new(41);
        let p = 3;
        let lens = [9usize, 4, 11];
        let (spans, total) = block_major_spans(&lens, p);
        for k in ALL {
            let a: Vec<Vec<i64>> = lens.iter().map(|&m| int_vec(&mut rng, m, -9, 9)).collect();
            let b: Vec<Vec<i64>> = lens.iter().map(|&m| int_vec(&mut rng, m, -9, 9)).collect();
            let pack = |ms: &[Vec<i64>]| {
                let mut fused = vec![0i64; total];
                for (j, m) in ms.iter().enumerate() {
                    pack_segments(&mut fused, m, &spans[j]);
                }
                fused
            };
            let mut fused = pack(&a);
            k.combine(&mut fused, &pack(&b));
            for (j, (av, bv)) in a.iter().zip(&b).enumerate() {
                let mut want = av.clone();
                k.combine(&mut want, bv);
                let mut got = vec![0i64; want.len()];
                scatter_segments(&mut got, &fused, &spans[j]);
                assert_eq!(got, want, "{} member {j}", k.name());
            }
        }
    }

    #[test]
    fn scatter_of_single_span_touches_only_that_range() {
        // The fused reduce-scatter path scatters one owned-block span;
        // every other element of the member buffer must stay untouched.
        let p = 4;
        let lens = [10usize];
        let (spans, total) = block_major_spans(&lens, p);
        let fused: Vec<i64> = (0..total as i64).collect();
        let mut member = vec![-1i64; 10];
        let rank = 2;
        scatter_segments(&mut member, &fused, &spans[0][rank..rank + 1]);
        let (src, dst) = &spans[0][rank];
        for (i, &v) in member.iter().enumerate() {
            if src.contains(&i) {
                assert_eq!(v, fused[dst + (i - src.start)]);
            } else {
                assert_eq!(v, -1, "element {i} outside the span was written");
            }
        }
    }
}
