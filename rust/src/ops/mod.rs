//! Commutative reduction operators (the paper's ⊕).
//!
//! Two families implement [`ReduceOp`]:
//!   * native Rust loops ([`native`]) — the default γ backend, written so
//!     LLVM autovectorizes them;
//!   * the PJRT-backed operator in `crate::runtime::PjrtOp`, which executes
//!     the AOT-compiled Pallas combine kernel (Layer 1) — the three-layer
//!     hot path.
//!
//! Both are validated against each other and against scalar folds in
//! `rust/tests/`.

pub mod kernels;
pub mod native;

pub use kernels::Kernel;
pub use native::{MaxOp, MinOp, NativeOp, ProdOp, SumOp};

use std::sync::atomic::{AtomicU64, Ordering};

/// A binary, commutative, associative elementwise operator on f32 blocks.
///
/// `combine` computes `acc[i] ← acc[i] ⊕ other[i]`. Implementations must be
/// commutative — Algorithm 1 applies ⊕ in skip order, not rank order
/// (paper §2.1).
///
/// # Length contract
///
/// Operand slices must have equal length. The *executor* enforces this
/// once per received payload (`CollectiveError::BadPayload`) before any
/// kernel call, so implementations stay on the unchecked fast path and
/// only `debug_assert!` the contract — a release-mode mismatch through
/// some other caller is a bug at that call site, not in the kernel.
pub trait ReduceOp: Send + Sync {
    /// Stable name (matches the artifact manifest's `op` field).
    fn name(&self) -> &'static str;

    /// `acc ⊕= other` (slices must have equal length — see the trait docs).
    fn combine(&self, acc: &mut [f32], other: &[f32]);

    /// Out-of-place fused pass: `dst[i] ← a[i] ⊕ b[i]` (all three slices
    /// equal length). Default is copy-then-combine; native operators
    /// override with a single fused loop. Not yet on the executor's hot
    /// path (which is in-place); provided as the kernel-layer building
    /// block for out-of-place consumers (e.g. a future fused
    /// staging+combine in the communicator).
    fn combine_into(&self, dst: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(dst.len(), a.len(), "⊕ operands must have equal length");
        dst.copy_from_slice(a);
        self.combine(dst, b);
    }

    /// The monomorphized [`Kernel`] implementing this operator, if it is
    /// one of the four native ops. The executor resolves this once per
    /// collective and then skips dyn dispatch entirely on the combine hot
    /// path. Instrumentation wrappers (e.g. [`CountingOp`]) and backend
    /// operators (PJRT) return `None` so every combine still flows through
    /// their `combine`.
    fn kernel(&self) -> Option<Kernel> {
        None
    }

    /// Identity element (e.g. 0 for sum, +∞ for min) — used to initialize
    /// empty accumulations and pad PJRT buckets.
    fn identity(&self) -> f32;
}

/// Parse an operator name (CLI/config) into a boxed native operator.
pub fn parse_native(name: &str) -> Option<Box<dyn ReduceOp>> {
    match name {
        "sum" => Some(Box::new(SumOp)),
        "prod" => Some(Box::new(ProdOp)),
        "min" => Some(Box::new(MinOp)),
        "max" => Some(Box::new(MaxOp)),
        _ => None,
    }
}

/// Instrumentation wrapper: counts invocations and combined elements.
/// The T1/T2 benches use this to report the exact ⊕ counts of
/// Theorems 1 and 2.
pub struct CountingOp<'a> {
    pub inner: &'a dyn ReduceOp,
    pub calls: AtomicU64,
    pub elems: AtomicU64,
}

impl<'a> CountingOp<'a> {
    pub fn new(inner: &'a dyn ReduceOp) -> Self {
        Self { inner, calls: AtomicU64::new(0), elems: AtomicU64::new(0) }
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn elems(&self) -> u64 {
        self.elems.load(Ordering::Relaxed)
    }
}

impl<'a> ReduceOp for CountingOp<'a> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.elems.fetch_add(acc.len() as u64, Ordering::Relaxed);
        self.inner.combine(acc, other);
    }

    fn identity(&self) -> f32 {
        self.inner.identity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_known_ops() {
        for name in ["sum", "prod", "min", "max"] {
            assert_eq!(parse_native(name).unwrap().name(), name);
        }
        assert!(parse_native("xor").is_none());
    }

    #[test]
    fn counting_op_counts() {
        let sum = SumOp;
        let c = CountingOp::new(&sum);
        let mut a = vec![1.0f32; 10];
        c.combine(&mut a, &vec![2.0f32; 10]);
        c.combine(&mut a[..5], &vec![3.0f32; 5]);
        assert_eq!(c.calls(), 2);
        assert_eq!(c.elems(), 15);
        assert_eq!(a[0], 6.0);
        assert_eq!(a[9], 3.0);
    }
}
