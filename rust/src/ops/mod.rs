//! Commutative reduction operators (the paper's ⊕), generic over the
//! element type.
//!
//! Two families implement [`ReduceOp`]:
//!   * native Rust loops ([`native`]) — the default γ backend, written so
//!     LLVM autovectorizes them; implemented for **every** [`Elem`] dtype
//!     (`f32`, `f64`, `i32`, `i64`, `u64` — integer ⊕ is wrapping, hence
//!     exactly associative);
//!   * the PJRT-backed operator in `crate::runtime::PjrtOp`, which executes
//!     the AOT-compiled Pallas combine kernel (Layer 1) — the three-layer
//!     hot path. The AOT artifacts are compiled for `f32` only, so the
//!     PJRT family implements `ReduceOp<f32>` alone (see
//!     [`Elem::service_op`](crate::datatypes::Elem)).
//!
//! Both are validated against each other and against scalar folds in
//! `rust/tests/`.

pub mod kernels;
pub mod native;

pub use kernels::Kernel;
pub use native::{MaxOp, MinOp, NativeOp, ProdOp, SumOp};

use std::sync::atomic::{AtomicU64, Ordering};

use crate::datatypes::Elem;

/// The names [`parse_native`] accepts, for CLI diagnostics.
pub const NATIVE_OP_NAMES: [&str; 4] = ["sum", "prod", "min", "max"];

/// Human-readable list of valid operator names.
pub const OP_NAMES_HELP: &str = "sum|prod|min|max";

/// A binary, commutative, associative elementwise operator on blocks of
/// `T` (default `f32`, so pre-dtype code and trait objects like
/// `Box<dyn ReduceOp>` keep meaning the f32 operator).
///
/// `combine` computes `acc[i] ← acc[i] ⊕ other[i]`. Implementations must be
/// commutative — Algorithm 1 applies ⊕ in skip order, not rank order
/// (paper §2.1). For float dtypes ⊕ is commutative but *not* associative,
/// so results are only reproducible for a fixed schedule; the integer
/// dtypes (wrapping arithmetic) are exactly associative and yield
/// bit-identical results across schedules and transport tiers.
///
/// # Length contract
///
/// Operand slices must have equal length. The *executor* enforces this
/// once per received payload (`CollectiveError::BadPayload`) before any
/// kernel call, so implementations stay on the unchecked fast path and
/// only `debug_assert!` the contract — a release-mode mismatch through
/// some other caller is a bug at that call site, not in the kernel.
pub trait ReduceOp<T: Elem = f32>: Send + Sync {
    /// Stable name (matches the artifact manifest's `op` field).
    fn name(&self) -> &'static str;

    /// `acc ⊕= other` (slices must have equal length — see the trait docs).
    fn combine(&self, acc: &mut [T], other: &[T]);

    /// Out-of-place fused pass: `dst[i] ← a[i] ⊕ b[i]` (all three slices
    /// equal length). Default is copy-then-combine; native operators
    /// override with a single fused loop. Not yet on the executor's hot
    /// path (which is in-place); provided as the kernel-layer building
    /// block for out-of-place consumers (e.g. a future fused
    /// staging+combine in the communicator).
    fn combine_into(&self, dst: &mut [T], a: &[T], b: &[T]) {
        debug_assert_eq!(dst.len(), a.len(), "⊕ operands must have equal length");
        dst.copy_from_slice(a);
        self.combine(dst, b);
    }

    /// The monomorphized [`Kernel`] implementing this operator, if it is
    /// one of the four native ops. The executor resolves this once per
    /// collective and then skips dyn dispatch entirely on the combine hot
    /// path (the kernel's generic methods re-monomorphize per dtype at
    /// the call site). Instrumentation wrappers (e.g. [`CountingOp`]) and
    /// backend operators (PJRT) return `None` so every combine still
    /// flows through their `combine`.
    fn kernel(&self) -> Option<Kernel> {
        None
    }

    /// Identity element (e.g. 0 for sum, +∞/MAX for min) — used to
    /// initialize empty accumulations and pad PJRT buckets.
    fn identity(&self) -> T;
}

/// Parse an operator name (CLI/config) into a boxed native operator over
/// `f32` — the pre-dtype entry point, kept for source compatibility.
pub fn parse_native(name: &str) -> Option<Box<dyn ReduceOp>> {
    parse_native_typed::<f32>(name)
}

/// Parse an operator name into a boxed native operator over any dtype.
pub fn parse_native_typed<T: Elem>(name: &str) -> Option<Box<dyn ReduceOp<T>>> {
    match name {
        "sum" => Some(Box::new(SumOp)),
        "prod" => Some(Box::new(ProdOp)),
        "min" => Some(Box::new(MinOp)),
        "max" => Some(Box::new(MaxOp)),
        _ => None,
    }
}

/// Instrumentation wrapper: counts invocations and combined elements.
/// The T1/T2 benches use this to report the exact ⊕ counts of
/// Theorems 1 and 2.
pub struct CountingOp<'a, T: Elem = f32> {
    pub inner: &'a dyn ReduceOp<T>,
    pub calls: AtomicU64,
    pub elems: AtomicU64,
}

impl<'a, T: Elem> CountingOp<'a, T> {
    pub fn new(inner: &'a dyn ReduceOp<T>) -> Self {
        Self { inner, calls: AtomicU64::new(0), elems: AtomicU64::new(0) }
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn elems(&self) -> u64 {
        self.elems.load(Ordering::Relaxed)
    }
}

impl<'a, T: Elem> ReduceOp<T> for CountingOp<'a, T> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn combine(&self, acc: &mut [T], other: &[T]) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.elems.fetch_add(acc.len() as u64, Ordering::Relaxed);
        self.inner.combine(acc, other);
    }

    fn identity(&self) -> T {
        self.inner.identity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_known_ops() {
        for name in NATIVE_OP_NAMES {
            assert_eq!(parse_native(name).unwrap().name(), name);
            assert_eq!(parse_native_typed::<i64>(name).unwrap().name(), name);
            assert_eq!(parse_native_typed::<u64>(name).unwrap().name(), name);
        }
        assert!(parse_native("xor").is_none());
        assert!(parse_native_typed::<f64>("xor").is_none());
    }

    #[test]
    fn counting_op_counts() {
        let sum = SumOp;
        let c = CountingOp::<f32>::new(&sum);
        let mut a = vec![1.0f32; 10];
        c.combine(&mut a, &vec![2.0f32; 10]);
        c.combine(&mut a[..5], &vec![3.0f32; 5]);
        assert_eq!(c.calls(), 2);
        assert_eq!(c.elems(), 15);
        assert_eq!(a[0], 6.0);
        assert_eq!(a[9], 3.0);
    }

    #[test]
    fn counting_op_counts_typed() {
        let sum = SumOp;
        let c = CountingOp::<i64>::new(&sum);
        let mut a = vec![1i64; 8];
        c.combine(&mut a, &vec![2i64; 8]);
        assert_eq!(c.calls(), 1);
        assert_eq!(c.elems(), 8);
        assert_eq!(a[0], 3);
    }
}
