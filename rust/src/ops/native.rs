//! Native (pure Rust) reduction operators.
//!
//! The loops are written as simple index-free iterator zips over equal-length
//! slices so LLVM autovectorizes them; `perf_hotpath` measures them against
//! the single-core streaming roofline (§Perf in DESIGN.md).

use super::ReduceOp;

/// Shared shape check with a useful message.
#[inline]
fn check(acc: &[f32], other: &[f32]) {
    assert_eq!(
        acc.len(),
        other.len(),
        "⊕ operands must have equal length (acc={}, other={})",
        acc.len(),
        other.len()
    );
}

/// Marker trait so generic tests can enumerate the native ops.
pub trait NativeOp: ReduceOp + Default + Copy {}

/// Elementwise addition (MPI_SUM).
#[derive(Debug, Default, Clone, Copy)]
pub struct SumOp;

impl ReduceOp for SumOp {
    fn name(&self) -> &'static str {
        "sum"
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        check(acc, other);
        for (a, b) in acc.iter_mut().zip(other) {
            *a += *b;
        }
    }

    fn identity(&self) -> f32 {
        0.0
    }
}
impl NativeOp for SumOp {}

/// Elementwise product (MPI_PROD).
#[derive(Debug, Default, Clone, Copy)]
pub struct ProdOp;

impl ReduceOp for ProdOp {
    fn name(&self) -> &'static str {
        "prod"
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        check(acc, other);
        for (a, b) in acc.iter_mut().zip(other) {
            *a *= *b;
        }
    }

    fn identity(&self) -> f32 {
        1.0
    }
}
impl NativeOp for ProdOp {}

/// Elementwise minimum (MPI_MIN).
#[derive(Debug, Default, Clone, Copy)]
pub struct MinOp;

impl ReduceOp for MinOp {
    fn name(&self) -> &'static str {
        "min"
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        check(acc, other);
        for (a, b) in acc.iter_mut().zip(other) {
            *a = a.min(*b);
        }
    }

    fn identity(&self) -> f32 {
        f32::INFINITY
    }
}
impl NativeOp for MinOp {}

/// Elementwise maximum (MPI_MAX).
#[derive(Debug, Default, Clone, Copy)]
pub struct MaxOp;

impl ReduceOp for MaxOp {
    fn name(&self) -> &'static str {
        "max"
    }

    fn combine(&self, acc: &mut [f32], other: &[f32]) {
        check(acc, other);
        for (a, b) in acc.iter_mut().zip(other) {
            *a = a.max(*b);
        }
    }

    fn identity(&self) -> f32 {
        f32::NEG_INFINITY
    }
}
impl NativeOp for MaxOp {}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops() -> Vec<Box<dyn ReduceOp>> {
        vec![Box::new(SumOp), Box::new(ProdOp), Box::new(MinOp), Box::new(MaxOp)]
    }

    #[test]
    fn identities_are_identities() {
        for op in ops() {
            let mut acc = vec![op.identity(); 16];
            let data: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
            op.combine(&mut acc, &data);
            assert_eq!(acc, data, "{} identity not neutral", op.name());
        }
    }

    #[test]
    fn commutative_on_random_data() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(17);
        for op in ops() {
            let x = rng.normal_vec(257);
            let y = rng.normal_vec(257);
            let mut a = x.clone();
            op.combine(&mut a, &y);
            let mut b = y.clone();
            op.combine(&mut b, &x);
            assert_eq!(a, b, "{} not commutative", op.name());
        }
    }

    #[test]
    fn known_values() {
        let mut a = vec![1.0, -2.0, 3.0];
        SumOp.combine(&mut a, &[4.0, 5.0, -6.0]);
        assert_eq!(a, vec![5.0, 3.0, -3.0]);
        let mut a = vec![2.0, 3.0, 4.0];
        ProdOp.combine(&mut a, &[0.5, -1.0, 0.0]);
        assert_eq!(a, vec![1.0, -3.0, 0.0]);
        let mut a = vec![1.0, -2.0];
        MinOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![0.0, -2.0]);
        let mut a = vec![1.0, -2.0];
        MaxOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![1.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn length_mismatch_panics() {
        let mut a = vec![0.0; 3];
        SumOp.combine(&mut a, &[0.0; 4]);
    }

    #[test]
    fn empty_slices_ok() {
        let mut a: Vec<f32> = vec![];
        SumOp.combine(&mut a, &[]);
    }
}
