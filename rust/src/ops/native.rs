//! Native (pure Rust) reduction operators, generic over the element type.
//!
//! Each operator is a thin `dyn`-compatible wrapper over its monomorphized
//! [`Kernel`] (see [`super::kernels`]): the cache-blocked, unrolled loops
//! live there, and callers that resolve [`ReduceOp::kernel`] (the schedule
//! executor) bypass the vtable entirely on the hot path. One zero-sized
//! operator type implements `ReduceOp<T>` for **every** supported dtype
//! (`impl<T: Elem> ReduceOp<T> for SumOp`), so `SumOp` works unchanged
//! whether the collective runs over `f32` or `i64`. `perf_hotpath`
//! measures the kernels against the single-core streaming roofline
//! (§Perf in DESIGN.md).
//!
//! Length checking is hoisted out of the kernel layer: the executor
//! validates each received payload once (`CollectiveError::BadPayload`),
//! and the kernels keep only `debug_assert!`s — see the [`ReduceOp`]
//! trait docs for the contract.

use crate::datatypes::Elem;

use super::kernels::Kernel;
use super::ReduceOp;

/// Marker trait so generic tests can enumerate the native ops (f32 view).
pub trait NativeOp: ReduceOp + Default + Copy {}

macro_rules! native_op {
    ($(#[$doc:meta])* $name:ident, $kernel:expr) => {
        $(#[$doc])*
        #[derive(Debug, Default, Clone, Copy)]
        pub struct $name;

        impl<T: Elem> ReduceOp<T> for $name {
            fn name(&self) -> &'static str {
                $kernel.name()
            }

            #[inline]
            fn combine(&self, acc: &mut [T], other: &[T]) {
                $kernel.combine(acc, other);
            }

            #[inline]
            fn combine_into(&self, dst: &mut [T], a: &[T], b: &[T]) {
                $kernel.combine_into(dst, a, b);
            }

            fn kernel(&self) -> Option<Kernel> {
                Some($kernel)
            }

            fn identity(&self) -> T {
                $kernel.identity()
            }
        }
        impl NativeOp for $name {}
    };
}

native_op!(
    /// Elementwise addition (MPI_SUM). Wrapping for integer dtypes.
    SumOp,
    Kernel::Sum
);
native_op!(
    /// Elementwise product (MPI_PROD). Wrapping for integer dtypes.
    ProdOp,
    Kernel::Prod
);
native_op!(
    /// Elementwise minimum (MPI_MIN).
    MinOp,
    Kernel::Min
);
native_op!(
    /// Elementwise maximum (MPI_MAX).
    MaxOp,
    Kernel::Max
);

#[cfg(test)]
mod tests {
    use super::*;

    fn ops() -> Vec<Box<dyn ReduceOp>> {
        vec![Box::new(SumOp), Box::new(ProdOp), Box::new(MinOp), Box::new(MaxOp)]
    }

    #[test]
    fn identities_are_identities() {
        for op in ops() {
            let mut acc = vec![op.identity(); 16];
            let data: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
            op.combine(&mut acc, &data);
            assert_eq!(acc, data, "{} identity not neutral", op.name());
        }
    }

    #[test]
    fn commutative_on_random_data() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(17);
        for op in ops() {
            let x = rng.normal_vec(257);
            let y = rng.normal_vec(257);
            let mut a = x.clone();
            op.combine(&mut a, &y);
            let mut b = y.clone();
            op.combine(&mut b, &x);
            assert_eq!(a, b, "{} not commutative", op.name());
        }
    }

    #[test]
    fn known_values() {
        let mut a = vec![1.0f32, -2.0, 3.0];
        SumOp.combine(&mut a, &[4.0, 5.0, -6.0]);
        assert_eq!(a, vec![5.0, 3.0, -3.0]);
        let mut a = vec![2.0f32, 3.0, 4.0];
        ProdOp.combine(&mut a, &[0.5, -1.0, 0.0]);
        assert_eq!(a, vec![1.0, -3.0, 0.0]);
        let mut a = vec![1.0f32, -2.0];
        MinOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![0.0, -2.0]);
        let mut a = vec![1.0f32, -2.0];
        MaxOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![1.0, 5.0]);
    }

    #[test]
    fn known_values_integer_dtypes() {
        let mut a = vec![1i64, -2, 3];
        SumOp.combine(&mut a, &[4, 5, -6]);
        assert_eq!(a, vec![5, 3, -3]);
        let mut a = vec![2i32, 3, -4];
        ProdOp.combine(&mut a, &[5, -1, 0]);
        assert_eq!(a, vec![10, -3, 0]);
        let mut a = vec![1u64, 7];
        MinOp.combine(&mut a, &[0, 9]);
        assert_eq!(a, vec![0, 7]);
        let mut a = vec![1i64, -2];
        MaxOp.combine(&mut a, &[0, 5]);
        assert_eq!(a, vec![1, 5]);
        // wrapping sum is total, not a panic
        let mut a = vec![i64::MAX];
        SumOp.combine(&mut a, &[1]);
        assert_eq!(a, vec![i64::MIN]);
    }

    #[test]
    fn combine_into_matches_in_place() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(5);
        for op in ops() {
            let a = rng.normal_vec(97);
            let b = rng.normal_vec(97);
            let mut dst = vec![0.0f32; 97];
            op.combine_into(&mut dst, &a, &b);
            let mut want = a.clone();
            op.combine(&mut want, &b);
            assert_eq!(dst, want, "{}", op.name());
        }
    }

    #[test]
    fn every_native_op_exposes_its_kernel() {
        for op in ops() {
            let k = op.kernel().expect("native op must expose a kernel");
            assert_eq!(k.name(), op.name());
            assert_eq!(k.identity::<f32>(), op.identity());
        }
    }

    // Length mismatches are validated once per payload by the executor
    // (see `ReduceOp`'s docs); the kernels only debug_assert. Cover the
    // debug-mode contract here so the guard itself stays exercised.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "equal length")]
    fn length_mismatch_panics_in_debug() {
        let mut a = vec![0.0f32; 3];
        SumOp.combine(&mut a, &[0.0; 4]);
    }

    #[test]
    fn empty_slices_ok() {
        let mut a: Vec<f32> = vec![];
        SumOp.combine(&mut a, &[]);
    }
}
