//! Native (pure Rust) reduction operators.
//!
//! Each operator is a thin `dyn`-compatible wrapper over its monomorphized
//! [`Kernel`] (see [`super::kernels`]): the cache-blocked, unrolled loops
//! live there, and callers that resolve [`ReduceOp::kernel`] (the schedule
//! executor) bypass the vtable entirely on the hot path. `perf_hotpath`
//! measures the kernels against the single-core streaming roofline
//! (§Perf in DESIGN.md).
//!
//! Length checking is hoisted out of the kernel layer: the executor
//! validates each received payload once (`CollectiveError::BadPayload`),
//! and the kernels keep only `debug_assert!`s — see the [`ReduceOp`]
//! trait docs for the contract.

use super::kernels::Kernel;
use super::ReduceOp;

/// Marker trait so generic tests can enumerate the native ops.
pub trait NativeOp: ReduceOp + Default + Copy {}

macro_rules! native_op {
    ($(#[$doc:meta])* $name:ident, $kernel:expr) => {
        $(#[$doc])*
        #[derive(Debug, Default, Clone, Copy)]
        pub struct $name;

        impl ReduceOp for $name {
            fn name(&self) -> &'static str {
                $kernel.name()
            }

            #[inline]
            fn combine(&self, acc: &mut [f32], other: &[f32]) {
                $kernel.combine(acc, other);
            }

            #[inline]
            fn combine_into(&self, dst: &mut [f32], a: &[f32], b: &[f32]) {
                $kernel.combine_into(dst, a, b);
            }

            fn kernel(&self) -> Option<Kernel> {
                Some($kernel)
            }

            fn identity(&self) -> f32 {
                $kernel.identity()
            }
        }
        impl NativeOp for $name {}
    };
}

native_op!(
    /// Elementwise addition (MPI_SUM).
    SumOp,
    Kernel::Sum
);
native_op!(
    /// Elementwise product (MPI_PROD).
    ProdOp,
    Kernel::Prod
);
native_op!(
    /// Elementwise minimum (MPI_MIN).
    MinOp,
    Kernel::Min
);
native_op!(
    /// Elementwise maximum (MPI_MAX).
    MaxOp,
    Kernel::Max
);

#[cfg(test)]
mod tests {
    use super::*;

    fn ops() -> Vec<Box<dyn ReduceOp>> {
        vec![Box::new(SumOp), Box::new(ProdOp), Box::new(MinOp), Box::new(MaxOp)]
    }

    #[test]
    fn identities_are_identities() {
        for op in ops() {
            let mut acc = vec![op.identity(); 16];
            let data: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
            op.combine(&mut acc, &data);
            assert_eq!(acc, data, "{} identity not neutral", op.name());
        }
    }

    #[test]
    fn commutative_on_random_data() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(17);
        for op in ops() {
            let x = rng.normal_vec(257);
            let y = rng.normal_vec(257);
            let mut a = x.clone();
            op.combine(&mut a, &y);
            let mut b = y.clone();
            op.combine(&mut b, &x);
            assert_eq!(a, b, "{} not commutative", op.name());
        }
    }

    #[test]
    fn known_values() {
        let mut a = vec![1.0, -2.0, 3.0];
        SumOp.combine(&mut a, &[4.0, 5.0, -6.0]);
        assert_eq!(a, vec![5.0, 3.0, -3.0]);
        let mut a = vec![2.0, 3.0, 4.0];
        ProdOp.combine(&mut a, &[0.5, -1.0, 0.0]);
        assert_eq!(a, vec![1.0, -3.0, 0.0]);
        let mut a = vec![1.0, -2.0];
        MinOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![0.0, -2.0]);
        let mut a = vec![1.0, -2.0];
        MaxOp.combine(&mut a, &[0.0, 5.0]);
        assert_eq!(a, vec![1.0, 5.0]);
    }

    #[test]
    fn combine_into_matches_in_place() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(5);
        for op in ops() {
            let a = rng.normal_vec(97);
            let b = rng.normal_vec(97);
            let mut dst = vec![0.0f32; 97];
            op.combine_into(&mut dst, &a, &b);
            let mut want = a.clone();
            op.combine(&mut want, &b);
            assert_eq!(dst, want, "{}", op.name());
        }
    }

    #[test]
    fn every_native_op_exposes_its_kernel() {
        for op in ops() {
            let k = op.kernel().expect("native op must expose a kernel");
            assert_eq!(k.name(), op.name());
            assert_eq!(k.identity(), op.identity());
        }
    }

    // Length mismatches are validated once per payload by the executor
    // (see `ReduceOp`'s docs); the kernels only debug_assert. Cover the
    // debug-mode contract here so the guard itself stays exercised.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "equal length")]
    fn length_mismatch_panics_in_debug() {
        let mut a = vec![0.0; 3];
        SumOp.combine(&mut a, &[0.0; 4]);
    }

    #[test]
    fn empty_slices_ok() {
        let mut a: Vec<f32> = vec![];
        SumOp.combine(&mut a, &[]);
    }
}
