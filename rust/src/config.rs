//! Configuration system: a TOML-subset file format plus CLI overrides.
//!
//! serde/toml are unavailable offline, so this is a small hand-rolled
//! parser covering the subset the launcher needs: `key = value` pairs,
//! `[section]` headers (flattened to `section.key`), strings, integers,
//! floats, booleans and comments. Values are stored as strings and
//! converted by typed getters; CLI `--key value` flags override file
//! entries (the usual launcher precedence).
//!
//! Example (`examples/ccoll.toml`):
//! ```toml
//! [run]
//! p = 22
//! m = 65536
//! algorithm = "allreduce"   # circulant, halving-up skips
//! op = "sum"
//! backend = "native"        # or "pjrt"
//!
//! [cost]
//! alpha = 1e-6
//! beta = 4e-10
//! gamma = 1e-9
//! ```

use std::collections::BTreeMap;

use crate::collectives::Algorithm;
use crate::sim::CostModel;

/// Flat key→value configuration with layered overrides.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("cannot read {path}: {source}")]
    Io { path: String, source: std::io::Error },
    #[error("key {key}: cannot parse {value:?} as {ty}")]
    Type { key: String, value: String, ty: &'static str },
    #[error("key {key}: {msg}")]
    Invalid { key: String, msg: String },
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text, flattening `[section]` to `section.` prefixes.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let line = match line.find('#') {
                // strip comments, but not inside quoted strings
                Some(i) if !line[..i].contains('"') || line[..i].matches('"').count() % 2 == 0 => {
                    line[..i].trim_end()
                }
                _ => line,
            };
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = format!("{}.", name.trim());
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ConfigError::Parse {
                line: ln + 1,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = format!("{section}{}", k.trim());
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|source| ConfigError::Io { path: path.to_string(), source })?;
        Self::parse(&text)
    }

    /// Apply `--key value` / `--flag` style CLI overrides (dots allowed in
    /// keys: `--cost.alpha 2e-6`). Returns leftover positional args.
    pub fn apply_args(&mut self, args: &[String]) -> Result<Vec<String>, ConfigError> {
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    self.values.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    self.values.insert(key.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    self.values.insert(key.to_string(), "true".to_string());
                }
            } else {
                positional.push(args[i].clone());
            }
            i += 1;
        }
        Ok(positional)
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.replace('_', "").parse().map_err(|_| ConfigError::Type {
                key: key.into(),
                value: v.clone(),
                ty: "usize",
            }),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ConfigError::Type {
                key: key.into(),
                value: v.clone(),
                ty: "f64",
            }),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.values.get(key).map(String::as_str) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => {
                Err(ConfigError::Type { key: key.into(), value: v.into(), ty: "bool" })
            }
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// The collective algorithm (`run.algorithm`, default circulant
    /// allreduce with halving-up skips). Unknown names report the full
    /// grammar of valid values.
    pub fn algorithm(&self) -> Result<Algorithm, ConfigError> {
        let name = self.get_str("run.algorithm", "allreduce");
        Algorithm::parse(name).ok_or_else(|| ConfigError::Invalid {
            key: "run.algorithm".into(),
            msg: format!("unknown algorithm {name:?} (valid: {})", Algorithm::NAMES_HELP),
        })
    }

    /// The element type (`run.dtype`, default f32). Unknown names report
    /// the valid set.
    pub fn dtype(&self) -> Result<crate::datatypes::DType, ConfigError> {
        let name = self.get_str("run.dtype", "f32");
        crate::datatypes::DType::parse(name).ok_or_else(|| ConfigError::Invalid {
            key: "run.dtype".into(),
            msg: format!(
                "unknown dtype {name:?} (valid: {})",
                crate::datatypes::DType::NAMES_HELP
            ),
        })
    }

    /// The transport backend (`transport.backend`, default from the
    /// process-wide `CCOLL_TRANSPORT` knob, which itself defaults to the
    /// in-process thread backend). Unknown names report the valid set.
    pub fn transport_backend(
        &self,
    ) -> Result<crate::transport::TransportBackend, ConfigError> {
        use crate::transport::TransportBackend;
        let default = crate::env_knobs::knobs().transport_backend;
        let name = self.get_str("transport.backend", default.name());
        TransportBackend::parse(name).ok_or_else(|| ConfigError::Invalid {
            key: "transport.backend".into(),
            msg: format!(
                "unknown transport backend {name:?} (valid: {})",
                TransportBackend::NAMES_HELP
            ),
        })
    }

    /// The α-β-γ cost model (`cost.*`, defaults = CostModel::cluster()).
    pub fn cost_model(&self) -> Result<CostModel, ConfigError> {
        let d = CostModel::cluster();
        Ok(CostModel::new(
            self.get_f64("cost.alpha", d.alpha)?,
            self.get_f64("cost.beta", d.beta)?,
            self.get_f64("cost.gamma", d.gamma)?,
        ))
    }

    /// Dump all resolved keys (for `ccoll info`).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str)> {
        self.values.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::SkipScheme;

    #[test]
    fn parses_sections_comments_types() {
        let cfg = Config::parse(
            r#"
            # a comment
            top = 1
            [run]
            p = 22            # trailing comment
            algorithm = "allreduce:pow2"
            verbose = true
            [cost]
            alpha = 1e-6
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get_usize("top", 0).unwrap(), 1);
        assert_eq!(cfg.get_usize("run.p", 0).unwrap(), 22);
        assert!(cfg.get_bool("run.verbose", false).unwrap());
        assert_eq!(cfg.cost_model().unwrap().alpha, 1e-6);
        assert_eq!(
            cfg.algorithm().unwrap(),
            crate::collectives::Algorithm::CirculantAllreduce(SkipScheme::PowerOfTwo)
        );
    }

    #[test]
    fn cli_overrides_file() {
        let mut cfg = Config::parse("run.p = 4").unwrap();
        let extra = cfg
            .apply_args(&["--run.p".into(), "8".into(), "trace".into(), "--flag".into()])
            .unwrap();
        assert_eq!(cfg.get_usize("run.p", 0).unwrap(), 8);
        assert_eq!(extra, vec!["trace".to_string()]);
        assert!(cfg.get_bool("flag", false).unwrap());
    }

    #[test]
    fn key_equals_value_form() {
        let mut cfg = Config::new();
        cfg.apply_args(&["--cost.alpha=2e-5".into()]).unwrap();
        assert_eq!(cfg.get_f64("cost.alpha", 0.0).unwrap(), 2e-5);
    }

    #[test]
    fn errors_are_typed() {
        let cfg = Config::parse("x = notanumber").unwrap();
        assert!(matches!(cfg.get_usize("x", 0), Err(ConfigError::Type { .. })));
        assert!(Config::parse("just a line").is_err());
        assert!(Config::from_file("/nope/nope.toml").is_err());
    }

    #[test]
    fn defaults_flow_through() {
        let cfg = Config::new();
        assert_eq!(cfg.get_usize("run.p", 8).unwrap(), 8);
        assert_eq!(cfg.get_str("run.op", "sum"), "sum");
        let cm = cfg.cost_model().unwrap();
        assert_eq!(cm.alpha, CostModel::cluster().alpha);
    }

    #[test]
    fn underscores_in_integers() {
        let cfg = Config::parse("m = 1_048_576").unwrap();
        assert_eq!(cfg.get_usize("m", 0).unwrap(), 1 << 20);
    }

    #[test]
    fn dtype_key_parses_and_defaults() {
        let cfg = Config::new();
        assert_eq!(cfg.dtype().unwrap(), crate::datatypes::DType::F32);
        let cfg = Config::parse("run.dtype = \"i64\"").unwrap();
        assert_eq!(cfg.dtype().unwrap(), crate::datatypes::DType::I64);
    }

    #[test]
    fn unknown_values_enumerate_the_valid_set() {
        let cfg = Config::parse("run.dtype = \"f16\"").unwrap();
        let err = cfg.dtype().unwrap_err().to_string();
        assert!(err.contains("f32|f64|i32|i64|u64"), "{err}");
        let cfg = Config::parse("run.algorithm = \"nope\"").unwrap();
        let err = cfg.algorithm().unwrap_err().to_string();
        assert!(err.contains("ring-allreduce") && err.contains("rabenseifner"), "{err}");
        let cfg = Config::parse("transport.backend = \"tcp\"").unwrap();
        let err = cfg.transport_backend().unwrap_err().to_string();
        assert!(err.contains("thread|uds"), "{err}");
    }

    #[test]
    fn transport_backend_key_parses_and_defaults() {
        use crate::transport::TransportBackend;
        let cfg = Config::new();
        // The ambient default follows the process-wide CCOLL_TRANSPORT
        // knob (thread unless the env overrides it).
        assert_eq!(
            cfg.transport_backend().unwrap(),
            crate::env_knobs::knobs().transport_backend
        );
        let cfg = Config::parse("transport.backend = \"uds\"").unwrap();
        assert_eq!(cfg.transport_backend().unwrap(), TransportBackend::Uds);
        let cfg = Config::parse("transport.backend = \"thread\"").unwrap();
        assert_eq!(cfg.transport_backend().unwrap(), TransportBackend::Thread);
    }
}
