//! `ccoll` command-line interface (hand-rolled; clap unavailable offline).
//!
//! Subcommands:
//!   info       platform + artifact + config report, plus the supported
//!              (op, dtype) kernel matrix
//!   run        execute a collective on the thread network, verify, report
//!              (generic over `run.dtype`: f32|f64|i32|i64|u64)
//!   simulate   α-β-γ DES + closed-form comparison sweep
//!   trace      symbolic round-by-round trace (reproduces the paper's §2.1
//!              p=22 example)
//!   validate   Theorem 1/2 counter + correctness sweep over a p range,
//!              plus an exact data-path check in the configured dtype
//!   train      end-to-end data-parallel training (PJRT compute + Alg 2)
//!
//! Global flags: `--config FILE` and `--key value` overrides (see
//! `crate::config`). Unknown `run.op` / `run.algorithm` / `run.dtype`
//! values fail with the full list of valid alternatives.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::collectives::{symbolic, Algorithm};
use crate::config::Config;
use crate::coordinator::{train, Launcher, OpBackend, RunMetrics, TrainConfig};
use crate::datatypes::{elem, BlockPartition, DType, Elem};
use crate::ops::{ReduceOp, SumOp, NATIVE_OP_NAMES, OP_NAMES_HELP};
use crate::runtime::{default_artifact_dir, ComputeService, Manifest};
use crate::sim::{closed_form, simulate};
use crate::topology::skips::SkipScheme;
use crate::util::rng::SplitMix64;
use crate::util::table::{fmt_si, Table};

pub const USAGE: &str = "\
usage: ccoll [--config FILE] [--key value …] <command>

commands:
  info                     show platform, artifacts, resolved config, and
                           the supported (op, dtype) kernel matrix
  run                      run a collective (keys: run.p run.m run.algorithm
                           run.op run.dtype run.backend run.seed run.verify)
  simulate                 cost-model sweep (keys: sim.p sim.m cost.alpha
                           cost.beta cost.gamma)
  trace                    symbolic trace (keys: trace.p trace.rank)
  validate                 Theorem 1/2 sweep + exact data-path check
                           (keys: validate.max_p run.dtype)
  search                   skip-sequence search, the paper's §2.1 open
                           question (keys: search.p search.m search.node
                           search.beam)
  train                    E2E data-parallel training (keys: train.workers
                           train.steps train.lr train.backend)
";

/// Entry point: parse args, dispatch. Returns the process exit code.
pub fn main_with_args(args: Vec<String>) -> Result<()> {
    let mut cfg = Config::new();
    // --config FILE is processed first so flags can override the file.
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).ok_or_else(|| anyhow!("--config needs a path"))?;
            cfg = Config::from_file(path)?;
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    let positional = cfg.apply_args(&rest)?;
    let cmd = positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&cfg),
        "run" => cmd_run(&cfg),
        "simulate" => cmd_simulate(&cfg),
        "trace" => cmd_trace(&cfg),
        "validate" => cmd_validate(&cfg),
        "search" => cmd_search(&cfg),
        "train" => cmd_train(&cfg),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_info(cfg: &Config) -> Result<()> {
    println!("circulant-collectives — Träff 2024 reproduction (see DESIGN.md)");
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} modules, buckets {:?}, jax-built)", dir.display(), m.artifacts.len(), m.buckets);
            println!("mlp: {} params ({}→{}→{}→{}, batch {})", m.mlp.params, m.mlp.d_in, m.mlp.hidden, m.mlp.hidden, m.mlp.d_out, m.mlp.batch);
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    // The supported (op, dtype) kernel matrix, derived from DType::ALL so
    // a newly added dtype can never leave this table stale: native
    // kernels are monomorphized per (op, dtype); the PJRT Pallas
    // artifacts are compiled for f32 only.
    let cols: Vec<String> =
        DType::ALL.iter().map(|d| format!("{} ({}B)", d.name(), d.size_bytes())).collect();
    let mut header: Vec<&str> = vec!["op"];
    header.extend(cols.iter().map(String::as_str));
    header.push("pjrt");
    let mut t = Table::new("kernel matrix (op × dtype)", &header);
    for op in NATIVE_OP_NAMES {
        let mut cells: Vec<String> = vec![op.to_string()];
        cells.extend(DType::ALL.iter().map(|_| "native".to_string()));
        cells.push("f32 only".into());
        t.row(&cells);
    }
    t.print();
    println!("integer ⊕ is wrapping (exactly associative — bit-exact oracles);");
    println!("float ⊕ is IEEE (non-associative — fixed-schedule reproducibility only).");
    let n: usize = cfg.entries().count();
    if n > 0 {
        println!("config:");
        for (k, v) in cfg.entries() {
            println!("  {k} = {v}");
        }
    }
    Ok(())
}

fn cmd_run(cfg: &Config) -> Result<()> {
    match cfg.dtype()? {
        DType::F32 => cmd_run_typed::<f32>(cfg),
        DType::F64 => cmd_run_typed::<f64>(cfg),
        DType::I32 => cmd_run_typed::<i32>(cfg),
        DType::I64 => cmd_run_typed::<i64>(cfg),
        DType::U64 => cmd_run_typed::<u64>(cfg),
    }
}

fn cmd_run_typed<T: Elem>(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("run.p", 8)?;
    let m = cfg.get_usize("run.m", 1 << 16)?;
    let alg = cfg.algorithm()?;
    let op_name = cfg.get_str("run.op", "sum").to_string();
    let backend_name = cfg.get_str("run.backend", "native").to_string();
    let seed = cfg.get_usize("run.seed", 1)? as u64;
    let verify = cfg.get_bool("run.verify", true)?;

    if !NATIVE_OP_NAMES.contains(&op_name.as_str()) {
        bail!("unknown run.op {op_name:?} (valid: {OP_NAMES_HELP})");
    }

    let _service; // keep the compute service alive for the whole run
    let backend = match backend_name.as_str() {
        "native" => OpBackend::Native,
        "pjrt" => {
            if T::DTYPE != DType::F32 {
                bail!(
                    "run.backend=pjrt supports run.dtype=f32 only (the AOT Pallas \
                     kernels are compiled for f32); got run.dtype={} — use \
                     run.backend=native for other dtypes",
                    T::DTYPE.name()
                );
            }
            let svc = ComputeService::start(default_artifact_dir(), vec![op_name.clone()], false, false)?;
            let h = svc.handle.clone();
            _service = svc;
            OpBackend::Pjrt(h)
        }
        other => bail!("unknown run.backend {other:?} (valid: native|pjrt)"),
    };

    let part = BlockPartition::regular(p, m);
    let sched = alg.schedule(p);
    sched.assert_valid();

    // Small-integer-valued inputs so sums verify exactly in every dtype
    // (float sums stay within the exactly-representable range; integer
    // sums are wrapping and exact by construction).
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<T>> = (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut oracle = vec![T::zero(); m];
    for v in &inputs {
        SumOp.combine(&mut oracle, v);
    }

    let sched2 = Arc::new(sched);
    let part2 = Arc::new(part.clone());
    let inputs2 = Arc::new(std::sync::Mutex::new(inputs.into_iter().map(Some).collect::<Vec<_>>()));
    let op2 = op_name.clone();
    let sched3 = sched2.clone();
    let t0 = std::time::Instant::now();
    let results = Launcher::new(p).backend(backend).run_typed::<T, _, _>(move |mut comm| {
        let mut buf = inputs2.lock().unwrap()[comm.rank()].take().unwrap();
        comm.run_schedule(&sched3, &part2, &op2, &mut buf).expect("collective");
        (buf, comm.counters())
    });
    let wall = t0.elapsed().as_secs_f64();

    let metrics = RunMetrics {
        algorithm: alg.name(),
        dtype: T::DTYPE.name().to_string(),
        p,
        m,
        wall_seconds: wall,
        per_rank: results.iter().map(|(_, c)| c.clone()).collect(),
    };
    metrics.summary_table().print();

    if verify && op_name == "sum" {
        let part = BlockPartition::regular(p, m);
        let mut ok = true;
        for (r, (buf, _)) in results.iter().enumerate() {
            let good = if alg.is_allreduce() {
                buf[..] == oracle[..]
            } else if alg.is_reduce_scatter() {
                buf[part.range(r)] == oracle[part.range(r)]
            } else {
                true
            };
            if !good {
                eprintln!("VERIFY FAILED at rank {r}");
                ok = false;
            }
        }
        if ok {
            println!("verify: OK (exact match vs scalar oracle, dtype {})", T::DTYPE.name());
        } else {
            bail!("verification failed");
        }
    }
    Ok(())
}

fn cmd_simulate(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("sim.p", 1000)?;
    let m = cfg.get_usize("sim.m", 1 << 20)?;
    let model = cfg.cost_model()?;
    println!("cost model: α={:.2e}s β={:.2e}s/elem γ={:.2e}s/elem", model.alpha, model.beta, model.gamma);
    let part = BlockPartition::regular(p, m);
    let mut t = Table::new(
        &format!("simulated allreduce, p={p}, m={m}"),
        &["algorithm", "rounds", "DES time", "closed form"],
    );
    for alg in Algorithm::allreduce_family() {
        let sched = alg.schedule(p);
        let sim = simulate(&sched, &part, &model);
        let cf = match &alg {
            Algorithm::CirculantAllreduce(_) => closed_form::alg2_allreduce(&model, p, m),
            Algorithm::RingAllreduce => closed_form::ring_allreduce(&model, p, m),
            Algorithm::RecursiveDoublingAllreduce => {
                closed_form::recursive_doubling_allreduce(&model, p, m)
            }
            Algorithm::RabenseifnerAllreduce => closed_form::rabenseifner_allreduce(&model, p, m),
            _ => closed_form::binomial_allreduce(&model, p, m),
        };
        t.row(&[
            alg.name(),
            sim.rounds.to_string(),
            format!("{}s", fmt_si(sim.total)),
            format!("{}s", fmt_si(cf)),
        ]);
    }
    t.print();
    let (best, tbest) = crate::coordinator::select_allreduce(&model, p, m);
    println!("selector: {} predicted {}s", best.name(), fmt_si(tbest));
    Ok(())
}

fn cmd_trace(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("trace.p", 22)?;
    let r = cfg.get_usize("trace.rank", p - 1)?;
    let scheme = SkipScheme::parse(cfg.get_str("trace.scheme", "halving")).map_err(|e| anyhow!("{e}"))?;
    let skips = scheme.skips(p).map_err(|e| anyhow!("{e}"))?;
    println!("p={p}, rank={r}, scheme={}, skips={skips:?} (⌈log2 {p}⌉={} rounds)", scheme.name(), skips.len());
    let sched = crate::collectives::reduce_scatter_schedule(p, &skips);
    println!("from-processors of rank {r}: {:?}", skips.iter().map(|s| (r + p - s) % p).collect::<Vec<_>>());
    let terms = symbolic::paper_example_terms(&sched, r);
    println!("\nW at rank {r} accumulates (x_i = input block of processor i for {r}):");
    println!("  W = {}", terms[0]);
    for (k, t) in terms[1..].iter().enumerate() {
        println!("    + {t}   (round {})", k + 1);
    }
    let depth = symbolic::verify_reduce_scatter(&sched).map_err(|e| anyhow!("{e}"))?;
    println!("\nsymbolic check: every contributor exactly once at every rank ✓ (max tree depth {depth})");
    Ok(())
}

fn cmd_validate(cfg: &Config) -> Result<()> {
    let max_p = cfg.get_usize("validate.max_p", 128)?;
    // Parse the dtype up front: a typo must fail before the sweep runs,
    // not after minutes of counter/symbolic work.
    let dtype = cfg.dtype()?;
    let mut bad = 0usize;
    for p in 1..=max_p {
        for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
            let skips = scheme.skips(p).map_err(|e| anyhow!("{e}"))?;
            if p >= 2 {
                let rs = crate::collectives::reduce_scatter_schedule(p, &skips);
                rs.assert_valid();
                let part = BlockPartition::uniform(p, 1);
                for c in rs.counters(&part) {
                    if c.blocks_sent != p - 1 || c.blocks_combined != p - 1 {
                        eprintln!("FAIL p={p} {}: counters {c:?}", scheme.name());
                        bad += 1;
                    }
                }
                if symbolic::verify_reduce_scatter(&rs).is_err() {
                    eprintln!("FAIL p={p} {}: symbolic", scheme.name());
                    bad += 1;
                }
            }
        }
    }
    if bad != 0 {
        bail!("{bad} validation failures");
    }
    println!("validate: PASS — Theorem 1 counters + symbolic correctness for p ≤ {max_p} × 3 schemes");
    // Data-path check in the configured dtype: small thread-network runs
    // against an exact scalar oracle (wrapping-integer arithmetic makes
    // this bit-exact for integer dtypes; small-integer values keep float
    // sums exact too).
    match dtype {
        DType::F32 => validate_data_path::<f32>(),
        DType::F64 => validate_data_path::<f64>(),
        DType::I32 => validate_data_path::<i32>(),
        DType::I64 => validate_data_path::<i64>(),
        DType::U64 => validate_data_path::<u64>(),
    }
}

fn validate_data_path<T: Elem>() -> Result<()> {
    use crate::collectives::{allreduce_schedule, reduce_scatter_schedule, run_schedule_threads_typed};
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    for p in [2usize, 3, 5, 9] {
        let part = BlockPartition::regular(p, 4 * p + 3);
        let skips = SkipScheme::HalvingUp.skips(p).map_err(|e| anyhow!("{e}"))?;
        let mut rng = SplitMix64::new(77 + p as u64);
        let inputs: Vec<Vec<T>> =
            (0..p).map(|_| elem::int_vec(&mut rng, part.total(), lo, hi)).collect();
        let mut oracle = vec![T::zero(); part.total()];
        for v in &inputs {
            SumOp.combine(&mut oracle, v);
        }
        let op: Arc<dyn ReduceOp<T>> = Arc::new(SumOp);
        let rs = run_schedule_threads_typed::<T>(
            &reduce_scatter_schedule(p, &skips),
            &part,
            op.clone(),
            inputs.clone(),
        );
        for (r, buf) in rs.iter().enumerate() {
            let range = part.range(r);
            if buf[range.clone()] != oracle[range] {
                bail!("data-path FAIL: reduce-scatter p={p} rank {r} ({})", T::DTYPE.name());
            }
        }
        let ar = run_schedule_threads_typed::<T>(
            &allreduce_schedule(p, &skips),
            &part,
            op,
            inputs,
        );
        for (r, buf) in ar.iter().enumerate() {
            if buf[..] != oracle[..] {
                bail!("data-path FAIL: allreduce p={p} rank {r} ({})", T::DTYPE.name());
            }
        }
    }
    println!("validate: data path OK — exact oracle match in dtype {}", T::DTYPE.name());
    Ok(())
}

fn cmd_search(cfg: &Config) -> Result<()> {
    use crate::collectives::reduce_scatter_schedule;
    use crate::sim::hier::{simulate_hier, HierModel};
    use crate::sim::CostModel;
    use crate::topology::search::{beam_search, exhaustive_best};

    let p = cfg.get_usize("search.p", 22)?;
    let m = cfg.get_usize("search.m", 4096 * p)?;
    let node = cfg.get_usize("search.node", 0)?; // 0 = homogeneous model
    let beam = cfg.get_usize("search.beam", 64)?;
    let part = BlockPartition::regular(p, m);
    let model = cfg.cost_model()?;

    let eval = |seq: &[usize]| -> f64 {
        let sched = reduce_scatter_schedule(p, seq);
        if node > 0 {
            let hm = HierModel { node_size: node, intra: model, inter: CostModel::new(model.alpha * 10.0, model.beta * 4.0, model.gamma) };
            simulate_hier(&sched, &part, &hm).total
        } else {
            simulate(&sched, &part, &model).total
        }
    };
    let halving = SkipScheme::HalvingUp.skips(p).map_err(|e| anyhow!("{e}"))?;
    let t_h = eval(&halving);
    println!("p={p}, m={m}, model={}", if node > 0 { format!("clustered(node={node})") } else { "homogeneous".into() });
    println!("halving-up {halving:?}: {}s", fmt_si(t_h));
    let (seq, t) = if p <= 24 {
        let (seq, t, n) = exhaustive_best(p, eval);
        println!("exhaustive search over {n} valid sequences:");
        (seq, t)
    } else {
        println!("beam search (width {beam}):");
        beam_search(p, beam, eval)
    };
    println!("best {seq:?}: {}s ({:.3}× vs halving-up)", fmt_si(t), t_h / t);
    Ok(())
}

fn cmd_train(cfg: &Config) -> Result<()> {
    let tc = TrainConfig {
        workers: cfg.get_usize("train.workers", 4)?,
        steps: cfg.get_usize("train.steps", 300)?,
        lr: cfg.get_f64("train.lr", 0.05)? as f32,
        seed: cfg.get_usize("train.seed", 7)? as u64,
        log_every: cfg.get_usize("train.log_every", 20)?,
        pjrt_reduce: cfg.get_str("train.backend", "pjrt") == "pjrt",
        scheme: SkipScheme::parse(cfg.get_str("train.scheme", "halving")).map_err(|e| anyhow!("{e}"))?,
    };
    let report = train(&default_artifact_dir(), &tc)?;
    println!(
        "\ntrained {} params on {} workers × {} steps in {:.2}s",
        report.params, report.workers, report.steps, report.wall_seconds
    );
    println!(
        "loss {:.4} → {:.4}; grad allreduce: {} rounds/step, {} elems/step/worker",
        report.first_loss, report.final_loss, report.rounds_per_allreduce, report.grad_elems_per_step
    );
    Ok(())
}
