//! `ccoll` command-line interface (hand-rolled; clap unavailable offline).
//!
//! Subcommands:
//!   info       platform + artifact + config report, the supported
//!              (op, dtype) kernel matrix, and every CCOLL_* knob
//!   run        execute a collective on the thread network, verify, report
//!              (generic over `run.dtype`: f32|f64|i32|i64|u64)
//!   serve      replay a recorded (or synthesized) mix of collectives
//!              through ONE persistent engine — the serving-path driver
//!              (per-op latency, plan-cache stats, spawn-once assertion)
//!   simulate   α-β-γ DES + closed-form comparison sweep
//!   trace      symbolic round-by-round trace (reproduces the paper's §2.1
//!              p=22 example)
//!   validate   Theorem 1/2 counter + correctness sweep over a p range,
//!              plus an exact data-path check in the configured dtype
//!   train      end-to-end data-parallel training (PJRT compute + Alg 2)
//!   launch     run THIS process as one rank of a multi-process collective
//!              over the Unix-domain-socket transport (`--backend uds`),
//!              or all ranks in-process (`--backend thread`) — the
//!              cross-backend acceptance driver
//!   chaos      engine soak under a seeded, declarative fault plan
//!              (`transport::fault`): kill a rank mid-soak, assert the
//!              RankDown error taxonomy, survivor bit-exactness, the
//!              2×op-timeout hang bound, spawn-once, and drain-mode
//!              shutdown — the robustness acceptance driver
//!   audit      static verification sweep (`crate::analysis`): every
//!              shipped algorithm × p ∈ 1..=audit.max_p × four partition
//!              shapes through all four verifier passes, then the seeded
//!              mutation harness — hard-fails unless every corruption
//!              class is caught with its named diagnostic
//!
//! Global flags: `--config FILE` and `--key value` overrides (see
//! `crate::config`). Unknown `run.op` / `run.algorithm` / `run.dtype`
//! values fail with the full list of valid alternatives.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::analysis;
use crate::collectives::Algorithm;
use crate::config::Config;
use crate::coordinator::{train, Launcher, OpBackend, RunMetrics, TrainConfig};
use crate::datatypes::{elem, BlockPartition, DType, Elem};
use crate::ops::{ReduceOp, SumOp, NATIVE_OP_NAMES, OP_NAMES_HELP};
use crate::runtime::{default_artifact_dir, ComputeService, Manifest};
use crate::sim::{closed_form, simulate};
use crate::topology::skips::SkipScheme;
use crate::util::rng::SplitMix64;
use crate::util::table::{fmt_si, Table};

pub const USAGE: &str = "\
usage: ccoll [--config FILE] [--key value …] <command>

commands:
  info                     show platform, artifacts, resolved config, the
                           supported (op, dtype) kernel matrix, and every
                           CCOLL_* environment knob
  run                      run a collective (keys: run.p run.m run.algorithm
                           run.op run.dtype run.backend run.seed run.verify)
  serve                    replay a mix of collectives through one
                           persistent engine (keys: serve.p serve.ops
                           serve.m serve.inflight serve.seed serve.scheme
                           serve.verify serve.trace|--trace FILE
                           serve.fuse|--fuse serve.json FILE run.dtype
                           run.op engine.queue_depth engine.park
                           engine.fusion.max_bytes engine.fusion.window
                           engine.pipeline.min_bytes
                           engine.pipeline.chunk_bytes)
  simulate                 cost-model sweep (keys: sim.p sim.m cost.alpha
                           cost.beta cost.gamma)
  trace                    symbolic trace (keys: trace.p trace.rank)
  validate                 Theorem 1/2 sweep + exact data-path check
                           (keys: validate.max_p run.dtype)
  search                   skip-sequence search, the paper's §2.1 open
                           question (keys: search.p search.m search.node
                           search.beam)
  train                    E2E data-parallel training (keys: train.workers
                           train.steps train.lr train.backend)
  launch                   one rank of a multi-process collective over UDS
                           (keys: --backend thread|uds --rank R --world P
                           --dir SOCKDIR launch.m launch.seed launch.verify
                           run.dtype transport.backend; thread backend runs
                           every rank in this one process; launch.iters
                           repeats the collective back-to-back; launch.gen
                           joins a generation-namespaced mesh;
                           launch.recover re-forms over the survivors at
                           generation+1 after a peer death and runs
                           launch.recover_iters more verified iterations;
                           launch.timeout_ms tightens the socket recv
                           deadline — the indirect-death detection bound)
  audit                    static schedule verification: sweep every shipped
                           algorithm × p × partition shapes through the
                           structure/dataflow/optimality/aliasing passes,
                           plus the pipelined tier's chunked plans (each
                           distinct chunk partition, remainder folding),
                           then prove the verifier bites via the seeded
                           mutation harness (keys: audit.max_p audit.seeds
                           audit.mutation_p audit.seed audit.json FILE)
  chaos                    fault-injection soak: one persistent engine over
                           fault-wrapped transports, kill a rank mid-run,
                           assert RankDown taxonomy + survivor bit-exactness
                           + no hang beyond 2× the op timeout (keys: chaos.p
                           chaos.ops chaos.m chaos.inflight chaos.seed
                           chaos.timeout_ms chaos.drop_prob chaos.json FILE
                           --kill-rank R --at-op N run.dtype
                           engine.retry.attempts engine.retry.base_ms
                           engine.backpressure_timeout; --chaos.recover
                           reconfigures over the survivors after the kill
                           and resumes the soak at p−1; chaos.flap_rank R
                           chaos.flap_from_op N chaos.flap_down_ops K
                           injects a transient kill-then-revive instead)
";

/// Entry point: parse args, dispatch. Returns the process exit code.
pub fn main_with_args(args: Vec<String>) -> Result<()> {
    let mut cfg = Config::new();
    // --config FILE is processed first so flags can override the file.
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).ok_or_else(|| anyhow!("--config needs a path"))?;
            cfg = Config::from_file(path)?;
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    let positional = cfg.apply_args(&rest)?;
    let cmd = positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&cfg),
        "run" => cmd_run(&cfg),
        "serve" => cmd_serve(&cfg),
        "simulate" => cmd_simulate(&cfg),
        "trace" => cmd_trace(&cfg),
        "validate" => cmd_validate(&cfg),
        "search" => cmd_search(&cfg),
        "train" => cmd_train(&cfg),
        "launch" => cmd_launch(&cfg),
        "chaos" => cmd_chaos(&cfg),
        "audit" => cmd_audit(&cfg),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_info(cfg: &Config) -> Result<()> {
    println!("circulant-collectives — Träff 2024 reproduction (see DESIGN.md)");
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} modules, buckets {:?}, jax-built)", dir.display(), m.artifacts.len(), m.buckets);
            println!("mlp: {} params ({}→{}→{}→{}, batch {})", m.mlp.params, m.mlp.d_in, m.mlp.hidden, m.mlp.hidden, m.mlp.d_out, m.mlp.batch);
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    // The supported (op, dtype) kernel matrix, derived from DType::ALL so
    // a newly added dtype can never leave this table stale: native
    // kernels are monomorphized per (op, dtype); the PJRT Pallas
    // artifacts are compiled for f32 only.
    let cols: Vec<String> =
        DType::ALL.iter().map(|d| format!("{} ({}B)", d.name(), d.size_bytes())).collect();
    let mut header: Vec<&str> = vec!["op"];
    header.extend(cols.iter().map(String::as_str));
    header.push("pjrt");
    let mut t = Table::new("kernel matrix (op × dtype)", &header);
    for op in NATIVE_OP_NAMES {
        let mut cells: Vec<String> = vec![op.to_string()];
        cells.extend(DType::ALL.iter().map(|_| "native".to_string()));
        cells.push("f32 only".into());
        t.row(&cells);
    }
    t.print();
    println!("integer ⊕ is wrapping (exactly associative — bit-exact oracles);");
    println!("float ⊕ is IEEE (non-associative — fixed-schedule reproducibility only).");
    // The registered transport backends and their capability flags, the
    // same enumerate-from-the-registry discipline as the kernel matrix:
    // a newly added backend can never leave this table stale. The
    // executor consults exactly these flags when picking a copy tier
    // (rendezvous → pooled → framed copy).
    let active = crate::env_knobs::knobs().transport_backend;
    let mut bt = Table::new(
        "transport backends (capability flags)",
        &["backend", "rendezvous", "loaned buffers", "max inline", "active"],
    );
    for b in crate::transport::backends() {
        let caps = b.caps();
        bt.row(&[
            b.name().to_string(),
            if caps.supports_rendezvous { "yes (zero-copy tier)".into() } else { "no".into() },
            if caps.supports_loaned_buffers { "yes (pooled tier)".into() } else { "no".into() },
            if caps.max_inline_bytes == usize::MAX {
                "unbounded".into()
            } else {
                caps.max_inline_bytes.to_string()
            },
            if *b == active { "← CCOLL_TRANSPORT".into() } else { String::new() },
        ]);
    }
    bt.print();
    // Every CCOLL_* knob with its resolved value (parsed once per process
    // by env_knobs; malformed values abort before we get here).
    let k = crate::env_knobs::knobs();
    let mut kt = Table::new("environment knobs (CCOLL_*)", &["knob", "value", "meaning"]);
    kt.row(&[
        "CCOLL_NO_RENDEZVOUS".into(),
        if k.rendezvous_enabled { "0 (rendezvous on)".into() } else { "1 (rendezvous OFF)".into() },
        "kill-switch for the zero-copy transport tier".into(),
    ]);
    kt.row(&[
        "CCOLL_RENDEZVOUS_MIN_ELEMS".into(),
        k.rendezvous_min_elems.to_string(),
        "min payload (elems) for a rendezvous publish".into(),
    ]);
    kt.row(&[
        "CCOLL_BENCH_FAST".into(),
        if k.bench_fast { "1".into() } else { "0".into() },
        "shrink bench sweeps for smoke runs".into(),
    ]);
    kt.row(&[
        "CCOLL_BENCH_DTYPE".into(),
        k.bench_dtype.name().to_string(),
        "element type of the T1/T2 benches".into(),
    ]);
    kt.row(&[
        "CCOLL_PJRT_CHUNK".into(),
        k.pjrt_chunk.map_or("unset".to_string(), |v| v.to_string()),
        "PJRT combine chunk-bucket override (elems)".into(),
    ]);
    kt.row(&[
        "CCOLL_ENGINE_QUEUE_DEPTH".into(),
        if k.engine_queue_depth == 0 {
            "0 (unbounded)".into()
        } else {
            k.engine_queue_depth.to_string()
        },
        "max in-flight engine ops before submit parks".into(),
    ]);
    kt.row(&[
        "CCOLL_ENGINE_PARK".into(),
        k.engine_park.name().to_string(),
        format!("engine worker wait strategy ({})", crate::engine::ParkPolicy::NAMES_HELP),
    ]);
    kt.row(&[
        "CCOLL_FUSION_MAX_BYTES".into(),
        k.fusion_max_bytes.to_string(),
        "fusion-tier batch byte budget (larger ops bypass)".into(),
    ]);
    kt.row(&[
        "CCOLL_FUSION_WINDOW".into(),
        k.fusion_window.to_string(),
        "fusion flush window in completed engine steps (0 = off)".into(),
    ]);
    kt.row(&[
        "CCOLL_PIPELINE_MIN_BYTES".into(),
        k.pipeline_min_bytes.to_string(),
        "min allreduce payload for the pipelined tier (0 = off)".into(),
    ]);
    kt.row(&[
        "CCOLL_PIPELINE_CHUNK_BYTES".into(),
        k.pipeline_chunk_bytes.to_string(),
        "chunk-epoch size of the pipelined tier (0 = off)".into(),
    ]);
    kt.row(&[
        "CCOLL_TRANSPORT".into(),
        k.transport_backend.name().to_string(),
        format!(
            "default transport backend ({})",
            crate::transport::TransportBackend::NAMES_HELP
        ),
    ]);
    kt.row(&[
        "CCOLL_RETRY_ATTEMPTS".into(),
        k.retry_attempts.to_string(),
        "transient-send retries before a peer is declared down (0 = fail fast)".into(),
    ]);
    kt.row(&[
        "CCOLL_RETRY_BASE_MS".into(),
        k.retry_base_ms.to_string(),
        "base backoff between send retries (doubles per attempt)".into(),
    ]);
    kt.row(&[
        "CCOLL_HEARTBEAT_MS".into(),
        if k.heartbeat_ms == 0 {
            "0 (heartbeats off)".into()
        } else {
            k.heartbeat_ms.to_string()
        },
        "UDS liveness probe interval; 4× silence declares the peer dead".into(),
    ]);
    kt.row(&[
        "CCOLL_RECONNECT_ATTEMPTS".into(),
        if k.reconnect_attempts == 0 {
            "0 (reconnect off)".into()
        } else {
            k.reconnect_attempts.to_string()
        },
        "UDS reconnects before a lost peer is declared dead (not flapping)".into(),
    ]);
    kt.row(&[
        "CCOLL_RECONNECT_BASE_MS".into(),
        k.reconnect_base_ms.to_string(),
        "base backoff between reconnect attempts (doubles per attempt)".into(),
    ]);
    kt.row(&[
        "CCOLL_ENGINE_BACKPRESSURE_TIMEOUT".into(),
        format!("{}s", k.engine_backpressure_timeout_secs),
        "max wait for a queue slot before submit fails loudly".into(),
    ]);
    kt.row(&[
        "CCOLL_AUDIT_PLANS".into(),
        if k.audit_plans { "1".into() } else { "0".into() },
        "audit every built plan in release too (debug always audits)".into(),
    ]);
    kt.print();
    let n: usize = cfg.entries().count();
    if n > 0 {
        println!("config:");
        for (k, v) in cfg.entries() {
            println!("  {k} = {v}");
        }
    }
    Ok(())
}

fn cmd_run(cfg: &Config) -> Result<()> {
    match cfg.dtype()? {
        DType::F32 => cmd_run_typed::<f32>(cfg),
        DType::F64 => cmd_run_typed::<f64>(cfg),
        DType::I32 => cmd_run_typed::<i32>(cfg),
        DType::I64 => cmd_run_typed::<i64>(cfg),
        DType::U64 => cmd_run_typed::<u64>(cfg),
    }
}

fn cmd_run_typed<T: Elem>(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("run.p", 8)?;
    let m = cfg.get_usize("run.m", 1 << 16)?;
    let alg = cfg.algorithm()?;
    let op_name = cfg.get_str("run.op", "sum").to_string();
    let backend_name = cfg.get_str("run.backend", "native").to_string();
    let seed = cfg.get_usize("run.seed", 1)? as u64;
    let verify = cfg.get_bool("run.verify", true)?;

    if !NATIVE_OP_NAMES.contains(&op_name.as_str()) {
        bail!("unknown run.op {op_name:?} (valid: {OP_NAMES_HELP})");
    }

    let _service; // keep the compute service alive for the whole run
    let backend = match backend_name.as_str() {
        "native" => OpBackend::Native,
        "pjrt" => {
            if T::DTYPE != DType::F32 {
                bail!(
                    "run.backend=pjrt supports run.dtype=f32 only (the AOT Pallas \
                     kernels are compiled for f32); got run.dtype={} — use \
                     run.backend=native for other dtypes",
                    T::DTYPE.name()
                );
            }
            let svc = ComputeService::start(default_artifact_dir(), vec![op_name.clone()], false, false)?;
            let h = svc.handle.clone();
            _service = svc;
            OpBackend::Pjrt(h)
        }
        other => bail!("unknown run.backend {other:?} (valid: native|pjrt)"),
    };

    let part = BlockPartition::regular(p, m);
    let sched = alg.schedule(p);
    sched.assert_valid();

    // Small-integer-valued inputs so sums verify exactly in every dtype
    // (float sums stay within the exactly-representable range; integer
    // sums are wrapping and exact by construction).
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<T>> = (0..p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut oracle = vec![T::zero(); m];
    for v in &inputs {
        SumOp.combine(&mut oracle, v);
    }

    let sched2 = Arc::new(sched);
    let part2 = Arc::new(part.clone());
    let inputs2 = Arc::new(std::sync::Mutex::new(inputs.into_iter().map(Some).collect::<Vec<_>>()));
    let op2 = op_name.clone();
    let sched3 = sched2.clone();
    let t0 = std::time::Instant::now();
    let results = Launcher::new(p).backend(backend).run_typed::<T, _, _>(move |mut comm| {
        let mut buf = inputs2.lock().unwrap()[comm.rank()].take().unwrap();
        comm.run_schedule(&sched3, &part2, &op2, &mut buf).expect("collective");
        (buf, comm.counters())
    });
    let wall = t0.elapsed().as_secs_f64();

    let metrics = RunMetrics {
        algorithm: alg.name(),
        dtype: T::DTYPE.name().to_string(),
        p,
        m,
        wall_seconds: wall,
        per_rank: results.iter().map(|(_, c)| c.clone()).collect(),
    };
    metrics.summary_table().print();

    if verify && op_name == "sum" {
        let part = BlockPartition::regular(p, m);
        let mut ok = true;
        for (r, (buf, _)) in results.iter().enumerate() {
            let good = if alg.is_allreduce() {
                buf[..] == oracle[..]
            } else if alg.is_reduce_scatter() {
                buf[part.range(r)] == oracle[part.range(r)]
            } else {
                true
            };
            if !good {
                eprintln!("VERIFY FAILED at rank {r}");
                ok = false;
            }
        }
        if ok {
            println!("verify: OK (exact match vs scalar oracle, dtype {})", T::DTYPE.name());
        } else {
            bail!("verification failed");
        }
    }
    Ok(())
}

/// One replayed operation of the serve trace.
#[derive(Debug, Clone)]
struct TraceOp {
    /// `true` = allreduce, `false` = reduce-scatter (regular partition).
    allreduce: bool,
    m: usize,
    op: String,
}

/// Parse a recorded trace: one op per line, `<kind> <m> [op]` with kind ∈
/// `allreduce|ar|reduce-scatter|rs`, `#` comments and blank lines ignored.
fn parse_trace(path: &str) -> Result<Vec<TraceOp>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read trace {path}: {e}"))?;
    let mut ops = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut fields = line.split_whitespace();
        let kind = fields.next().unwrap();
        let allreduce = match kind {
            "allreduce" | "ar" => true,
            "reduce-scatter" | "rs" => false,
            other => bail!(
                "trace {path}:{}: unknown kind {other:?} (valid: allreduce|ar|reduce-scatter|rs)",
                ln + 1
            ),
        };
        let m: usize = fields
            .next()
            .ok_or_else(|| anyhow!("trace {path}:{}: missing element count", ln + 1))?
            .replace('_', "")
            .parse()
            .map_err(|_| anyhow!("trace {path}:{}: bad element count", ln + 1))?;
        let op = fields.next().unwrap_or("sum").to_string();
        if !NATIVE_OP_NAMES.contains(&op.as_str()) {
            bail!("trace {path}:{}: unknown op {op:?} (valid: {OP_NAMES_HELP})", ln + 1);
        }
        if let Some(extra) = fields.next() {
            bail!("trace {path}:{}: trailing field {extra:?} (format: <kind> <m> [op])", ln + 1);
        }
        ops.push(TraceOp { allreduce, m, op });
    }
    if ops.is_empty() {
        bail!("trace {path}: no operations");
    }
    Ok(ops)
}

/// Deterministic synthetic mix when no trace file is given: alternating
/// allreduce/reduce-scatter over a few payload sizes and ⊕ names.
fn synth_mix(n: usize, m: usize, base_op: &str, seed: u64) -> Vec<TraceOp> {
    let mut rng = SplitMix64::new(seed);
    let sizes = [m.max(1), (m / 2).max(1), (m / 4).max(1)];
    let ops = [base_op, "max"];
    (0..n)
        .map(|_| TraceOp {
            allreduce: rng.next_below(2) == 0,
            m: sizes[rng.next_below(sizes.len())],
            op: ops[rng.next_below(ops.len())].to_string(),
        })
        .collect()
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    match cfg.dtype()? {
        DType::F32 => cmd_serve_typed::<f32>(cfg),
        DType::F64 => cmd_serve_typed::<f64>(cfg),
        DType::I32 => cmd_serve_typed::<i32>(cfg),
        DType::I64 => cmd_serve_typed::<i64>(cfg),
        DType::U64 => cmd_serve_typed::<u64>(cfg),
    }
}

/// The serving-path replay driver: ONE persistent engine, a window of
/// in-flight operations, per-op latency accounting, and a hard assertion
/// that the whole replay spawned exactly `p` rank threads (spawn-once).
fn cmd_serve_typed<T: Elem>(cfg: &Config) -> Result<()> {
    use crate::engine::{CollectiveEngine, EngineConfig, OpHandle, OpRequest, ParkPolicy};
    use std::collections::VecDeque;
    use std::time::Instant;

    let p = cfg.get_usize("serve.p", 8)?;
    let n_ops = cfg.get_usize("serve.ops", 1000)?;
    let m = cfg.get_usize("serve.m", 1024)?;
    let inflight = cfg.get_usize("serve.inflight", 8)?.max(1);
    let seed = cfg.get_usize("serve.seed", 1)? as u64;
    let verify = cfg.get_bool("serve.verify", true)?;
    let base_op = cfg.get_str("run.op", "sum").to_string();
    if !NATIVE_OP_NAMES.contains(&base_op.as_str()) {
        bail!("unknown run.op {base_op:?} (valid: {OP_NAMES_HELP})");
    }
    let scheme = SkipScheme::parse(cfg.get_str("serve.scheme", "halving"))
        .map_err(|e| anyhow!("{e}"))?;
    let knobs = crate::env_knobs::knobs();
    let queue_depth = cfg.get_usize("engine.queue_depth", knobs.engine_queue_depth)?;
    let park_name = cfg.get_str("engine.park", knobs.engine_park.name());
    let park = ParkPolicy::parse(park_name).ok_or_else(|| {
        anyhow!("unknown engine.park {park_name:?} (valid: {})", ParkPolicy::NAMES_HELP)
    })?;
    // `serve --fuse` (bare flag) or `--serve.fuse 1`: run the replay
    // through the engine's fusion tier (batch compatible small ops into
    // one circulant run per batch).
    let fuse = cfg.get_bool("serve.fuse", cfg.get_bool("fuse", false)?)?;
    let fusion_max_bytes = cfg.get_usize("engine.fusion.max_bytes", knobs.fusion_max_bytes)?;
    let fusion_window =
        cfg.get_usize("engine.fusion.window", knobs.fusion_window as usize)? as u64;
    if fuse && fusion_window == 0 {
        bail!(
            "--fuse with engine.fusion.window 0 never fuses anything \
             (window 0 disables fusion)"
        );
    }
    let pipeline_min_bytes =
        cfg.get_usize("engine.pipeline.min_bytes", knobs.pipeline_min_bytes)?;
    let pipeline_chunk_bytes =
        cfg.get_usize("engine.pipeline.chunk_bytes", knobs.pipeline_chunk_bytes)?;
    let retry_attempts = cfg.get_usize("engine.retry.attempts", knobs.retry_attempts)?;
    let retry_base_ms = cfg.get_usize("engine.retry.base_ms", knobs.retry_base_ms as usize)? as u64;
    let backpressure_secs = cfg.get_usize(
        "engine.backpressure_timeout",
        knobs.engine_backpressure_timeout_secs as usize,
    )? as u64;

    // `serve --trace FILE` (the bare --trace flag) or `--serve.trace FILE`.
    let trace_path = cfg.get("serve.trace").or_else(|| cfg.get("trace"));
    let trace = match trace_path {
        Some(path) if path != "true" => parse_trace(path)?,
        Some(_) => bail!("--trace needs a file path (or use --serve.trace FILE)"),
        None => synth_mix(n_ops, m, &base_op, seed),
    };
    if trace.is_empty() {
        bail!("serve: nothing to replay (serve.ops = 0?)");
    }

    println!(
        "serve: p={p}, {} ops ({}), window={inflight}, dtype={}, scheme={}, \
         queue_depth={queue_depth}, park={}, fusion={}",
        trace.len(),
        trace_path.map_or_else(|| format!("synthetic mix, seed {seed}"), |t| format!("trace {t}")),
        T::DTYPE.name(),
        scheme.name(),
        park.name(),
        if fuse {
            format!("on (budget {fusion_max_bytes} B, window {fusion_window} steps)")
        } else {
            "off".to_string()
        },
    );

    let spawned_before = crate::transport::rank_threads_spawned();
    let mut engine = CollectiveEngine::<T>::new(
        EngineConfig::new(p)
            .scheme(scheme.clone())
            .queue_depth(queue_depth)
            .park(park)
            .fusion(fuse)
            .fusion_max_bytes(fusion_max_bytes)
            .fusion_window(fusion_window)
            .pipeline_min_bytes(pipeline_min_bytes)
            .pipeline_chunk_bytes(pipeline_chunk_bytes)
            .retry(retry_attempts, retry_base_ms)
            .backpressure_timeout(std::time::Duration::from_secs(backpressure_secs)),
    );

    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed ^ 0x5e3e);
    // (submit time, handle, oracle, op) — popped in submission order once
    // the window fills; per-op latency is submit→wait-complete.
    let mut pending: VecDeque<(Instant, OpHandle<T>, Option<Vec<T>>, TraceOp)> =
        VecDeque::with_capacity(inflight);
    let mut latencies: Vec<f64> = Vec::with_capacity(trace.len());
    let mut verified_ops = 0usize;
    let mut drain_one = |pending: &mut VecDeque<(Instant, OpHandle<T>, Option<Vec<T>>, TraceOp)>,
                         latencies: &mut Vec<f64>|
     -> Result<()> {
        let (t_submit, handle, oracle, top) = pending.pop_front().expect("nonempty window");
        let out = handle.wait().map_err(|e| anyhow!("serve op failed: {e}"))?;
        latencies.push(t_submit.elapsed().as_secs_f64());
        if let Some(want) = oracle {
            verified_ops += 1;
            let part = BlockPartition::regular(p, top.m);
            for (r, buf) in out.iter().enumerate() {
                let good = if top.allreduce {
                    buf[..] == want[..]
                } else {
                    buf[part.range(r)] == want[part.range(r)]
                };
                if !good {
                    bail!(
                        "serve VERIFY FAILED: {} m={} op={} rank {r}",
                        if top.allreduce { "allreduce" } else { "reduce-scatter" },
                        top.m,
                        top.op
                    );
                }
            }
        }
        Ok(())
    };

    let t0 = Instant::now();
    for top in &trace {
        let inputs: Vec<Vec<T>> = (0..p).map(|_| elem::int_vec(&mut rng, top.m, lo, hi)).collect();
        let oracle = if verify && top.op == "sum" {
            let mut acc = vec![T::zero(); top.m];
            for v in &inputs {
                SumOp.combine(&mut acc, v);
            }
            Some(acc)
        } else {
            None
        };
        let req = if top.allreduce {
            OpRequest::allreduce(inputs, &top.op)
        } else {
            OpRequest::reduce_scatter(inputs, &top.op)
        };
        let handle = engine.submit(req).map_err(|e| anyhow!("submit failed: {e}"))?;
        pending.push_back((Instant::now(), handle, oracle, top.clone()));
        if pending.len() >= inflight {
            drain_one(&mut pending, &mut latencies)?;
        }
    }
    while !pending.is_empty() {
        drain_one(&mut pending, &mut latencies)?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.plan_stats();
    let fstats = engine.fusion_stats();
    // Recovery-state surface: a plain serve never reconfigures, so these
    // report generation 0 / all-up — the point is that CI can diff them
    // and a chaos-recovered engine reports the same fields truthfully.
    let generation = engine.generation();
    let recovered_ops = engine.recovered_ops();
    let peer_health = engine.peer_health();
    engine.shutdown();
    let stale_frames_dropped = engine.stale_frames_dropped();

    // Spawn-once assertion: the whole replay must have created exactly the
    // p engine workers — any per-op thread spawn is a serving regression.
    let spawned = crate::transport::rank_threads_spawned() - spawned_before;
    if spawned != p as u64 {
        bail!(
            "engine spawned {spawned} rank threads over {} ops (want exactly {p}: \
             spawn-once violated — something spawns per operation)",
            trace.len()
        );
    }

    let lat = crate::util::stats::Summary::of(&latencies);
    let ops_per_sec = trace.len() as f64 / wall;
    let mut t = Table::new(
        "serve replay",
        &[
            "ops", "wall s", "ops/s", "lat mean", "lat p50", "lat p95", "lat p99",
            "plan hit/miss", "threads",
        ],
    );
    t.row(&[
        trace.len().to_string(),
        format!("{wall:.3}"),
        fmt_si(ops_per_sec),
        format!("{}s", fmt_si(lat.mean)),
        format!("{}s", fmt_si(lat.median)),
        format!("{}s", fmt_si(lat.p95)),
        format!("{}s", fmt_si(lat.p99)),
        format!("{}/{}", stats.hits, stats.misses),
        format!("{spawned} (= p ✓)"),
    ]);
    t.print();
    if fuse {
        println!(
            "fusion: {} batches fusing {} ops (avg {:.1}/batch, {} B packed), \
             {} singles, {} large + {} counts bypassed, fused-plan hit/miss {}/{}",
            fstats.batches,
            fstats.fused_ops,
            fstats.avg_batch(),
            fstats.fused_bytes,
            fstats.single_flushes,
            fstats.bypass_large,
            fstats.bypass_kind,
            fstats.plan_hits,
            fstats.plan_misses,
        );
    }
    if fstats.pipelined_ops > 0 {
        println!(
            "pipeline: {} ops over {pipeline_min_bytes} B dispatched chunked \
             ({pipeline_chunk_bytes} B chunks)",
            fstats.pipelined_ops,
        );
    }
    if verify && verified_ops == 0 {
        println!(
            "serve: note — verification is on but the mix contained no sum ops, \
             so no result was oracle-checked"
        );
    }

    // Machine-readable report (serve.json FILE): latency percentiles,
    // plan + fusion stats — what CI diffs across soaks.
    if let Some(path) = cfg.get("serve.json") {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut fusion = BTreeMap::new();
        fusion.insert("enabled".to_string(), Json::Bool(fuse));
        fusion.insert("batches".to_string(), Json::Num(fstats.batches as f64));
        fusion.insert("fused_ops".to_string(), Json::Num(fstats.fused_ops as f64));
        fusion.insert("avg_batch".to_string(), Json::Num(fstats.avg_batch()));
        fusion.insert("fused_bytes".to_string(), Json::Num(fstats.fused_bytes as f64));
        fusion.insert("single_flushes".to_string(), Json::Num(fstats.single_flushes as f64));
        fusion.insert("bypass_large".to_string(), Json::Num(fstats.bypass_large as f64));
        fusion.insert("bypass_kind".to_string(), Json::Num(fstats.bypass_kind as f64));
        fusion.insert("plan_hits".to_string(), Json::Num(fstats.plan_hits as f64));
        fusion.insert("plan_misses".to_string(), Json::Num(fstats.plan_misses as f64));
        fusion.insert("flush_budget".to_string(), Json::Num(fstats.flush_budget as f64));
        fusion.insert("flush_window".to_string(), Json::Num(fstats.flush_window as f64));
        fusion.insert(
            "flush_incompatible".to_string(),
            Json::Num(fstats.flush_incompatible as f64),
        );
        fusion.insert("flush_forced".to_string(), Json::Num(fstats.flush_forced as f64));
        fusion.insert("pipelined_ops".to_string(), Json::Num(fstats.pipelined_ops as f64));
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Num(1.0));
        obj.insert("kind".to_string(), Json::Str("serve".to_string()));
        obj.insert("p".to_string(), Json::Num(p as f64));
        obj.insert("ops".to_string(), Json::Num(trace.len() as f64));
        obj.insert("dtype".to_string(), Json::Str(T::DTYPE.name().to_string()));
        obj.insert("scheme".to_string(), Json::Str(scheme.name()));
        obj.insert("wall_seconds".to_string(), Json::Num(wall));
        obj.insert("ops_per_sec".to_string(), Json::Num(ops_per_sec));
        obj.insert("lat_mean_s".to_string(), Json::Num(lat.mean));
        obj.insert("lat_p50_s".to_string(), Json::Num(lat.median));
        obj.insert("lat_p95_s".to_string(), Json::Num(lat.p95));
        obj.insert("lat_p99_s".to_string(), Json::Num(lat.p99));
        obj.insert("lat_max_s".to_string(), Json::Num(lat.max));
        obj.insert("plan_hits".to_string(), Json::Num(stats.hits as f64));
        obj.insert("plan_misses".to_string(), Json::Num(stats.misses as f64));
        obj.insert("verified_ops".to_string(), Json::Num(verified_ops as f64));
        obj.insert("rank_threads_spawned".to_string(), Json::Num(spawned as f64));
        obj.insert("generations".to_string(), Json::Num(generation as f64));
        obj.insert("recovered_ops".to_string(), Json::Num(recovered_ops as f64));
        obj.insert(
            "stale_frames_dropped".to_string(),
            Json::Num(stale_frames_dropped as f64),
        );
        obj.insert(
            "peer_health".to_string(),
            Json::Arr(peer_health.iter().map(|&up| Json::Bool(up)).collect()),
        );
        obj.insert("fusion".to_string(), Json::Obj(fusion));
        std::fs::write(path, Json::Obj(obj).render() + "\n")
            .map_err(|e| anyhow!("cannot write serve.json {path}: {e}"))?;
        println!("serve: wrote {path}");
    }

    // Fusion soak gate: a long fused replay that never formed a batch or
    // never hit a fused plan would silently measure the unfused path —
    // fail loudly instead (short replays are exempt; a tiny trace may
    // legitimately have no compatible pair).
    if fuse && trace.len() >= 200 {
        if fstats.batches == 0 {
            bail!(
                "fusion soak: no fused batches formed over {} ops — \
                 incompatible mix or mis-set budget/window?",
                trace.len()
            );
        }
        if fstats.plan_hits == 0 {
            bail!(
                "fusion soak: {} fused batches but zero fused-plan cache hits — \
                 every batch shape was unique, the plan cache is not amortizing",
                fstats.batches
            );
        }
    }
    println!(
        "serve: OK — {} ops through one engine, {} plan-cache hits{}, spawn-once verified{}",
        trace.len(),
        stats.hits,
        if fuse { format!(", {} fused batches", fstats.batches) } else { String::new() },
        if verified_ops > 0 {
            format!(", {verified_ops} sum ops verified exactly")
        } else {
            String::new()
        }
    );
    Ok(())
}

fn cmd_simulate(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("sim.p", 1000)?;
    let m = cfg.get_usize("sim.m", 1 << 20)?;
    let model = cfg.cost_model()?;
    println!("cost model: α={:.2e}s β={:.2e}s/elem γ={:.2e}s/elem", model.alpha, model.beta, model.gamma);
    let part = BlockPartition::regular(p, m);
    let mut t = Table::new(
        &format!("simulated allreduce, p={p}, m={m}"),
        &["algorithm", "rounds", "DES time", "closed form"],
    );
    for alg in Algorithm::allreduce_family() {
        let sched = alg.schedule(p);
        let sim = simulate(&sched, &part, &model);
        let cf = match &alg {
            Algorithm::CirculantAllreduce(_) => closed_form::alg2_allreduce(&model, p, m),
            Algorithm::RingAllreduce => closed_form::ring_allreduce(&model, p, m),
            Algorithm::RecursiveDoublingAllreduce => {
                closed_form::recursive_doubling_allreduce(&model, p, m)
            }
            Algorithm::RabenseifnerAllreduce => closed_form::rabenseifner_allreduce(&model, p, m),
            _ => closed_form::binomial_allreduce(&model, p, m),
        };
        t.row(&[
            alg.name(),
            sim.rounds.to_string(),
            format!("{}s", fmt_si(sim.total)),
            format!("{}s", fmt_si(cf)),
        ]);
    }
    t.print();
    let (best, tbest) = crate::coordinator::select_allreduce(&model, p, m);
    println!("selector: {} predicted {}s", best.name(), fmt_si(tbest));
    Ok(())
}

fn cmd_trace(cfg: &Config) -> Result<()> {
    let p = cfg.get_usize("trace.p", 22)?;
    let r = cfg.get_usize("trace.rank", p - 1)?;
    let scheme = SkipScheme::parse(cfg.get_str("trace.scheme", "halving")).map_err(|e| anyhow!("{e}"))?;
    let skips = scheme.skips(p).map_err(|e| anyhow!("{e}"))?;
    println!("p={p}, rank={r}, scheme={}, skips={skips:?} (⌈log2 {p}⌉={} rounds)", scheme.name(), skips.len());
    let sched = crate::collectives::reduce_scatter_schedule(p, &skips);
    println!("from-processors of rank {r}: {:?}", skips.iter().map(|s| (r + p - s) % p).collect::<Vec<_>>());
    let terms = analysis::paper_example_terms(&sched, r);
    println!("\nW at rank {r} accumulates (x_i = input block of processor i for {r}):");
    println!("  W = {}", terms[0]);
    for (k, t) in terms[1..].iter().enumerate() {
        println!("    + {t}   (round {})", k + 1);
    }
    let depth = analysis::verify_reduce_scatter(&sched).map_err(|e| anyhow!("{e}"))?;
    println!("\nsymbolic check: every contributor exactly once at every rank ✓ (max tree depth {depth})");
    Ok(())
}

fn cmd_validate(cfg: &Config) -> Result<()> {
    let max_p = cfg.get_usize("validate.max_p", 128)?;
    // Parse the dtype up front: a typo must fail before the sweep runs,
    // not after minutes of counter/symbolic work.
    let dtype = cfg.dtype()?;
    let mut bad = 0usize;
    for p in 1..=max_p {
        for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
            let skips = scheme.skips(p).map_err(|e| anyhow!("{e}"))?;
            if p >= 2 {
                let rs = crate::collectives::reduce_scatter_schedule(p, &skips);
                rs.assert_valid();
                let part = BlockPartition::uniform(p, 1);
                for c in rs.counters(&part) {
                    if c.blocks_sent != p - 1 || c.blocks_combined != p - 1 {
                        eprintln!("FAIL p={p} {}: counters {c:?}", scheme.name());
                        bad += 1;
                    }
                }
                if analysis::verify_reduce_scatter(&rs).is_err() {
                    eprintln!("FAIL p={p} {}: symbolic", scheme.name());
                    bad += 1;
                }
            }
        }
    }
    if bad != 0 {
        bail!("{bad} validation failures");
    }
    println!("validate: PASS — Theorem 1 counters + symbolic correctness for p ≤ {max_p} × 3 schemes");
    // Data-path check in the configured dtype: small thread-network runs
    // against an exact scalar oracle (wrapping-integer arithmetic makes
    // this bit-exact for integer dtypes; small-integer values keep float
    // sums exact too).
    match dtype {
        DType::F32 => validate_data_path::<f32>(),
        DType::F64 => validate_data_path::<f64>(),
        DType::I32 => validate_data_path::<i32>(),
        DType::I64 => validate_data_path::<i64>(),
        DType::U64 => validate_data_path::<u64>(),
    }
}

fn validate_data_path<T: Elem>() -> Result<()> {
    use crate::collectives::{allreduce_schedule, reduce_scatter_schedule, run_schedule_threads_typed};
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    for p in [2usize, 3, 5, 9] {
        let part = BlockPartition::regular(p, 4 * p + 3);
        let skips = SkipScheme::HalvingUp.skips(p).map_err(|e| anyhow!("{e}"))?;
        let mut rng = SplitMix64::new(77 + p as u64);
        let inputs: Vec<Vec<T>> =
            (0..p).map(|_| elem::int_vec(&mut rng, part.total(), lo, hi)).collect();
        let mut oracle = vec![T::zero(); part.total()];
        for v in &inputs {
            SumOp.combine(&mut oracle, v);
        }
        let op: Arc<dyn ReduceOp<T>> = Arc::new(SumOp);
        let rs = run_schedule_threads_typed::<T>(
            &reduce_scatter_schedule(p, &skips),
            &part,
            op.clone(),
            inputs.clone(),
        );
        for (r, buf) in rs.iter().enumerate() {
            let range = part.range(r);
            if buf[range.clone()] != oracle[range] {
                bail!("data-path FAIL: reduce-scatter p={p} rank {r} ({})", T::DTYPE.name());
            }
        }
        let ar = run_schedule_threads_typed::<T>(
            &allreduce_schedule(p, &skips),
            &part,
            op,
            inputs,
        );
        for (r, buf) in ar.iter().enumerate() {
            if buf[..] != oracle[..] {
                bail!("data-path FAIL: allreduce p={p} rank {r} ({})", T::DTYPE.name());
            }
        }
    }
    println!("validate: data path OK — exact oracle match in dtype {}", T::DTYPE.name());
    Ok(())
}

fn cmd_search(cfg: &Config) -> Result<()> {
    use crate::collectives::reduce_scatter_schedule;
    use crate::sim::hier::{simulate_hier, HierModel};
    use crate::sim::CostModel;
    use crate::topology::search::{beam_search, exhaustive_best};

    let p = cfg.get_usize("search.p", 22)?;
    let m = cfg.get_usize("search.m", 4096 * p)?;
    let node = cfg.get_usize("search.node", 0)?; // 0 = homogeneous model
    let beam = cfg.get_usize("search.beam", 64)?;
    let part = BlockPartition::regular(p, m);
    let model = cfg.cost_model()?;

    let eval = |seq: &[usize]| -> f64 {
        let sched = reduce_scatter_schedule(p, seq);
        if node > 0 {
            let hm = HierModel { node_size: node, intra: model, inter: CostModel::new(model.alpha * 10.0, model.beta * 4.0, model.gamma) };
            simulate_hier(&sched, &part, &hm).total
        } else {
            simulate(&sched, &part, &model).total
        }
    };
    let halving = SkipScheme::HalvingUp.skips(p).map_err(|e| anyhow!("{e}"))?;
    let t_h = eval(&halving);
    println!("p={p}, m={m}, model={}", if node > 0 { format!("clustered(node={node})") } else { "homogeneous".into() });
    println!("halving-up {halving:?}: {}s", fmt_si(t_h));
    let (seq, t) = if p <= 24 {
        let (seq, t, n) = exhaustive_best(p, eval);
        println!("exhaustive search over {n} valid sequences:");
        (seq, t)
    } else {
        println!("beam search (width {beam}):");
        beam_search(p, beam, eval)
    };
    println!("best {seq:?}: {}s ({:.3}× vs halving-up)", fmt_si(t), t_h / t);
    Ok(())
}

fn cmd_train(cfg: &Config) -> Result<()> {
    let tc = TrainConfig {
        workers: cfg.get_usize("train.workers", 4)?,
        steps: cfg.get_usize("train.steps", 300)?,
        lr: cfg.get_f64("train.lr", 0.05)? as f32,
        seed: cfg.get_usize("train.seed", 7)? as u64,
        log_every: cfg.get_usize("train.log_every", 20)?,
        pjrt_reduce: cfg.get_str("train.backend", "pjrt") == "pjrt",
        scheme: SkipScheme::parse(cfg.get_str("train.scheme", "halving")).map_err(|e| anyhow!("{e}"))?,
    };
    let report = train(&default_artifact_dir(), &tc)?;
    println!(
        "\ntrained {} params on {} workers × {} steps in {:.2}s",
        report.params, report.workers, report.steps, report.wall_seconds
    );
    println!(
        "loss {:.4} → {:.4}; grad allreduce: {} rounds/step, {} elems/step/worker",
        report.first_loss, report.final_loss, report.rounds_per_allreduce, report.grad_elems_per_step
    );
    Ok(())
}

fn cmd_launch(cfg: &Config) -> Result<()> {
    match cfg.dtype()? {
        DType::F32 => cmd_launch_typed::<f32>(cfg),
        DType::F64 => cmd_launch_typed::<f64>(cfg),
        DType::I32 => cmd_launch_typed::<i32>(cfg),
        DType::I64 => cmd_launch_typed::<i64>(cfg),
        DType::U64 => cmd_launch_typed::<u64>(cfg),
    }
}

/// The multi-process bootstrap driver: run this process as ONE rank of a
/// p-process allreduce over the Unix-domain-socket transport, verify the
/// result against the scalar sum oracle AND against an in-process
/// thread-backend run of the same schedule (bit-identity — the schedule
/// fixes the ⊕ association, so only the wire differs between backends).
/// Every process regenerates all p ranks' inputs deterministically from
/// the seed, so no input distribution step is needed. With
/// `--backend thread` the same collective runs entirely in this process —
/// the oracle side of the cross-backend acceptance gate.
fn cmd_launch_typed<T: Elem>(cfg: &Config) -> Result<()> {
    use crate::collectives::{allreduce_schedule, execute_rank, run_schedule_threads_typed};
    use crate::transport::uds::UdsTransport;
    use crate::transport::{Transport, TransportBackend};
    use std::path::Path;

    // `--backend` is the bootstrap shorthand for `transport.backend`;
    // both spellings go through the same loud enumerate-on-error parse.
    let backend = match cfg.get("backend") {
        Some(name) => TransportBackend::parse(name).ok_or_else(|| {
            anyhow!("unknown --backend {name:?} (valid: {})", TransportBackend::NAMES_HELP)
        })?,
        None => cfg.transport_backend()?,
    };
    let world = match cfg.get("launch.world").or_else(|| cfg.get("world")) {
        Some(v) => v
            .replace('_', "")
            .parse::<usize>()
            .map_err(|_| anyhow!("bad --world {v:?} (want a positive integer)"))?,
        None => 4,
    };
    if world == 0 {
        bail!("--world must be ≥ 1");
    }
    let m = cfg.get_usize("launch.m", 1 << 12)?;
    let seed = cfg.get_usize("launch.seed", 1)? as u64;
    let verify = cfg.get_bool("launch.verify", true)?;
    // `launch.iters` repeats the collective back-to-back (fresh inputs,
    // advancing wire epochs). The kill-one-rank CI smoke relies on a
    // large iteration count to keep survivors on the wire long enough
    // for the kill to land mid-collective.
    let iters = cfg.get_usize("launch.iters", 1)?.max(1);
    // `launch.gen` joins a generation-namespaced socket mesh (a revived
    // rank rejoining a reconfigured directory must speak the current
    // generation, not gen 0's leftover sockets). `launch.recover` turns a
    // peer death into a reconfiguration instead of an exit: survivors
    // re-form over world−1 at generation+1 and run `launch.recover_iters`
    // more verified collectives.
    let gen = cfg.get_usize("launch.gen", 0)? as u64;
    let recover = cfg.get_bool("launch.recover", false)?;
    let recover_iters = cfg.get_usize("launch.recover_iters", 50)?.max(1);
    // Receive/ack deadline for the socket mesh (0 keeps the transport
    // default). Recovery runs want this tight: a survivor that observes a
    // death only indirectly — parked on a fellow survivor that already
    // broke out — pays one full recv timeout before it consults the
    // health bitmap.
    let timeout_ms = cfg.get_usize("launch.timeout_ms", 0)?;

    // Deterministic inputs for ALL ranks from the seed — every process
    // computes the same vectors, its own rank's share, the scalar oracle
    // and the thread-backend cross-check without exchanging a byte of
    // input data.
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed);
    let inputs: Vec<Vec<T>> = (0..world).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
    let mut oracle = vec![T::zero(); m];
    for v in &inputs {
        SumOp.combine(&mut oracle, v);
    }

    let part = BlockPartition::regular(world, m);
    let skips = SkipScheme::HalvingUp.skips(world).map_err(|e| anyhow!("{e}"))?;
    let sched = allreduce_schedule(world, &skips);
    sched.assert_valid();

    match backend {
        TransportBackend::Thread => {
            for _ in 0..iters {
                let out =
                    run_schedule_threads_typed::<T>(&sched, &part, Arc::new(SumOp), inputs.clone());
                if verify {
                    for (r, buf) in out.iter().enumerate() {
                        if buf[..] != oracle[..] {
                            bail!("launch VERIFY FAILED: thread backend rank {r}");
                        }
                    }
                }
            }
            println!(
                "launch: OK — thread backend, p={world} allreduce of {m} {} elems in one \
                 process{}",
                T::DTYPE.name(),
                if verify { " (exact oracle match)" } else { "" },
            );
        }
        TransportBackend::Uds => {
            let rank = match cfg.get("launch.rank").or_else(|| cfg.get("rank")) {
                Some(v) => v
                    .parse::<usize>()
                    .map_err(|_| anyhow!("bad --rank {v:?} (want 0..{world})"))?,
                None => bail!("--backend uds needs --rank R (this process's rank)"),
            };
            if rank >= world {
                bail!("--rank {rank} out of range for --world {world}");
            }
            let dir = cfg.get("launch.dir").or_else(|| cfg.get("dir")).ok_or_else(|| {
                anyhow!(
                    "--backend uds needs --dir SOCKDIR (a directory shared by all {world} \
                     rank processes for their rank-R.sock files)"
                )
            })?;
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow!("cannot create --dir {dir}: {e}"))?;
            // Stale-socket hygiene: remove leftovers from a crashed run,
            // refuse loudly if another live process already serves this
            // rank in this directory. Generation-aware: a revived rank
            // rejoining a reconfigured mesh preflights (and binds) inside
            // the current generation's namespace, never gen 0's leftovers.
            UdsTransport::<T>::preflight_socket_gen(Path::new(dir), rank, gen)
                .map_err(|e| {
                    anyhow!("uds preflight failed (rank {rank} gen {gen} in {dir}): {e}")
                })?;
            let t0 = std::time::Instant::now();
            let mut transport = UdsTransport::<T>::connect_gen(
                rank,
                world,
                Path::new(dir),
                gen,
                std::time::Duration::from_secs(30),
            )
            .map_err(|e| {
                anyhow!("uds bootstrap failed (rank {rank}/{world} gen {gen} in {dir}): {e}")
            })?;
            let bootstrap = t0.elapsed().as_secs_f64();
            if timeout_ms > 0 {
                transport.set_timeout(std::time::Duration::from_millis(timeout_ms as u64));
            }
            let mut buf = inputs[rank].clone();
            let t1 = std::time::Instant::now();
            let mut round_base = 0u64;
            // With `launch.recover` a peer death breaks the loop into the
            // reconfiguration path below instead of exiting nonzero. The
            // death may surface directly (PeerDown naming the peer) or
            // indirectly — parked on a fellow survivor that already broke
            // out of the iteration, this rank sees a liveness Timeout —
            // so the authoritative census is the transport's health
            // bitmap: the reader threads record every EOF they observe no
            // matter which recv the main thread is blocked in, and the
            // survivors keep their own sockets open (below), so the only
            // down marks anyone can hold name actually-dead ranks.
            let mut dead: Vec<usize> = Vec::new();
            for _ in 0..iters {
                buf.copy_from_slice(&inputs[rank]);
                match execute_rank(&mut transport, &sched, &part, &SumOp, &mut buf, round_base) {
                    Ok(next) => round_base = next,
                    Err(e) if recover => {
                        use crate::collectives::CollectiveError;
                        use crate::transport::TransportError;
                        let mut down: Vec<usize> = transport
                            .peer_status()
                            .into_iter()
                            .enumerate()
                            .filter(|&(r, up)| !up && r != rank)
                            .map(|(r, _)| r)
                            .collect();
                        if let CollectiveError::Transport(TransportError::PeerDown {
                            peer, ..
                        })
                        | CollectiveError::RankDown { peer, .. } = &e
                        {
                            if !down.contains(peer) {
                                down.push(*peer);
                            }
                        }
                        down.sort_unstable();
                        if down.is_empty() {
                            // Not a death (bad buffer, black-holed frame,
                            // …): nothing to reconfigure around.
                            return Err(anyhow!("rank {rank}: {e}"));
                        }
                        dead = down;
                        break;
                    }
                    Err(e) => return Err(anyhow!("rank {rank}: {e}")),
                }
            }
            if !dead.is_empty() {
                // Keep the old generation's mesh OPEN until the new one is
                // formed: closing our sockets now would hand every
                // slower survivor an EOF indistinguishable from a real
                // death, and the survivor sets would diverge. With the
                // old mesh held open, the only dead sockets anyone can
                // observe during detection are the killed rank's own.
                let survivors: Vec<usize> = (0..world).filter(|r| !dead.contains(r)).collect();
                let p2 = survivors.len();
                if p2 < 2 {
                    bail!(
                        "launch: rank(s) {dead:?} died and only {p2} rank(s) survive — \
                         not enough for a collective"
                    );
                }
                let new_rank = survivors
                    .iter()
                    .position(|&r| r == rank)
                    .expect("a survivor is by definition in the survivor set");
                let gen2 = gen + 1;
                // Re-form: same directory, next generation's socket
                // namespace — every survivor independently computes the
                // same dense remap from the same PeerDown observation.
                UdsTransport::<T>::preflight_socket_gen(Path::new(dir), new_rank, gen2)
                    .map_err(|e| {
                        anyhow!("recovery preflight failed (rank {new_rank} gen {gen2}): {e}")
                    })?;
                let t_rec = std::time::Instant::now();
                let mut transport2 = UdsTransport::<T>::connect_gen(
                    new_rank,
                    p2,
                    Path::new(dir),
                    gen2,
                    std::time::Duration::from_secs(30),
                )
                .map_err(|e| {
                    anyhow!(
                        "recovery bootstrap failed (rank {rank} re-forming as \
                         {new_rank}/{p2} gen {gen2} in {dir}): {e}"
                    )
                })?;
                if timeout_ms > 0 {
                    transport2.set_timeout(std::time::Duration::from_millis(timeout_ms as u64));
                }
                // Every survivor is in the generation-namespaced mesh now;
                // the old generation's sockets can close without being
                // mistaken for deaths.
                drop(transport);
                let mut transport = transport2;
                let inputs2: Vec<Vec<T>> =
                    survivors.iter().map(|&r| inputs[r].clone()).collect();
                let mut oracle2 = vec![T::zero(); m];
                for v in &inputs2 {
                    SumOp.combine(&mut oracle2, v);
                }
                let part2 = BlockPartition::regular(p2, m);
                let skips2 =
                    SkipScheme::HalvingUp.skips(p2).map_err(|e| anyhow!("{e}"))?;
                let sched2 = allreduce_schedule(p2, &skips2);
                sched2.assert_valid();
                let mut buf2 = inputs2[new_rank].clone();
                let mut rb2 = 0u64;
                for i in 0..recover_iters {
                    buf2.copy_from_slice(&inputs2[new_rank]);
                    rb2 = execute_rank(&mut transport, &sched2, &part2, &SumOp, &mut buf2, rb2)
                        .map_err(|e| {
                            anyhow!("rank {rank} (recovered as {new_rank}/{p2}): {e}")
                        })?;
                    if verify && buf2[..] != oracle2[..] {
                        bail!(
                            "launch VERIFY FAILED: recovered rank {new_rank}/{p2} \
                             iteration {i} diverges from the survivor sum oracle"
                        );
                    }
                }
                if verify {
                    let thread_out = run_schedule_threads_typed::<T>(
                        &sched2,
                        &part2,
                        Arc::new(SumOp),
                        inputs2,
                    );
                    if thread_out[new_rank][..] != buf2[..] {
                        bail!(
                            "launch VERIFY FAILED: recovered rank {new_rank}/{p2} is not \
                             bit-identical to the thread backend"
                        );
                    }
                }
                println!(
                    "launch: RECOVERED — rank {rank} re-formed as {new_rank}/{p2} at \
                     generation {gen2} after rank(s) {dead:?} died; {recover_iters} iterations \
                     in {:.3}s{}",
                    t_rec.elapsed().as_secs_f64(),
                    if verify {
                        " (exact survivor oracle + thread-backend bit-identity)"
                    } else {
                        ""
                    },
                );
                return Ok(());
            }
            let wall = t1.elapsed().as_secs_f64();
            if verify {
                if buf[..] != oracle[..] {
                    bail!(
                        "launch VERIFY FAILED: uds rank {rank} diverges from the scalar sum \
                         oracle"
                    );
                }
                // Cross-backend bit-identity: the same schedule over the
                // in-process thread backend — same rounds, same ⊕
                // association, only the wire differs.
                let thread_out =
                    run_schedule_threads_typed::<T>(&sched, &part, Arc::new(SumOp), inputs);
                if thread_out[rank][..] != buf[..] {
                    bail!(
                        "launch VERIFY FAILED: rank {rank} uds result is not bit-identical \
                         to the thread backend"
                    );
                }
            }
            let c = transport.counters();
            println!(
                "launch: OK — uds backend, rank {rank}/{world}, {m} {} elems × {iters} iters, \
                 {} rounds, bootstrap {bootstrap:.3}s, collective {wall:.3}s, sent {} msgs / \
                 {} elems, copied {} B, recv-pool hits/misses {}/{}{}",
                T::DTYPE.name(),
                sched.rounds.len(),
                c.msgs_sent,
                c.elems_sent,
                c.bytes_copied,
                c.pool_hits,
                c.pool_misses,
                if verify { " (exact oracle + thread-backend bit-identity)" } else { "" },
            );
        }
    }
    Ok(())
}

/// The static-verification acceptance driver: run every shipped algorithm
/// × p ∈ 1..=audit.max_p × four partition shapes (regular, random, zipf,
/// single-block) through all four `crate::analysis` passes, then run the
/// seeded mutation harness and hard-fail unless 100% of the injected
/// corruption classes are caught with one of their named diagnostics.
fn cmd_audit(cfg: &Config) -> Result<()> {
    use crate::analysis::mutate::{self, Mutation};
    use std::collections::BTreeMap;

    let max_p = cfg.get_usize("audit.max_p", 64)?;
    if max_p == 0 {
        bail!("audit.max_p must be ≥ 1");
    }
    let mut_p = cfg.get_usize("audit.mutation_p", 22)?;
    if mut_p < 3 {
        bail!("audit.mutation_p must be ≥ 3 (recv retargeting needs a third rank)");
    }
    let mut_seeds = cfg.get_usize("audit.seeds", 8)?.max(1) as u64;
    let part_seed = cfg.get_usize("audit.seed", 1)? as u64;

    // Phase 1: the clean sweep — every (algorithm, p, partition-shape)
    // must pass structure, exactly-once dataflow, the paper-optimality
    // envelope, and aliasing.
    let mut pairs = 0usize;
    let mut failures: Vec<String> = Vec::new();
    let mut commut: BTreeMap<String, bool> = BTreeMap::new();
    for p in 1..=max_p {
        let m = 3 * p + 1; // deliberately not divisible by p
        let parts = [
            BlockPartition::regular(p, m),
            BlockPartition::random(p, m, part_seed ^ p as u64),
            BlockPartition::zipf(p, m, 1.2, part_seed.wrapping_add(p as u64)),
            BlockPartition::single_block(p, m, 0),
        ];
        let refs: Vec<&BlockPartition> = parts.iter().collect();
        for alg in analysis::shipped_roster(p) {
            match analysis::audit_algorithm(&alg, p, &refs) {
                Ok(rep) => {
                    pairs += 1;
                    let e = commut.entry(alg.name()).or_insert(false);
                    *e |= rep.dataflow.commutativity_required;
                }
                Err(e) => {
                    failures.push(format!("{} p={p}: [{}] {e}", alg.name(), e.code()));
                }
            }
        }
    }

    // Phase 1b: the pipelined (chunked-plan) sweep — the engine's
    // large-message tier runs each chunk as its own epoch over a regular
    // partition of the chunk length, so every distinct chunk partition a
    // pipelined allreduce can produce must pass the same four passes.
    // Geometry chosen so the remainder-folding path is always exercised
    // (two distinct chunk lengths per (scheme, p)).
    let mut pipelined_reports = 0usize;
    for p in 1..=max_p {
        let m = 8 * p + 3;
        let chunk_elems = 3 * p;
        for scheme in
            [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt, SkipScheme::FullyConnected]
        {
            let alg = Algorithm::CirculantAllreduce(scheme);
            match analysis::audit_pipelined(&alg, p, m, chunk_elems) {
                Ok(reps) => pipelined_reports += reps.len(),
                Err(e) => failures.push(format!(
                    "pipelined {} p={p} m={m} chunk={chunk_elems}: [{}] {e}",
                    alg.name(),
                    e.code()
                )),
            }
        }
    }

    // Phase 2: the mutation harness — prove the verifier bites. Every
    // injected corruption must surface as one of its class's named codes.
    let mut injected = 0usize;
    let mut caught = 0usize;
    let mut_part = BlockPartition::regular(mut_p, 2 * mut_p);
    for alg in [
        Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
        Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
    ] {
        let (sem, env) = analysis::expectation(&alg, mut_p);
        for m in Mutation::ALL {
            for seed in 0..mut_seeds {
                let mut sched = alg.schedule(mut_p);
                if !mutate::apply(&mut sched, m, seed) {
                    continue;
                }
                injected += 1;
                match analysis::audit_schedule(&sched, sem, &env, &[&mut_part]) {
                    Err(e) if m.expected_codes().contains(&e.code()) => caught += 1,
                    Err(e) => failures.push(format!(
                        "mutation {} seed {seed} on {}: caught as [{}], expected one of {:?}",
                        m.name(),
                        alg.name(),
                        e.code(),
                        m.expected_codes()
                    )),
                    Ok(_) => failures.push(format!(
                        "mutation {} seed {seed} on {}: NOT caught — verifier hole",
                        m.name(),
                        alg.name()
                    )),
                }
            }
        }
    }

    let needs_commut: Vec<String> =
        commut.iter().filter(|(_, &b)| b).map(|(k, _)| k.clone()).collect();
    let mut t = Table::new(
        "static audit",
        &[
            "(alg,p) pairs", "partitions/pair", "chunk plans", "mutations injected", "caught",
            "failures",
        ],
    );
    t.row(&[
        pairs.to_string(),
        "4".to_string(),
        pipelined_reports.to_string(),
        injected.to_string(),
        caught.to_string(),
        failures.len().to_string(),
    ]);
    t.print();
    println!(
        "⊕-commutativity required by: {}",
        if needs_commut.is_empty() { "none".to_string() } else { needs_commut.join(", ") }
    );

    if let Some(path) = cfg.get("audit.json") {
        use crate::util::json::Json;
        let mut mut_obj = BTreeMap::new();
        mut_obj.insert("classes".to_string(), Json::Num(Mutation::ALL.len() as f64));
        mut_obj.insert("injected".to_string(), Json::Num(injected as f64));
        mut_obj.insert("caught".to_string(), Json::Num(caught as f64));
        mut_obj.insert("seeds".to_string(), Json::Num(mut_seeds as f64));
        mut_obj.insert("p".to_string(), Json::Num(mut_p as f64));
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Num(1.0));
        obj.insert("kind".to_string(), Json::Str("audit".to_string()));
        obj.insert("max_p".to_string(), Json::Num(max_p as f64));
        obj.insert("pairs_checked".to_string(), Json::Num(pairs as f64));
        obj.insert("partitions_per_pair".to_string(), Json::Num(4.0));
        obj.insert("pipelined_chunk_plans".to_string(), Json::Num(pipelined_reports as f64));
        obj.insert(
            "commutativity_required".to_string(),
            Json::Arr(needs_commut.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        obj.insert(
            "failures".to_string(),
            Json::Arr(failures.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        obj.insert("mutation".to_string(), Json::Obj(mut_obj));
        std::fs::write(path, Json::Obj(obj).render() + "\n")
            .map_err(|e| anyhow!("cannot write audit.json {path}: {e}"))?;
        println!("audit: wrote {path}");
    }

    // The gates that make this a verifier, not a report.
    if !failures.is_empty() {
        for f in failures.iter().take(10) {
            eprintln!("audit FAIL: {f}");
        }
        bail!(
            "audit: {} failure(s) across {pairs} clean pairs + {pipelined_reports} chunk plans \
             + {injected} mutations",
            failures.len()
        );
    }
    if pipelined_reports == 0 {
        bail!("audit: the pipelined chunked-plan sweep audited nothing — hard gate");
    }
    if injected == 0 || caught != injected {
        bail!("audit: mutation harness caught {caught}/{injected} — must be 100% of a non-empty set");
    }
    println!(
        "audit: OK — {pairs} (algorithm, p) pairs × 4 partition shapes + {pipelined_reports} \
         pipelined chunk plans verified (p ≤ {max_p}), {caught}/{injected} injected corruptions \
         caught with named diagnostics"
    );
    Ok(())
}

/// Engine transports under chaos: the in-process thread network with a
/// seeded fault plan layered on every rank's endpoint.
type ChaosNet<T> = crate::transport::fault::FaultTransport<T, crate::transport::Endpoint<T>>;

/// Chaos-soak outcome accounting, shared by the window drain and the
/// recovery trigger (a plain function instead of a capturing closure so
/// the submit loop can read the running counts mid-soak).
#[derive(Default)]
struct SoakStats {
    completed: usize,
    failed_rank_down: usize,
    failed_timeout: usize,
    failed_other: Vec<String>,
    max_wait: std::time::Duration,
}

/// Pop the oldest in-flight chaos op: enforce the 2×op-timeout hang
/// bound, verify a surviving op bit-exact against its oracle, and
/// classify failures into the RankDown / liveness-Timeout taxonomy.
fn chaos_drain_one<T: Elem>(
    pending: &mut std::collections::VecDeque<(
        std::time::Instant,
        crate::engine::OpHandle<T, ChaosNet<T>>,
        Vec<T>,
    )>,
    latencies: &mut Vec<f64>,
    stats: &mut SoakStats,
    hang_bound: std::time::Duration,
) -> Result<()> {
    use crate::collectives::CollectiveError;
    use crate::engine::EngineError;
    use crate::transport::TransportError;
    let (t_submit, handle, oracle) = pending.pop_front().expect("nonempty window");
    let t_wait = std::time::Instant::now();
    let outcome = handle.wait();
    let waited = t_wait.elapsed();
    stats.max_wait = stats.max_wait.max(waited);
    if waited > hang_bound {
        bail!(
            "chaos HANG: a wait blocked {:.3}s, over the 2×op-timeout bound of {:.3}s",
            waited.as_secs_f64(),
            hang_bound.as_secs_f64()
        );
    }
    latencies.push(t_submit.elapsed().as_secs_f64());
    match outcome {
        Ok(out) => {
            for (r, buf) in out.iter().enumerate() {
                if buf[..] != oracle[..] {
                    bail!("chaos VERIFY FAILED: surviving op diverges from oracle at rank {r}");
                }
            }
            stats.completed += 1;
        }
        Err(EngineError::Collective { source: CollectiveError::RankDown { .. }, .. }) => {
            stats.failed_rank_down += 1
        }
        Err(EngineError::Collective {
            source:
                CollectiveError::Transport(
                    TransportError::Timeout { .. } | TransportError::AckTimeout { .. },
                ),
            ..
        }) => stats.failed_timeout += 1,
        Err(other) => stats.failed_other.push(other.to_string()),
    }
    Ok(())
}

fn cmd_chaos(cfg: &Config) -> Result<()> {
    match cfg.dtype()? {
        DType::F32 => cmd_chaos_typed::<f32>(cfg),
        DType::F64 => cmd_chaos_typed::<f64>(cfg),
        DType::I32 => cmd_chaos_typed::<i32>(cfg),
        DType::I64 => cmd_chaos_typed::<i64>(cfg),
        DType::U64 => cmd_chaos_typed::<u64>(cfg),
    }
}

/// The robustness acceptance driver: ONE persistent engine whose rank
/// transports are wrapped in [`crate::transport::fault::FaultTransport`]
/// with a seeded plan — by default a fault-injected kill of one rank
/// mid-soak (`--kill-rank R --at-op N`), optionally message drops
/// (`chaos.drop_prob`). The soak then *asserts* the failure contract:
///
///   - every op that completes is bit-exact vs the scalar sum oracle;
///   - every op failed by the kill carries the `RankDown` taxonomy
///     (positive death detection), never a bare liveness `Timeout`;
///   - no wait blocks longer than 2× the op timeout (the hang bound);
///   - exactly `p` rank threads were spawned (spawn-once survives chaos);
///   - in-flight accounting drains to zero (no leaked slots after ≥ the
///     killed half of the soak failed);
///   - drain-mode shutdown completes in-flight work and rejects new
///     submissions.
///
/// With `--chaos.recover` the soak becomes the self-healing acceptance
/// gate: after the kill is positively detected, the window is settled,
/// [`CollectiveEngine::recover`](crate::engine::CollectiveEngine::recover)
/// re-forms the engine over the `p−1` survivors within the 2×op-timeout
/// bound, and the soak resumes at `p′` — post-recovery ops verified
/// bit-exact against the survivor oracle, the generation bump, stale-frame
/// accounting, and the `p + p′` thread total all asserted from the same
/// machine-readable report. With `chaos.flap_rank` the plan injects a
/// transient kill-then-revive instead: ops inside the outage window fail
/// RankDown, ops after the revival complete, and the generation must stay
/// 0 (reconnection is not reconfiguration).
fn cmd_chaos_typed<T: Elem>(cfg: &Config) -> Result<()> {
    use crate::engine::{CollectiveEngine, EngineConfig, EngineError, OpHandle, OpRequest};
    use crate::transport::fault::{FaultAction, FaultPlan, FaultRule, FaultTransport};
    use crate::transport::network_typed;
    use std::collections::VecDeque;
    use std::time::{Duration, Instant};

    let p = cfg.get_usize("chaos.p", 4)?;
    if p < 2 {
        bail!("chaos.p must be ≥ 2 (a one-rank collective has no peer to kill)");
    }
    let n_ops = cfg.get_usize("chaos.ops", 250)?;
    if n_ops == 0 {
        bail!("chaos.ops must be ≥ 1");
    }
    let m = cfg.get_usize("chaos.m", 256)?;
    let inflight = cfg.get_usize("chaos.inflight", 4)?.max(1);
    let seed = cfg.get_usize("chaos.seed", 1)? as u64;
    let timeout_ms = cfg.get_usize("chaos.timeout_ms", 2_000)? as u64;
    let drop_prob = cfg.get_f64("chaos.drop_prob", 0.0)?;
    if !(0.0..=1.0).contains(&drop_prob) {
        bail!("chaos.drop_prob must be in [0, 1], got {drop_prob}");
    }
    // Transient kill-then-revive injection (the flap case): the rank goes
    // down at `chaos.flap_from_op` for `chaos.flap_down_ops` op epochs,
    // then revives — the engine must fail ops inside the window and
    // complete ops after it with NO generation bump.
    let flap_rank = match cfg.get("chaos.flap_rank") {
        Some(v) => Some(
            v.parse::<usize>()
                .map_err(|_| anyhow!("bad chaos.flap_rank {v:?} (want 0..{p})"))?,
        ),
        None => None,
    };
    if let Some(fr) = flap_rank {
        if fr >= p {
            bail!("chaos.flap_rank {fr} out of range for chaos.p {p}");
        }
    }
    let flap_from_op = cfg.get_usize("chaos.flap_from_op", (n_ops / 3).max(1))? as u64;
    let flap_down_ops = cfg.get_usize("chaos.flap_down_ops", 2)?.max(1) as u64;
    // The kill is on by default (this is the acceptance driver for the
    // failure path); `--chaos.kill 0` runs a fault-plan soak without it,
    // and a flap soak replaces the permanent kill unless asked for both.
    let kill_enabled = cfg.get_bool("chaos.kill", flap_rank.is_none())?;
    // `--chaos.recover`: reconfigure over the survivors after the kill
    // and resume the soak at p−1 (the self-healing acceptance gate).
    let recover_enabled = cfg.get_bool("chaos.recover", false)?;
    if recover_enabled && !kill_enabled {
        bail!("--chaos.recover needs the kill enabled (it recovers from the injected death)");
    }
    if recover_enabled && flap_rank.is_some() {
        bail!(
            "--chaos.recover and chaos.flap_rank are mutually exclusive — a flap revives \
             on its own, a recovery re-forms the world"
        );
    }
    if recover_enabled && p < 3 {
        bail!("--chaos.recover needs chaos.p ≥ 3 (the p−1 survivors must still form a collective)");
    }
    let kill_rank = match cfg.get("chaos.kill_rank").or_else(|| cfg.get("kill-rank")) {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow!("bad --kill-rank {v:?} (want 0..{p})"))?,
        None => p - 1,
    };
    if kill_rank >= p {
        bail!("--kill-rank {kill_rank} out of range for chaos.p {p}");
    }
    // 1-based submitted-op index at which the kill engages (the fault
    // layer kills once it observes an op tag ≥ this watermark).
    let at_op = match cfg.get("chaos.at_op").or_else(|| cfg.get("at-op")) {
        Some(v) => v
            .replace('_', "")
            .parse::<u64>()
            .map_err(|_| anyhow!("bad --at-op {v:?} (want a positive op index)"))?,
        None => ((n_ops / 2) as u64).max(1),
    };
    let knobs = crate::env_knobs::knobs();
    let queue_depth = cfg.get_usize("engine.queue_depth", knobs.engine_queue_depth)?;
    let retry_attempts = cfg.get_usize("engine.retry.attempts", knobs.retry_attempts)?;
    let retry_base_ms = cfg.get_usize("engine.retry.base_ms", knobs.retry_base_ms as usize)? as u64;
    let backpressure_secs = cfg.get_usize(
        "engine.backpressure_timeout",
        knobs.engine_backpressure_timeout_secs as usize,
    )? as u64;

    let mut plan = FaultPlan::new(seed);
    if kill_enabled {
        plan = plan.kill_rank(kill_rank, at_op);
    }
    if let Some(fr) = flap_rank {
        plan = plan.flap_rank(fr, flap_from_op, flap_down_ops);
    }
    if drop_prob > 0.0 {
        plan = plan.rule(FaultRule::new(FaultAction::Drop).with_probability(drop_prob));
    }
    println!(
        "chaos: p={p}, {n_ops} ops of {m} {} elems, window={inflight}, seed={seed}, \
         op_timeout={timeout_ms}ms, kill={}, flap={}, recover={}, drop_prob={drop_prob}",
        T::DTYPE.name(),
        if kill_enabled { format!("rank {kill_rank} at op {at_op}") } else { "off".into() },
        flap_rank.map_or_else(
            || "off".to_string(),
            |fr| format!("rank {fr} down ops {flap_from_op}..{}", flap_from_op + flap_down_ops),
        ),
        if recover_enabled { "on" } else { "off" },
    );

    let spawned_before = crate::transport::rank_threads_spawned();
    let transports: Vec<ChaosNet<T>> = network_typed::<T>(p)
        .into_iter()
        .map(|ep| FaultTransport::new(ep, plan.clone()))
        .collect();
    let mut engine = CollectiveEngine::<T, ChaosNet<T>>::with_transports(
        EngineConfig::new(p)
            .queue_depth(queue_depth)
            .op_timeout(Duration::from_millis(timeout_ms))
            .retry(retry_attempts, retry_base_ms)
            .backpressure_timeout(Duration::from_secs(backpressure_secs)),
        transports,
    );

    let hang_bound = Duration::from_millis(2 * timeout_ms);
    let (lo, hi) = elem::test_value_bounds(T::DTYPE);
    let mut rng = SplitMix64::new(seed ^ 0xc4a0);
    let mut stats = SoakStats::default();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_ops);
    // (submit time, handle, oracle) in submission order.
    let mut pending: VecDeque<(Instant, OpHandle<T, ChaosNet<T>>, Vec<T>)> =
        VecDeque::with_capacity(inflight);

    let t0 = Instant::now();
    // The soak is recovery-aware: after a reconfiguration `cur_p` shrinks
    // to the survivor count, so inputs and oracles are sized for the
    // world the engine actually has.
    let mut cur_p = p;
    let mut submitted = 0usize;
    let mut recover_seconds = 0.0f64;
    let mut completed_at_first_down: Option<usize> = None;
    while submitted < n_ops {
        let inputs: Vec<Vec<T>> =
            (0..cur_p).map(|_| elem::int_vec(&mut rng, m, lo, hi)).collect();
        let mut oracle = vec![T::zero(); m];
        for v in &inputs {
            SumOp.combine(&mut oracle, v);
        }
        let handle = engine
            .submit(OpRequest::allreduce(inputs, "sum"))
            .map_err(|e| anyhow!("chaos submit failed: {e}"))?;
        submitted += 1;
        pending.push_back((Instant::now(), handle, oracle));
        if pending.len() >= inflight {
            chaos_drain_one(&mut pending, &mut latencies, &mut stats, hang_bound)?;
        }
        if completed_at_first_down.is_none() && stats.failed_rank_down > 0 {
            completed_at_first_down = Some(stats.completed);
        }
        // First positively-detected death in recover mode: settle the
        // whole window (the remaining in-flight ops fail RankDown too),
        // reconfigure over the survivors, and resume the soak at p′.
        if recover_enabled && engine.recoveries() == 0 && stats.failed_rank_down > 0 {
            while !pending.is_empty() {
                chaos_drain_one(&mut pending, &mut latencies, &mut stats, hang_bound)?;
            }
            let t_rec = Instant::now();
            let report =
                engine.recover().map_err(|e| anyhow!("chaos: recovery failed: {e}"))?;
            recover_seconds = t_rec.elapsed().as_secs_f64();
            if recover_seconds > hang_bound.as_secs_f64() {
                bail!(
                    "chaos: reconfiguration took {recover_seconds:.3}s, over the {:.3}s \
                     2×op-timeout bound",
                    hang_bound.as_secs_f64()
                );
            }
            cur_p = report.p;
            println!(
                "chaos: recovered — p {p}→{cur_p}, generation {}, failed rank(s) {:?}, \
                 {recover_seconds:.3}s",
                report.generation, report.failed,
            );
        }
    }
    while !pending.is_empty() {
        chaos_drain_one(&mut pending, &mut latencies, &mut stats, hang_bound)?;
        if completed_at_first_down.is_none() && stats.failed_rank_down > 0 {
            completed_at_first_down = Some(stats.completed);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let SoakStats { completed, failed_rank_down, failed_timeout, failed_other, max_wait } =
        stats;

    // In-flight accounting must drain to zero: every failed op released
    // its queue slot (the leak check — a lost slot would accumulate and
    // eventually wedge submission behind backpressure). The last rank
    // share settles concurrently with `wait` returning, so allow a
    // bounded grace period.
    let drain_deadline = Instant::now() + Duration::from_secs(2);
    while engine.in_flight() > 0 && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_micros(100));
    }
    let in_flight_end = engine.in_flight();

    // Drain-mode shutdown: completes in-flight work (none left) and
    // rejects new submissions with the shut-down error.
    engine.drain_shutdown();
    let post_inputs: Vec<Vec<T>> = (0..cur_p).map(|_| vec![T::zero(); 4]).collect();
    match engine.submit(OpRequest::allreduce(post_inputs, "sum")) {
        Err(EngineError::ShutDown) => {}
        Ok(_) => bail!("chaos: submit after drain_shutdown unexpectedly succeeded"),
        Err(other) => bail!(
            "chaos: submit after drain_shutdown failed with {other:?} (want the shut-down error)"
        ),
    }
    // Read after shutdown: the stale-frame snapshot is finalized when the
    // workers surrender their endpoints.
    let generations = engine.generation();
    let recoveries = engine.recoveries();
    let recovered_ops = engine.recovered_ops();
    let stale_frames_dropped = engine.stale_frames_dropped();

    let spawned = crate::transport::rank_threads_spawned() - spawned_before;
    let lat = crate::util::stats::Summary::of(&latencies);
    let mut t = Table::new(
        "chaos soak",
        &[
            "ops", "completed", "rank-down", "timeout", "gen", "stale", "wall s", "lat p99",
            "max wait", "threads",
        ],
    );
    t.row(&[
        n_ops.to_string(),
        completed.to_string(),
        failed_rank_down.to_string(),
        failed_timeout.to_string(),
        generations.to_string(),
        stale_frames_dropped.to_string(),
        format!("{wall:.3}"),
        format!("{}s", fmt_si(lat.p99)),
        format!("{}s", fmt_si(max_wait.as_secs_f64())),
        spawned.to_string(),
    ]);
    t.print();

    if let Some(path) = cfg.get("chaos.json") {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Num(1.0));
        obj.insert("kind".to_string(), Json::Str("chaos".to_string()));
        obj.insert("p".to_string(), Json::Num(p as f64));
        obj.insert("ops".to_string(), Json::Num(n_ops as f64));
        obj.insert("m".to_string(), Json::Num(m as f64));
        obj.insert("dtype".to_string(), Json::Str(T::DTYPE.name().to_string()));
        obj.insert("seed".to_string(), Json::Num(seed as f64));
        obj.insert("kill_enabled".to_string(), Json::Bool(kill_enabled));
        obj.insert("kill_rank".to_string(), Json::Num(kill_rank as f64));
        obj.insert("at_op".to_string(), Json::Num(at_op as f64));
        obj.insert("drop_prob".to_string(), Json::Num(drop_prob));
        obj.insert("recover".to_string(), Json::Bool(recover_enabled));
        obj.insert("recoveries".to_string(), Json::Num(recoveries as f64));
        obj.insert("generations".to_string(), Json::Num(generations as f64));
        obj.insert("recovered_ops".to_string(), Json::Num(recovered_ops as f64));
        obj.insert(
            "stale_frames_dropped".to_string(),
            Json::Num(stale_frames_dropped as f64),
        );
        // −1 marks "no reconfiguration ran" (0 would read as a 0-second
        // recovery).
        obj.insert(
            "recover_seconds".to_string(),
            Json::Num(if recoveries > 0 { recover_seconds } else { -1.0 }),
        );
        obj.insert("p_after".to_string(), Json::Num(cur_p as f64));
        obj.insert(
            "flap_rank".to_string(),
            Json::Num(flap_rank.map_or(-1.0, |fr| fr as f64)),
        );
        obj.insert("flap_from_op".to_string(), Json::Num(flap_from_op as f64));
        obj.insert("flap_down_ops".to_string(), Json::Num(flap_down_ops as f64));
        obj.insert("op_timeout_ms".to_string(), Json::Num(timeout_ms as f64));
        obj.insert("completed".to_string(), Json::Num(completed as f64));
        obj.insert("failed_rank_down".to_string(), Json::Num(failed_rank_down as f64));
        obj.insert("failed_timeout".to_string(), Json::Num(failed_timeout as f64));
        obj.insert("failed_other".to_string(), Json::Num(failed_other.len() as f64));
        obj.insert("wall_seconds".to_string(), Json::Num(wall));
        obj.insert("lat_p50_s".to_string(), Json::Num(lat.median));
        obj.insert("lat_p99_s".to_string(), Json::Num(lat.p99));
        obj.insert("max_wait_s".to_string(), Json::Num(max_wait.as_secs_f64()));
        obj.insert("hang_bound_s".to_string(), Json::Num(hang_bound.as_secs_f64()));
        obj.insert("rank_threads_spawned".to_string(), Json::Num(spawned as f64));
        obj.insert("in_flight_end".to_string(), Json::Num(in_flight_end as f64));
        std::fs::write(path, Json::Obj(obj).render() + "\n")
            .map_err(|e| anyhow!("cannot write chaos.json {path}: {e}"))?;
        println!("chaos: wrote {path}");
    }

    // The assertions that make this a gate, not a demo.
    if !failed_other.is_empty() {
        bail!(
            "chaos: {} ops failed outside the expected taxonomy (RankDown / Timeout), e.g.: {}",
            failed_other.len(),
            failed_other[0]
        );
    }
    if failed_timeout > 0 && drop_prob == 0.0 {
        bail!(
            "chaos: {failed_timeout} ops failed with a liveness Timeout but no drops were \
             configured — the kill should surface as RankDown (positive detection), not as a \
             silent stall"
        );
    }
    if kill_enabled && (at_op as usize) <= n_ops && failed_rank_down == 0 {
        bail!(
            "chaos: rank {kill_rank} was killed at op {at_op} of {n_ops} but no op failed \
             with RankDown — the failure path never engaged"
        );
    }
    if completed + failed_rank_down + failed_timeout != n_ops {
        bail!(
            "chaos: accounting mismatch — {completed} completed + {failed_rank_down} rank-down \
             + {failed_timeout} timeout ≠ {n_ops} submitted"
        );
    }
    // Spawn accounting: exactly p workers at construction, plus exactly
    // p′ respawned by a reconfiguration — anything else is a per-op
    // spawn leak or a half-finished recovery.
    let expected_threads = p as u64 + if recoveries > 0 { cur_p as u64 } else { 0 };
    if spawned != expected_threads {
        bail!(
            "chaos: engine spawned {spawned} rank threads over {n_ops} ops (want exactly \
             {expected_threads}: spawn-once violated under faults)"
        );
    }
    if in_flight_end != 0 {
        bail!(
            "chaos: {in_flight_end} in-flight slots never drained after the soak — a failed op \
             leaked its queue slot"
        );
    }
    if recover_enabled {
        if recoveries == 0 {
            bail!(
                "chaos: --chaos.recover was set but no reconfiguration ran — the kill at op \
                 {at_op} never produced a RankDown to recover from"
            );
        }
        if recovered_ops == 0 {
            bail!(
                "chaos: the engine reconfigured to p′={cur_p} but completed zero ops \
                 afterwards — recovery produced a dead engine"
            );
        }
    }
    if let Some(fr) = flap_rank {
        if flap_from_op as usize <= n_ops && failed_rank_down == 0 {
            bail!(
                "chaos: rank {fr} flapped down at op {flap_from_op} but no op failed with \
                 RankDown — the outage window never engaged"
            );
        }
        if generations != 0 {
            bail!(
                "chaos: a transient flap bumped the generation to {generations} — \
                 reconnection must not be reconfiguration"
            );
        }
        if let Some(c0) = completed_at_first_down {
            if completed <= c0 {
                bail!(
                    "chaos: no op completed after rank {fr}'s outage window — the engine \
                     never resumed after the revival"
                );
            }
        }
    }
    println!(
        "chaos: OK — {completed} ops completed bit-exact, {failed_rank_down} failed fast with \
         RankDown{}{}, max wait {:.3}s ≤ {:.3}s hang bound, spawn-once + drain-shutdown verified",
        if failed_timeout > 0 {
            format!(", {failed_timeout} timed out under drops")
        } else {
            String::new()
        },
        if recoveries > 0 {
            format!(
                ", reconfigured p {p}→{cur_p} in {recover_seconds:.3}s (gen {generations}, \
                 {recovered_ops} post-recovery ops, {stale_frames_dropped} stale frames dropped)"
            )
        } else {
            String::new()
        },
        max_wait.as_secs_f64(),
        hang_bound.as_secs_f64(),
    );
    Ok(())
}
