//! Closed-form time formulas in the α-β-γ model.
//!
//! These are the analytic series plotted next to the DES results in F1/F2:
//! the paper's Corollary 1 and 3 for Algorithms 1/2, and standard formulas
//! for the baselines ([10, 15, 16, 17] of the paper). All take vector
//! length `m` (elements) and processor count `p`.

use super::CostModel;
use crate::util::ceil_log2;

/// Corollary 1: Algorithm 1 (reduce-scatter) on a regular partition.
/// `T = α⌈log2 p⌉ + β·(p−1)/p·m + γ·(p−1)/p·m`.
pub fn alg1_reduce_scatter(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    c.alpha * ceil_log2(p) as f64 + (c.beta + c.gamma) * frac
}

/// Theorem 2: Algorithm 2 (allreduce) — reduce-scatter + mirrored
/// allgather: `2α⌈log2 p⌉ + 2β·(p−1)/p·m + γ·(p−1)/p·m`.
pub fn alg2_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    2.0 * c.alpha * ceil_log2(p) as f64 + (2.0 * c.beta + c.gamma) * frac
}

/// The allgather phase alone (volume `(p−1)/p·m`, `⌈log2 p⌉` rounds).
pub fn allgather(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    c.alpha * ceil_log2(p) as f64 + c.beta * frac
}

/// Corollary 3: worst-case bound for irregular partitions,
/// `⌈log2 p⌉(α + βm + γm)` — all elements can sit in one block.
pub fn corollary3_bound(c: &CostModel, p: usize, m: usize) -> f64 {
    ceil_log2(p) as f64 * (c.alpha + (c.beta + c.gamma) * m as f64)
}

/// Ring (bucket) reduce-scatter [15]: `(p−1)(α + (β+γ)m/p)`.
pub fn ring_reduce_scatter(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    (p - 1) as f64 * (c.alpha + (c.beta + c.gamma) * m as f64 / p as f64)
}

/// Ring allreduce [15]: RS ring + AG ring,
/// `2(p−1)α + (2β+γ)(p−1)m/p`.
pub fn ring_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    2.0 * (p - 1) as f64 * c.alpha + (2.0 * c.beta + c.gamma) * frac
}

/// Recursive doubling allreduce: full vector every round,
/// `⌈log2 p⌉(α + (β+γ)m)` (+ a fold in and a copy-back round when p is not
/// a power of two).
pub fn recursive_doubling_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = p.ilog2() as f64;
    let base = q * (c.alpha + (c.beta + c.gamma) * m as f64);
    if p.is_power_of_two() {
        base
    } else {
        base + (c.alpha + (c.beta + c.gamma) * m as f64) + (c.alpha + c.beta * m as f64)
    }
}

/// Rabenseifner allreduce [16] (recursive halving RS + recursive doubling
/// AG; power-of-two form): `2α·log2 p + (2β+γ)·(p−1)/p·m`.
pub fn rabenseifner_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = p.ilog2() as f64;
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    let base = 2.0 * c.alpha * q + (2.0 * c.beta + c.gamma) * frac;
    if p.is_power_of_two() {
        base
    } else {
        base + (c.alpha + (c.beta + c.gamma) * m as f64) + (c.alpha + c.beta * m as f64)
    }
}

/// Binomial-tree allreduce (reduce to root + broadcast), full vector on
/// every edge: `2⌈log2 p⌉(α + βm) + ⌈log2 p⌉γm`.
pub fn binomial_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = ceil_log2(p) as f64;
    2.0 * q * (c.alpha + c.beta * m as f64) + q * c.gamma * m as f64
}

/// Pipelined binary-tree allreduce estimate: `k` chunks of `c = m/k`
/// elements through a depth-`⌈log2 p⌉` tree, reduce then broadcast, with
/// the 2× arity bandwidth penalty the paper mentions (§1). Optimized over
/// `k` numerically.
pub fn pipelined_binary_tree_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let d = ceil_log2(p) as f64;
    let mut best = f64::INFINITY;
    let mut k = 1usize;
    while k <= m.max(1) {
        let chunk = (m as f64 / k as f64).ceil();
        // per pipeline stage a node serializes two child messages (one port)
        let stage = 2.0 * (c.alpha + c.beta * chunk) + 2.0 * c.gamma * chunk;
        let t = 2.0 * (d + k as f64 - 1.0) * stage;
        best = best.min(t);
        k *= 2;
    }
    best
}

/// Number of chunk epochs the engine's pipelined tier would use for an
/// `m`-element vector with `chunk_elems`-element chunks — mirrors
/// `pipeline_chunk_sizes` in `collectives::exec` (the remainder folds
/// into the last chunk; fewer than two whole chunks degenerates to one
/// plain run).
pub fn pipeline_num_chunks(m: usize, chunk_elems: usize) -> usize {
    if chunk_elems == 0 || m < 2 * chunk_elems {
        1
    } else {
        m / chunk_elems
    }
}

/// The engine's pipelined circulant allreduce: `n_c` chunks, each running
/// Algorithm 2 as its own wire epoch, with chunk `k+1`'s sends overlapped
/// against chunk `k`'s combines under the sliding window:
///
/// `T = α(2⌈log₂p⌉ + n_c − 1) + 2β·(p−1)/p·m + γ·(p−1)/p·m/n_c`.
///
/// The wire is busy end to end (full 2β volume term, fill latency of
/// `2q + n_c − 1` rounds), while all but one chunk's combine time hides
/// under the next chunk's transfers — pipelining saves
/// `γ·(p−1)/p·m·(1 − 1/n_c)` of [`alg2_allreduce`]'s γ term at a cost of
/// `α(n_c − 1)` extra round latencies. `n_c = 1` reduces exactly to
/// [`alg2_allreduce`]; large `n_c` is the pessimization regime where the
/// α term dominates.
pub fn pipelined_circulant_allreduce(c: &CostModel, p: usize, m: usize, chunk_elems: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let nc = pipeline_num_chunks(m, chunk_elems) as f64;
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    c.alpha * (2.0 * ceil_log2(p) as f64 + nc - 1.0)
        + 2.0 * c.beta * frac
        + c.gamma * frac / nc
}

/// Smallest vector length (elements) at which the pipelined tier beats
/// the plain Algorithm 2 run for this cost model, found by doubling
/// search over `m`. Returns `None` when no length up to `2^40` wins —
/// e.g. γ = 0 (free reduction: nothing to hide) or `chunk_elems = 0`
/// (tier disabled). `selector` uses this to ground
/// `CCOLL_PIPELINE_MIN_BYTES` in the model.
pub fn pipeline_break_even_elems(c: &CostModel, p: usize, chunk_elems: usize) -> Option<usize> {
    if p == 1 || chunk_elems == 0 {
        return None;
    }
    let mut m = 2 * chunk_elems; // smallest pipelined (≥ 2 chunk) length
    while m <= 1 << 40 {
        if pipelined_circulant_allreduce(c, p, m, chunk_elems) < alg2_allreduce(c, p, m) {
            return Some(m);
        }
        m *= 2;
    }
    None
}

/// Two-tree allreduce estimate [17]: full-bandwidth pipelined trees.
pub fn two_tree_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let d = ceil_log2(p) as f64 + 1.0;
    let mut best = f64::INFINITY;
    let mut k = 1usize;
    while k <= m.max(1) {
        let chunk = (m as f64 / k as f64 / 2.0).ceil(); // halves through each tree
        let stage = c.alpha + (c.beta + c.gamma) * chunk;
        let t = 2.0 * (d + k as f64 - 1.0) * stage;
        best = best.min(t);
        k *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: CostModel = CostModel { alpha: 1.0, beta: 0.01, gamma: 0.005 };

    #[test]
    fn corollary1_exact_values() {
        // p=22, m=22: q=5, frac = 21/22·22 = 21
        let t = alg1_reduce_scatter(&C, 22, 22);
        assert!((t - (5.0 + 0.015 * 21.0)).abs() < 1e-12);
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        for (p, m) in [(22, 220), (64, 4096), (1000, 10_000)] {
            let lhs = alg2_allreduce(&C, p, m);
            let rhs = alg1_reduce_scatter(&C, p, m) + allgather(&C, p, m);
            assert!((lhs - rhs).abs() < 1e-9, "p={p} m={m}");
        }
    }

    #[test]
    fn alg2_beats_recursive_doubling_for_large_m() {
        let p = 64;
        let m = 1 << 20;
        assert!(alg2_allreduce(&C, p, m) < recursive_doubling_allreduce(&C, p, m));
    }

    #[test]
    fn ring_wins_never_by_volume_only_by_rounds() {
        // Volume terms of Alg 2 and ring allreduce are identical; ring only
        // loses on the α term — so Alg 2 ≤ ring for all p ≥ 2, m.
        for p in [2usize, 3, 17, 64, 1000] {
            for m in [1usize, 100, 1 << 16] {
                assert!(
                    alg2_allreduce(&C, p, m) <= ring_allreduce(&C, p, m) + 1e-9,
                    "p={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn small_m_log_algorithms_beat_ring() {
        let big_p = 1024;
        let small_m = 16;
        assert!(alg2_allreduce(&C, big_p, small_m) < ring_allreduce(&C, big_p, small_m) / 10.0);
    }

    #[test]
    fn rabenseifner_matches_alg2_on_powers_of_two() {
        // Both are volume/round optimal for p = 2^k in this model.
        for (p, m) in [(64, 4096), (256, 1 << 16)] {
            let a = alg2_allreduce(&C, p, m);
            let b = rabenseifner_allreduce(&C, p, m);
            assert!((a - b).abs() < 1e-9, "p={p}: {a} vs {b}");
        }
    }

    #[test]
    fn p1_all_zero() {
        for f in [
            alg1_reduce_scatter,
            alg2_allreduce,
            ring_allreduce,
            recursive_doubling_allreduce,
            binomial_allreduce,
        ] {
            assert_eq!(f(&C, 1, 100), 0.0);
        }
    }

    #[test]
    fn pipelined_circulant_reduces_to_alg2_at_one_chunk() {
        for (p, m) in [(2usize, 100usize), (8, 4096), (64, 1 << 16)] {
            // chunk ≥ m/2 → a single chunk → exactly the plain formula
            let a = pipelined_circulant_allreduce(&C, p, m, m);
            let b = alg2_allreduce(&C, p, m);
            assert!((a - b).abs() < 1e-9, "p={p} m={m}: {a} vs {b}");
        }
    }

    #[test]
    fn pipelined_circulant_wins_for_large_m_and_loses_for_small() {
        let p = 8;
        let chunk = 1 << 15; // 32 Ki elements
        let large = 1 << 22;
        assert!(
            pipelined_circulant_allreduce(&C, p, large, chunk) < alg2_allreduce(&C, p, large),
            "large-m pipelining must hide the combine time"
        );
        // Just over two chunks of a small vector: the α(n_c−1) surcharge
        // exceeds the tiny hidden γ term.
        let small_chunk = 4;
        let small = 8;
        assert!(
            pipelined_circulant_allreduce(&C, p, small, small_chunk)
                > alg2_allreduce(&C, p, small),
            "small-m pipelining must be a pessimization"
        );
    }

    #[test]
    fn break_even_is_consistent_with_the_formula() {
        let p = 8;
        let chunk = 1 << 15;
        let be = pipeline_break_even_elems(&C, p, chunk).expect("γ > 0 must break even");
        assert!(
            pipelined_circulant_allreduce(&C, p, be, chunk) < alg2_allreduce(&C, p, be),
            "break-even point must actually win"
        );
        // Free reduction: nothing to hide, pipelining can never pay.
        let free = CostModel { alpha: 1.0, beta: 0.01, gamma: 0.0 };
        assert_eq!(pipeline_break_even_elems(&free, p, chunk), None);
        assert_eq!(pipeline_break_even_elems(&C, p, 0), None);
    }

    #[test]
    fn pipelined_tree_improves_with_pipelining() {
        // With chunking allowed, the pipelined tree must beat its own k=1
        // (pure binomial-ish) configuration for large m.
        let m = 1 << 20;
        let d = ceil_log2(64) as f64;
        let k1 = 2.0 * d * (2.0 * (C.alpha + C.beta * m as f64) + 2.0 * C.gamma * m as f64);
        assert!(pipelined_binary_tree_allreduce(&C, 64, m) < k1);
    }
}
