//! Closed-form time formulas in the α-β-γ model.
//!
//! These are the analytic series plotted next to the DES results in F1/F2:
//! the paper's Corollary 1 and 3 for Algorithms 1/2, and standard formulas
//! for the baselines ([10, 15, 16, 17] of the paper). All take vector
//! length `m` (elements) and processor count `p`.

use super::CostModel;
use crate::util::ceil_log2;

/// Corollary 1: Algorithm 1 (reduce-scatter) on a regular partition.
/// `T = α⌈log2 p⌉ + β·(p−1)/p·m + γ·(p−1)/p·m`.
pub fn alg1_reduce_scatter(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    c.alpha * ceil_log2(p) as f64 + (c.beta + c.gamma) * frac
}

/// Theorem 2: Algorithm 2 (allreduce) — reduce-scatter + mirrored
/// allgather: `2α⌈log2 p⌉ + 2β·(p−1)/p·m + γ·(p−1)/p·m`.
pub fn alg2_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    2.0 * c.alpha * ceil_log2(p) as f64 + (2.0 * c.beta + c.gamma) * frac
}

/// The allgather phase alone (volume `(p−1)/p·m`, `⌈log2 p⌉` rounds).
pub fn allgather(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    c.alpha * ceil_log2(p) as f64 + c.beta * frac
}

/// Corollary 3: worst-case bound for irregular partitions,
/// `⌈log2 p⌉(α + βm + γm)` — all elements can sit in one block.
pub fn corollary3_bound(c: &CostModel, p: usize, m: usize) -> f64 {
    ceil_log2(p) as f64 * (c.alpha + (c.beta + c.gamma) * m as f64)
}

/// Ring (bucket) reduce-scatter [15]: `(p−1)(α + (β+γ)m/p)`.
pub fn ring_reduce_scatter(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    (p - 1) as f64 * (c.alpha + (c.beta + c.gamma) * m as f64 / p as f64)
}

/// Ring allreduce [15]: RS ring + AG ring,
/// `2(p−1)α + (2β+γ)(p−1)m/p`.
pub fn ring_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    2.0 * (p - 1) as f64 * c.alpha + (2.0 * c.beta + c.gamma) * frac
}

/// Recursive doubling allreduce: full vector every round,
/// `⌈log2 p⌉(α + (β+γ)m)` (+ a fold in and a copy-back round when p is not
/// a power of two).
pub fn recursive_doubling_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = p.ilog2() as f64;
    let base = q * (c.alpha + (c.beta + c.gamma) * m as f64);
    if p.is_power_of_two() {
        base
    } else {
        base + (c.alpha + (c.beta + c.gamma) * m as f64) + (c.alpha + c.beta * m as f64)
    }
}

/// Rabenseifner allreduce [16] (recursive halving RS + recursive doubling
/// AG; power-of-two form): `2α·log2 p + (2β+γ)·(p−1)/p·m`.
pub fn rabenseifner_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = p.ilog2() as f64;
    let frac = (p - 1) as f64 / p as f64 * m as f64;
    let base = 2.0 * c.alpha * q + (2.0 * c.beta + c.gamma) * frac;
    if p.is_power_of_two() {
        base
    } else {
        base + (c.alpha + (c.beta + c.gamma) * m as f64) + (c.alpha + c.beta * m as f64)
    }
}

/// Binomial-tree allreduce (reduce to root + broadcast), full vector on
/// every edge: `2⌈log2 p⌉(α + βm) + ⌈log2 p⌉γm`.
pub fn binomial_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let q = ceil_log2(p) as f64;
    2.0 * q * (c.alpha + c.beta * m as f64) + q * c.gamma * m as f64
}

/// Pipelined binary-tree allreduce estimate: `k` chunks of `c = m/k`
/// elements through a depth-`⌈log2 p⌉` tree, reduce then broadcast, with
/// the 2× arity bandwidth penalty the paper mentions (§1). Optimized over
/// `k` numerically.
pub fn pipelined_binary_tree_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let d = ceil_log2(p) as f64;
    let mut best = f64::INFINITY;
    let mut k = 1usize;
    while k <= m.max(1) {
        let chunk = (m as f64 / k as f64).ceil();
        // per pipeline stage a node serializes two child messages (one port)
        let stage = 2.0 * (c.alpha + c.beta * chunk) + 2.0 * c.gamma * chunk;
        let t = 2.0 * (d + k as f64 - 1.0) * stage;
        best = best.min(t);
        k *= 2;
    }
    best
}

/// Two-tree allreduce estimate [17]: full-bandwidth pipelined trees.
pub fn two_tree_allreduce(c: &CostModel, p: usize, m: usize) -> f64 {
    if p == 1 {
        return 0.0;
    }
    let d = ceil_log2(p) as f64 + 1.0;
    let mut best = f64::INFINITY;
    let mut k = 1usize;
    while k <= m.max(1) {
        let chunk = (m as f64 / k as f64 / 2.0).ceil(); // halves through each tree
        let stage = c.alpha + (c.beta + c.gamma) * chunk;
        let t = 2.0 * (d + k as f64 - 1.0) * stage;
        best = best.min(t);
        k *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: CostModel = CostModel { alpha: 1.0, beta: 0.01, gamma: 0.005 };

    #[test]
    fn corollary1_exact_values() {
        // p=22, m=22: q=5, frac = 21/22·22 = 21
        let t = alg1_reduce_scatter(&C, 22, 22);
        assert!((t - (5.0 + 0.015 * 21.0)).abs() < 1e-12);
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        for (p, m) in [(22, 220), (64, 4096), (1000, 10_000)] {
            let lhs = alg2_allreduce(&C, p, m);
            let rhs = alg1_reduce_scatter(&C, p, m) + allgather(&C, p, m);
            assert!((lhs - rhs).abs() < 1e-9, "p={p} m={m}");
        }
    }

    #[test]
    fn alg2_beats_recursive_doubling_for_large_m() {
        let p = 64;
        let m = 1 << 20;
        assert!(alg2_allreduce(&C, p, m) < recursive_doubling_allreduce(&C, p, m));
    }

    #[test]
    fn ring_wins_never_by_volume_only_by_rounds() {
        // Volume terms of Alg 2 and ring allreduce are identical; ring only
        // loses on the α term — so Alg 2 ≤ ring for all p ≥ 2, m.
        for p in [2usize, 3, 17, 64, 1000] {
            for m in [1usize, 100, 1 << 16] {
                assert!(
                    alg2_allreduce(&C, p, m) <= ring_allreduce(&C, p, m) + 1e-9,
                    "p={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn small_m_log_algorithms_beat_ring() {
        let big_p = 1024;
        let small_m = 16;
        assert!(alg2_allreduce(&C, big_p, small_m) < ring_allreduce(&C, big_p, small_m) / 10.0);
    }

    #[test]
    fn rabenseifner_matches_alg2_on_powers_of_two() {
        // Both are volume/round optimal for p = 2^k in this model.
        for (p, m) in [(64, 4096), (256, 1 << 16)] {
            let a = alg2_allreduce(&C, p, m);
            let b = rabenseifner_allreduce(&C, p, m);
            assert!((a - b).abs() < 1e-9, "p={p}: {a} vs {b}");
        }
    }

    #[test]
    fn p1_all_zero() {
        for f in [
            alg1_reduce_scatter,
            alg2_allreduce,
            ring_allreduce,
            recursive_doubling_allreduce,
            binomial_allreduce,
        ] {
            assert_eq!(f(&C, 1, 100), 0.0);
        }
    }

    #[test]
    fn pipelined_tree_improves_with_pipelining() {
        // With chunking allowed, the pipelined tree must beat its own k=1
        // (pure binomial-ish) configuration for large m.
        let m = 1 << 20;
        let d = ceil_log2(64) as f64;
        let k1 = 2.0 * d * (2.0 * (C.alpha + C.beta * m as f64) + 2.0 * C.gamma * m as f64);
        assert!(pipelined_binary_tree_allreduce(&C, 64, m) < k1);
    }
}
