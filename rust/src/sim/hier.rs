//! Two-level (clustered) cost model — the §3 setting.
//!
//! Nodes of `node_size` consecutive ranks; an edge inside a node pays the
//! `intra` parameters, an edge between nodes pays `inter` (typically
//! 10–50× higher latency, lower bandwidth). This is the model under which
//! the paper's §3 remark — that flat doubling/halving schemes suffer
//! latency contention on hierarchical systems — becomes measurable, and
//! under which the decomposed schedule of
//! `collectives::hierarchical` pays off ([21]).

use crate::datatypes::BlockPartition;
use crate::schedule::{RecvAction, Schedule};

use super::{CostModel, SimResult};

/// Two-level cost model.
#[derive(Debug, Clone, Copy)]
pub struct HierModel {
    pub node_size: usize,
    pub intra: CostModel,
    pub inter: CostModel,
}

impl HierModel {
    /// A typical clustered system: fast shared-memory node (0.2 µs,
    /// 40 GB/s) vs network (2 µs, 10 GB/s); γ from the intra model.
    pub fn typical(node_size: usize) -> Self {
        Self {
            node_size,
            intra: CostModel::new(2e-7, 4.0 / 40e9, 1e-9),
            inter: CostModel::new(2e-6, 4.0 / 10e9, 1e-9),
        }
    }

    fn edge(&self, a: usize, b: usize) -> &CostModel {
        if a / self.node_size == b / self.node_size {
            &self.intra
        } else {
            &self.inter
        }
    }
}

/// Asynchronous DES under the two-level model (same semantics as
/// [`super::simulate`], with per-edge α/β) **including per-node link
/// contention**: a node has one NIC, so `c` simultaneous cross-node flows
/// out of (or into) a node in a round each see `c×` the inter-node β.
/// This is exactly the "constrained per node bandwidth" of §3/[21] that a
/// one-port-per-rank model hides — flat doubling/halving schedules put
/// every rank of a node on the wire simultaneously, the decomposed
/// schedule only its leader.
pub fn simulate_hier(schedule: &Schedule, part: &BlockPartition, model: &HierModel) -> SimResult {
    assert_eq!(part.p(), schedule.p);
    let p = schedule.p;
    let num_nodes = p.div_ceil(model.node_size);
    let node_of = |r: usize| r / model.node_size;
    let mut ready = vec![0.0f64; p];
    for round in &schedule.rounds {
        let before = ready.clone();
        // Per-node cross-link concurrency this round (out and in).
        let mut out_cnt = vec![0usize; num_nodes];
        let mut in_cnt = vec![0usize; num_nodes];
        for (r, step) in round.steps.iter().enumerate() {
            if let Some(send) = &step.send {
                if node_of(r) != node_of(send.peer) {
                    out_cnt[node_of(r)] += 1;
                    in_cnt[node_of(send.peer)] += 1;
                }
            }
        }
        for (r, step) in round.steps.iter().enumerate() {
            let mut t = before[r];
            if let Some(send) = &step.send {
                let b = send.blocks.normalized(p);
                let n = part.circular_elems(b.start, b.len) as f64;
                let c = model.edge(r, send.peer);
                let contention = if node_of(r) != node_of(send.peer) {
                    out_cnt[node_of(r)].max(in_cnt[node_of(send.peer)]) as f64
                } else {
                    1.0
                };
                t = t.max(before[r] + c.alpha + c.beta * contention * n);
            }
            if let Some(recv) = &step.recv {
                let b = recv.blocks.normalized(p);
                let n = part.circular_elems(b.start, b.len) as f64;
                let c = model.edge(r, recv.peer);
                let contention = if node_of(r) != node_of(recv.peer) {
                    in_cnt[node_of(r)].max(out_cnt[node_of(recv.peer)]) as f64
                } else {
                    1.0
                };
                let mut tr =
                    before[r].max(before[recv.peer]) + c.alpha + c.beta * contention * n;
                if recv.action == RecvAction::Combine {
                    tr += model.intra.gamma * n;
                }
                t = t.max(tr);
            }
            ready[r] = t;
        }
    }
    let total = ready.iter().copied().fold(0.0, f64::max);
    SimResult { finish: ready, total, rounds: schedule.num_rounds() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::hierarchical::hierarchical_allreduce_schedule;
    use crate::collectives::Algorithm;
    use crate::topology::skips::SkipScheme;

    #[test]
    fn single_node_hier_model_matches_flat_simulation() {
        // With everything in one node there are no cross-links, hence no
        // contention: the two simulators must agree exactly.
        let flat = CostModel::cluster();
        let p = 32;
        let model = HierModel { node_size: p, intra: flat, inter: flat };
        let part = BlockPartition::regular(p, 1 << 12);
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        let a = simulate_hier(&sched, &part, &model).total;
        let b = super::super::simulate(&sched, &part, &flat).total;
        assert!((a - b).abs() < 1e-12 * b);
    }

    #[test]
    fn contention_scales_cross_node_rounds() {
        // All ranks of each node crossing simultaneously see c× β: a flat
        // Alg 2 on 2 nodes must cost strictly more under contention than
        // with per-rank ports (homogeneous params, same schedule).
        let flat = CostModel::cluster();
        let p = 16;
        let model = HierModel { node_size: 8, intra: flat, inter: flat };
        let part = BlockPartition::regular(p, 1 << 14);
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        let with_contention = simulate_hier(&sched, &part, &model).total;
        let no_contention = super::super::simulate(&sched, &part, &flat).total;
        assert!(with_contention > no_contention * 1.5, "{with_contention} vs {no_contention}");
    }

    #[test]
    fn decomposition_pays_off_on_clustered_systems() {
        // §3/[21]: with constrained inter-node links, the decomposed
        // schedule beats flat Algorithm 2 (which sends most traffic across
        // nodes), for a realistically sized vector.
        let node = 8;
        let p = 64;
        let model = HierModel::typical(node);
        let part = BlockPartition::regular(p, 1 << 20);
        let flat = Algorithm::parse("ar").unwrap().schedule(p);
        let hier = hierarchical_allreduce_schedule(p, node, &SkipScheme::HalvingUp);
        let t_flat = simulate_hier(&flat, &part, &model).total;
        let t_hier = simulate_hier(&hier, &part, &model).total;
        assert!(
            t_hier < t_flat,
            "hierarchical {t_hier} should beat flat {t_flat} on clustered model"
        );
        // while on a homogeneous model the flat schedule wins (fewer rounds)
        let flat_model = HierModel { node_size: node, intra: CostModel::cluster(), inter: CostModel::cluster() };
        let t_flat_h = simulate_hier(&flat, &part, &flat_model).total;
        let t_hier_h = simulate_hier(&hier, &part, &flat_model).total;
        assert!(t_flat_h < t_hier_h, "flat should win homogeneously: {t_flat_h} vs {t_hier_h}");
    }
}
