//! α-β-γ cost modeling: discrete-event schedule simulation + closed forms.
//!
//! The homogeneous, linear-affine transmission-cost model of Corollary 1:
//! a bidirectional send-receive of `n` elements costs `α + βn`, and
//! reducing `n` received elements with ⊕ costs `γn`. The simulator
//! evaluates *any* [`Schedule`] in this model asynchronously (each rank's
//! clock advances independently; a receive completes no earlier than the
//! sender's readiness), which reproduces Corollary 1 exactly on regular
//! partitions and exposes the skew effects of Corollary 3 on irregular
//! ones — at `p` far beyond what the thread transport can run.

pub mod calibrate;
pub mod closed_form;
pub mod hier;

use crate::datatypes::BlockPartition;
use crate::schedule::{RecvAction, Schedule};

/// The (α, β, γ) parameters. Units are arbitrary but consistent: α in
/// seconds per message, β/γ in seconds per element.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
}

impl CostModel {
    pub fn new(alpha: f64, beta: f64, gamma: f64) -> Self {
        Self { alpha, beta, gamma }
    }

    /// A cluster-ish default: 1 µs latency, 10 GB/s links (f32 elements),
    /// 1 element/ns reduction speed.
    pub fn cluster() -> Self {
        Self { alpha: 1e-6, beta: 4.0 / 10e9, gamma: 1e-9 }
    }

    /// Latency-dominated regime (small messages matter).
    pub fn latency_bound() -> Self {
        Self { alpha: 1e-5, beta: 4.0 / 10e9, gamma: 1e-9 }
    }

    /// Bandwidth-dominated regime (large vectors matter).
    pub fn bandwidth_bound() -> Self {
        Self { alpha: 1e-7, beta: 4.0 / 1e9, gamma: 4e-10 }
    }
}

/// Result of simulating one schedule.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of each rank.
    pub finish: Vec<f64>,
    /// Makespan: max over ranks.
    pub total: f64,
    pub rounds: usize,
}

/// Asynchronous discrete-event evaluation of `schedule` under `model`.
///
/// Semantics per rank and round (eager sends, synchronous receives):
///   * a send occupies the sender for `α + β·send_elems`;
///   * a receive completes at
///     `max(self_ready, sender_ready) + α + β·recv_elems`, plus
///     `γ·recv_elems` if the action is `Combine`;
///   * the rank's clock advances to the max of both.
///
/// On regular partitions with the paper's schedule this telescopes to
/// Corollary 1's `α⌈log2 p⌉ + (β+γ)·(p−1)/p·m` (asserted in tests).
pub fn simulate(schedule: &Schedule, part: &BlockPartition, model: &CostModel) -> SimResult {
    assert_eq!(part.p(), schedule.p);
    let p = schedule.p;
    let mut ready = vec![0.0f64; p];
    for round in &schedule.rounds {
        let before = ready.clone();
        for (r, step) in round.steps.iter().enumerate() {
            let mut t = before[r];
            if let Some(send) = &step.send {
                let b = send.blocks.normalized(p);
                let n = part.circular_elems(b.start, b.len) as f64;
                t = t.max(before[r] + model.alpha + model.beta * n);
            }
            if let Some(recv) = &step.recv {
                let b = recv.blocks.normalized(p);
                let n = part.circular_elems(b.start, b.len) as f64;
                let mut tr = before[r].max(before[recv.peer]) + model.alpha + model.beta * n;
                if recv.action == RecvAction::Combine {
                    tr += model.gamma * n;
                }
                t = t.max(tr);
            }
            ready[r] = t;
        }
    }
    let total = ready.iter().copied().fold(0.0, f64::max);
    SimResult { finish: ready, total, rounds: schedule.num_rounds() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{BlockRange, RankStep, Recv, Round, Transfer};

    /// Hand-built 1-round exchange between 2 ranks, 4 elems each way.
    fn swap2(part_elems: usize) -> (Schedule, BlockPartition) {
        let mut s = Schedule::new(2, "swap");
        s.rounds.push(Round {
            steps: vec![
                RankStep {
                    send: Some(Transfer { peer: 1, blocks: BlockRange::new(1, 1) }),
                    recv: Some(Recv {
                        peer: 1,
                        blocks: BlockRange::new(0, 1),
                        action: RecvAction::Combine,
                    }),
                },
                RankStep {
                    send: Some(Transfer { peer: 0, blocks: BlockRange::new(0, 1) }),
                    recv: Some(Recv {
                        peer: 0,
                        blocks: BlockRange::new(1, 1),
                        action: RecvAction::Combine,
                    }),
                },
            ],
        });
        (s, BlockPartition::uniform(2, part_elems))
    }

    #[test]
    fn one_round_cost_is_linear_affine() {
        let (s, part) = swap2(4);
        let m = CostModel::new(1.0, 0.5, 0.25);
        let r = simulate(&s, &part, &m);
        // α + β·4 + γ·4 = 1 + 2 + 1 = 4, symmetric ranks
        assert!((r.total - 4.0).abs() < 1e-12, "{}", r.total);
        assert_eq!(r.finish[0], r.finish[1]);
    }

    #[test]
    fn store_skips_gamma() {
        let (mut s, part) = swap2(4);
        for step in &mut s.rounds[0].steps {
            step.recv.as_mut().unwrap().action = RecvAction::Store;
        }
        let m = CostModel::new(1.0, 0.5, 0.25);
        let r = simulate(&s, &part, &m);
        assert!((r.total - 3.0).abs() < 1e-12);
    }

    #[test]
    fn receiver_waits_for_late_sender() {
        // Round 1: only ranks 0,1 swap. Round 2: rank 2 receives from 0.
        let mut s = Schedule::new(3, "late");
        let (sw, _) = swap2(4);
        let mut round1 = Round::idle(3);
        round1.steps[0] = sw.rounds[0].steps[0];
        round1.steps[1] = sw.rounds[0].steps[1];
        // fix peers' block ids for p=3 context (use blocks 0/1 as before)
        s.rounds.push(round1);
        let mut round2 = Round::idle(3);
        round2.steps[0] =
            RankStep { send: Some(Transfer { peer: 2, blocks: BlockRange::new(2, 1) }), recv: None };
        round2.steps[2] = RankStep {
            send: None,
            recv: Some(Recv { peer: 0, blocks: BlockRange::new(2, 1), action: RecvAction::Store }),
        };
        s.rounds.push(round2);
        let part = BlockPartition::uniform(3, 4);
        let m = CostModel::new(1.0, 0.5, 0.25);
        let r = simulate(&s, &part, &m);
        // rank 0 busy until 4 (round 1), rank 2 idle; recv completes at
        // max(0, 4) + 1 + 2 = 7
        assert!((r.finish[2] - 7.0).abs() < 1e-12, "{}", r.finish[2]);
    }

    #[test]
    fn idle_ranks_cost_nothing() {
        let mut s = Schedule::new(4, "idle");
        s.rounds.push(Round::idle(4));
        let part = BlockPartition::uniform(4, 8);
        let r = simulate(&s, &part, &CostModel::cluster());
        assert_eq!(r.total, 0.0);
    }
}
