//! Empirical α-β-γ calibration of the *actual* thread-network transport.
//!
//! Fits the linear-affine model of Corollary 1 to measurements:
//!   * α — median round-trip/2 of empty-payload ping-pong between two rank
//!     threads;
//!   * β — incremental per-element cost from large-payload ping-pong;
//!   * γ — per-element cost of the native combine on a large buffer.
//!
//! The calibrated model turns the DES from a *relative* predictor into an
//! absolute one for this substrate (used by `perf_hotpath` to report
//! wall/DES ratios near 1 instead of arbitrary units).

use std::time::Instant;

use crate::ops::ReduceOp;
use crate::transport::run_ranks;

use super::CostModel;

/// Median of a small sample (consumes it).
fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

/// Ping-pong `iters` times with `n`-element payloads between 2 ranks;
/// returns seconds per one-way message.
fn pingpong(n: usize, iters: usize) -> f64 {
    let out = run_ranks(2, move |rank, ep| {
        let payload = vec![0.5f32; n];
        let peer = 1 - rank;
        // warmup (borrow-pack API: the transport copies from the slice)
        for round in 0..4u64 {
            let got = ep.sendrecv(Some((peer, &payload, &[])), Some(peer), round).unwrap();
            ep.release(peer, got.unwrap());
        }
        let t0 = Instant::now();
        for it in 0..iters as u64 {
            if rank == 0 {
                ep.send_to(peer, 100 + it, payload.clone()).unwrap();
                ep.recv_from(peer, 1000 + it).unwrap();
            } else {
                let p = ep.recv_from(peer, 100 + it).unwrap();
                ep.send_to(peer, 1000 + it, p).unwrap();
            }
        }
        t0.elapsed().as_secs_f64()
    });
    // total time covers 2·iters one-way messages
    out[0].min(out[1]) / (2.0 * iters as f64)
}

/// Calibrate the thread-network transport + a native ⊕.
/// `reps` controls sampling; keep small (3–5) — each rep spawns threads.
pub fn calibrate_transport(op: &dyn ReduceOp, reps: usize) -> CostModel {
    let reps = reps.max(1);
    let small = 0usize;
    let big = 1 << 18;
    let alpha = median((0..reps).map(|_| pingpong(small, 200)).collect());
    let t_big = median((0..reps).map(|_| pingpong(big, 50)).collect());
    let beta = ((t_big - alpha) / big as f64).max(1e-13);

    // γ: native combine on a large buffer
    let n = 1 << 20;
    let mut acc = vec![1.0f32; n];
    let other = vec![0.5f32; n];
    let mut samples = Vec::new();
    for _ in 0..reps.max(3) {
        let t0 = Instant::now();
        op.combine(&mut acc, &other);
        samples.push(t0.elapsed().as_secs_f64() / n as f64);
    }
    let gamma = median(samples).max(1e-13);
    CostModel::new(alpha.max(1e-9), beta, gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;
    use crate::collectives::run_schedule_threads;
    use crate::datatypes::BlockPartition;
    use crate::ops::SumOp;
    use crate::sim::simulate;
    use std::sync::Arc;

    #[test]
    fn calibration_yields_sane_magnitudes() {
        let m = calibrate_transport(&SumOp, 2);
        // channel hop on this box: somewhere between 100 ns and 1 ms
        assert!(m.alpha > 1e-8 && m.alpha < 1e-3, "alpha {:.3e}", m.alpha);
        // per-element copy cost: under a microsecond per element, over 1e-12
        assert!(m.beta > 1e-12 && m.beta < 1e-6, "beta {:.3e}", m.beta);
        assert!(m.gamma > 1e-12 && m.gamma < 1e-6, "gamma {:.3e}", m.gamma);
    }

    #[test]
    fn calibrated_des_predicts_measured_allreduce_within_an_order() {
        // The point of calibration: absolute agreement within ~one order
        // of magnitude (thread scheduling noise on 1 core is large).
        let model = calibrate_transport(&SumOp, 2);
        let p = 4;
        let mels = 1 << 16;
        let part = BlockPartition::regular(p, mels);
        let sched = Algorithm::parse("ar").unwrap().schedule(p);
        let inputs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; mels]).collect();
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let _ = run_schedule_threads(&sched, &part, Arc::new(SumOp), inputs.clone());
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let des = simulate(&sched, &part, &model).total;
        let ratio = best / des;
        assert!(
            (0.1..=100.0).contains(&ratio),
            "measured {best:.6} vs calibrated DES {des:.6} (ratio {ratio:.1})"
        );
    }
}
