//! Seeded schedule corruption — proof that the verifier bites.
//!
//! Each [`Mutation`] injects one semantically distinct corruption class
//! into a valid schedule; the audit passes must catch every one with a
//! diagnostic from [`Mutation::expected_codes`]. `ccoll audit` and the
//! `analysis_verifier` test suite both run this harness and hard-fail on
//! any silent corruption.

use crate::schedule::{RecvAction, Schedule};
use crate::util::rng::SplitMix64;

/// One injectable corruption class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// Remove one send together with its matching recv: a contribution
    /// silently never arrives.
    DropTransfer,
    /// Re-point one recv at a different origin rank: the round's
    /// matching is broken.
    RetargetRecv,
    /// Swap the block ranges of two transfers in the same round (both
    /// sides, so the round still matches): the right data flows to the
    /// wrong blocks.
    SwapBlockRanges,
    /// Flip a `Store` recv into a `Combine`: a contribution is applied
    /// twice.
    DuplicateContribution,
    /// Append a replay of an existing combine round: every one of its
    /// contributions arrives again.
    ReplayRound,
}

impl Mutation {
    pub const ALL: [Mutation; 5] = [
        Mutation::DropTransfer,
        Mutation::RetargetRecv,
        Mutation::SwapBlockRanges,
        Mutation::DuplicateContribution,
        Mutation::ReplayRound,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Mutation::DropTransfer => "drop-transfer",
            Mutation::RetargetRecv => "retarget-recv",
            Mutation::SwapBlockRanges => "swap-block-ranges",
            Mutation::DuplicateContribution => "duplicate-contribution",
            Mutation::ReplayRound => "replay-round",
        }
    }

    /// The diagnostic codes ([`super::AnalysisError::code`]) an audit may
    /// legitimately report for this corruption — anything else (or no
    /// error at all) is a verifier hole.
    pub fn expected_codes(&self) -> &'static [&'static str] {
        match self {
            // Dataflow runs before the count envelope, so a dropped
            // transfer surfaces as the contribution it loses (or, for
            // data-movement cells, the stale one it leaves behind).
            Mutation::DropTransfer => &["lost-contribution", "wrong-contribution"],
            Mutation::RetargetRecv => &[
                "recv-peer-mismatch",
                "send-peer-mismatch",
                "unmatched-send",
                "unmatched-recv",
            ],
            Mutation::SwapBlockRanges => {
                &["duplicate-contribution", "lost-contribution", "wrong-contribution"]
            }
            Mutation::DuplicateContribution => &["duplicate-contribution"],
            Mutation::ReplayRound => &["duplicate-contribution", "round-count"],
        }
    }
}

/// Apply `m` to `sched`, picking the corruption site from `seed`.
/// Returns `false` when the schedule offers no target for this class
/// (e.g. no `Store` recv to flip in a pure reduce-scatter) — the
/// schedule is then unchanged.
pub fn apply(sched: &mut Schedule, m: Mutation, seed: u64) -> bool {
    let mut rng = SplitMix64::new(seed);
    let p = sched.p;
    match m {
        Mutation::DropTransfer => {
            let sites = send_sites(sched);
            if sites.is_empty() {
                return false;
            }
            let (k, r) = sites[rng.next_below(sites.len())];
            let peer = sched.rounds[k].steps[r].send.unwrap().peer;
            sched.rounds[k].steps[r].send = None;
            sched.rounds[k].steps[peer].recv = None;
            true
        }
        Mutation::RetargetRecv => {
            if p < 3 {
                return false; // no third rank to mis-name
            }
            let sites: Vec<(usize, usize)> = sched
                .rounds
                .iter()
                .enumerate()
                .flat_map(|(k, round)| {
                    round
                        .steps
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| s.recv.is_some())
                        .map(move |(r, _)| (k, r))
                })
                .collect();
            if sites.is_empty() {
                return false;
            }
            let (k, r) = sites[rng.next_below(sites.len())];
            let recv = sched.rounds[k].steps[r].recv.as_mut().unwrap();
            let mut wrong = (recv.peer + 1) % p;
            if wrong == r {
                wrong = (wrong + 1) % p;
            }
            recv.peer = wrong;
            true
        }
        Mutation::SwapBlockRanges => {
            // Need one round with two transfers carrying different ranges.
            let mut rounds: Vec<usize> = (0..sched.rounds.len()).collect();
            shuffle(&mut rounds, &mut rng);
            for k in rounds {
                let senders: Vec<usize> = sched.rounds[k]
                    .steps
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.send.is_some())
                    .map(|(r, _)| r)
                    .collect();
                if senders.len() < 2 {
                    continue;
                }
                let ia = rng.next_below(senders.len());
                let a = senders[ia];
                let b = senders[(ia + 1) % senders.len()];
                let sa = sched.rounds[k].steps[a].send.unwrap();
                let sb = sched.rounds[k].steps[b].send.unwrap();
                if sa.blocks == sb.blocks {
                    continue;
                }
                // Swap both sides so the round still matches structurally.
                sched.rounds[k].steps[a].send.as_mut().unwrap().blocks = sb.blocks;
                sched.rounds[k].steps[b].send.as_mut().unwrap().blocks = sa.blocks;
                sched.rounds[k].steps[sa.peer].recv.as_mut().unwrap().blocks = sb.blocks;
                sched.rounds[k].steps[sb.peer].recv.as_mut().unwrap().blocks = sa.blocks;
                return true;
            }
            false
        }
        Mutation::DuplicateContribution => {
            let sites: Vec<(usize, usize)> = sched
                .rounds
                .iter()
                .enumerate()
                .flat_map(|(k, round)| {
                    round
                        .steps
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| {
                            s.recv.is_some_and(|rv| rv.action == RecvAction::Store)
                        })
                        .map(move |(r, _)| (k, r))
                })
                .collect();
            if sites.is_empty() {
                return false;
            }
            let (k, r) = sites[rng.next_below(sites.len())];
            sched.rounds[k].steps[r].recv.as_mut().unwrap().action = RecvAction::Combine;
            true
        }
        Mutation::ReplayRound => {
            let combine_rounds: Vec<usize> = sched
                .rounds
                .iter()
                .enumerate()
                .filter(|(_, round)| {
                    round.steps.iter().any(|s| {
                        s.recv.is_some_and(|rv| rv.action == RecvAction::Combine)
                    })
                })
                .map(|(k, _)| k)
                .collect();
            if combine_rounds.is_empty() {
                return false;
            }
            let k = combine_rounds[rng.next_below(combine_rounds.len())];
            let replay = sched.rounds[k].clone();
            sched.rounds.push(replay);
            true
        }
    }
}

fn send_sites(sched: &Schedule) -> Vec<(usize, usize)> {
    sched
        .rounds
        .iter()
        .enumerate()
        .flat_map(|(k, round)| {
            round
                .steps
                .iter()
                .enumerate()
                .filter(|(_, s)| s.send.is_some())
                .map(move |(r, _)| (k, r))
        })
        .collect()
}

fn shuffle(v: &mut [usize], rng: &mut SplitMix64) {
    for i in (1..v.len()).rev() {
        v.swap(i, rng.next_below(i + 1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{audit_schedule, expectation, Semantics};
    use crate::collectives::Algorithm;
    use crate::datatypes::BlockPartition;
    use crate::topology::skips::SkipScheme;

    /// Every corruption class, over several seeds and both circulant
    /// collectives, must be caught with one of its named diagnostics.
    #[test]
    fn every_mutation_class_is_caught_and_named() {
        let p = 22;
        let part = BlockPartition::regular(p, 2 * p);
        for alg in [
            Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
            Algorithm::CirculantAllreduce(SkipScheme::HalvingUp),
        ] {
            let (sem, env) = expectation(&alg, p);
            for m in Mutation::ALL {
                let mut applied = 0;
                for seed in 0..8u64 {
                    let mut sched = alg.schedule(p);
                    if !apply(&mut sched, m, seed) {
                        continue;
                    }
                    applied += 1;
                    let err = audit_schedule(&sched, sem, &env, &[&part]).expect_err(
                        &format!("{}: mutation {} seed {seed} not caught", alg.name(), m.name()),
                    );
                    assert!(
                        m.expected_codes().contains(&err.code()),
                        "{}: mutation {} seed {seed} caught as {:?}, expected one of {:?}",
                        alg.name(),
                        m.name(),
                        err.code(),
                        m.expected_codes()
                    );
                }
                // duplicate-contribution needs a Store recv, which only
                // the allreduce's allgather phase has.
                if alg == Algorithm::CirculantAllreduce(SkipScheme::HalvingUp)
                    || m != Mutation::DuplicateContribution
                {
                    assert!(applied > 0, "{}: mutation {} never applied", alg.name(), m.name());
                }
            }
        }
    }

    #[test]
    fn mutations_are_deterministic_per_seed() {
        let alg = Algorithm::CirculantAllreduce(SkipScheme::HalvingUp);
        let mut a = alg.schedule(13);
        let mut b = alg.schedule(13);
        assert!(apply(&mut a, Mutation::DropTransfer, 42));
        assert!(apply(&mut b, Mutation::DropTransfer, 42));
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn unmutated_schedule_still_audits_clean() {
        let alg = Algorithm::CirculantAllreduce(SkipScheme::HalvingUp);
        let (sem, env) = expectation(&alg, 13);
        let part = BlockPartition::regular(13, 26);
        // An inapplicable mutation must leave the schedule untouched.
        let mut sched = Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp).schedule(13);
        assert!(!apply(&mut sched, Mutation::DuplicateContribution, 7));
        audit_schedule(&alg.schedule(13), sem, &env, &[&part]).unwrap();
    }
}
