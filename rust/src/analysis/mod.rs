//! Static schedule & protocol verifier — the single verification entry
//! point for every `(Schedule, BlockPartition, p)` this library builds.
//!
//! Four passes, every one with typed diagnostics ([`AnalysisError`], each
//! variant carrying a stable [`AnalysisError::code`]):
//!
//! 1. **Structure / round matching** — [`Schedule::validate`]: every send
//!    has the unique recv that accepts it over the same global blocks and
//!    vice versa, so the synchronous round execution cannot deadlock.
//! 2. **Exactly-once dataflow** — [`dataflow::check_dataflow`]: abstract
//!    interpretation tracking, per `(rank, block)` cell, the multiset of
//!    contributing input vectors through every round; proves each result
//!    block is the full p-way reduction (or exact copy, for data-movement
//!    collectives) with no duplicate, lost or foreign contribution, and
//!    reports whether ⊕ must commute.
//! 3. **Paper-optimality envelope** — [`check_optimality`]: per-rank
//!    send/recv/combine block counts are *exactly* `p−1` and the round
//!    count exactly `⌈log₂ p⌉` for the circulant generators (Theorems 1
//!    and 2; baselines get their own expected envelopes from
//!    [`expectation`]).
//! 4. **Aliasing** — [`check_aliasing`]: statically prove the send/recv
//!    working-vector views carved in `collectives::exec` are disjoint per
//!    step (block level *and* element level under the actual partition),
//!    emitting a per-step [`TierMap`] the executor consults for its
//!    zero-copy rendezvous verdict instead of recomputing overlap tests.
//!
//! [`audit_algorithm`] runs all four for a shipped [`Algorithm`];
//! [`audit_plan`] is the `PlanCache` build-time hook (on in debug builds,
//! opt-in via `CCOLL_AUDIT_PLANS` in release); `ccoll audit` sweeps
//! algorithms × p × partition shapes and exercises the [`mutate`] harness
//! to prove the verifier actually bites.

pub mod dataflow;
pub mod mutate;

pub use dataflow::{
    check_dataflow, paper_example_terms, run_symbolic, verify_allreduce, verify_reduce_scatter,
    DataflowReport, Expr,
};

use crate::collectives::Algorithm;
use crate::datatypes::BlockPartition;
use crate::schedule::{Schedule, ScheduleError};
use crate::util::ceil_log2;

/// A typed verifier diagnostic. `Display` renders the human message; the
/// stable machine name comes from [`AnalysisError::code`] (what `ccoll
/// audit --audit.json` reports and the mutation-catch tests assert on).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum AnalysisError {
    /// Round matching / structural validity (pass 1).
    #[error(transparent)]
    Structure(#[from] ScheduleError),
    #[error("{name}: {got} rounds, expected exactly {want} (p={p})")]
    RoundCount { name: String, p: usize, got: usize, want: usize },
    #[error("{name}: rank {rank} {counter} = {got}, expected exactly {want} (p={p})")]
    BlockCount { name: String, p: usize, rank: usize, counter: &'static str, got: usize, want: usize },
    #[error(
        "{name}: rank {rank} block {block}: contribution of rank {source} \
         appears {got} times — duplicate contribution"
    )]
    DuplicateContribution { name: String, rank: usize, block: usize, source: usize, got: usize },
    #[error("{name}: rank {rank} block {block}: contribution of rank {source} never arrives — lost contribution")]
    LostContribution { name: String, rank: usize, block: usize, source: usize },
    #[error("{name}: rank {rank} block {block}: holds contribution of rank {source}, which does not belong here")]
    WrongContribution { name: String, rank: usize, block: usize, source: usize },
    #[error(
        "{name}: rank {rank} round {round}: send/recv block ranges are \
         disjoint but their element views overlap — aliasing contract broken"
    )]
    AliasViolation { name: String, rank: usize, round: usize },
    #[error(
        "{name}: rank {rank} round {round}: send and recv block ranges \
         overlap — rendezvous-unsafe step in a schedule class the paper \
         guarantees fully zero-copy eligible"
    )]
    RendezvousRegression { name: String, rank: usize, round: usize },
}

impl AnalysisError {
    /// Stable machine-readable diagnostic code.
    pub fn code(&self) -> &'static str {
        match self {
            AnalysisError::Structure(e) => e.code(),
            AnalysisError::RoundCount { .. } => "round-count",
            AnalysisError::BlockCount { .. } => "block-count",
            AnalysisError::DuplicateContribution { .. } => "duplicate-contribution",
            AnalysisError::LostContribution { .. } => "lost-contribution",
            AnalysisError::WrongContribution { .. } => "wrong-contribution",
            AnalysisError::AliasViolation { .. } => "alias-violation",
            AnalysisError::RendezvousRegression { .. } => "rendezvous-regression",
        }
    }
}

/// What the final state of a correct schedule must look like — drives the
/// exactly-once dataflow pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Semantics {
    /// `state[r][r]` is the full p-way reduction for every rank r.
    ReduceScatter,
    /// Every cell of every rank is the full p-way reduction.
    Allreduce,
    /// Precondition: rank r holds finished block r. Postcondition: every
    /// cell `(r, g)` holds exactly block-owner g's input.
    Allgather,
    /// Every cell at `root` is the full p-way reduction (other ranks
    /// unconstrained).
    ReduceToRoot { root: usize },
    /// Every cell of every rank holds exactly `root`'s input.
    BcastFromRoot { root: usize },
    /// Semantics not derivable from the algorithm name — run only the
    /// structure, envelope and aliasing passes.
    Unknown,
}

/// Expected resource envelope for one `(algorithm, p)` pair. `None`
/// fields are unconstrained (rooted trees have per-rank-varying counts;
/// fold-based baselines have data-dependent round structure).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Envelope {
    /// Exact round count (`⌈log₂ p⌉` for the circulant generators).
    pub rounds: Option<usize>,
    /// Exact per-rank blocks sent (Theorem 1/2: `p−1` resp. `2(p−1)`).
    pub blocks_sent: Option<usize>,
    pub blocks_recv: Option<usize>,
    /// Exact per-rank ⊕ applications in blocks (`p−1`).
    pub blocks_combined: Option<usize>,
    /// Every step must be zero-copy (rendezvous) eligible — true for all
    /// circulant schedules (§3's in-place condition σ_{k−1} ≤ 2σ_k makes
    /// each round's send and recv ranges disjoint).
    pub rendezvous_all: bool,
}

/// The paper-stated (or baseline-expected) envelope and result semantics
/// for a shipped algorithm at a given `p`.
pub fn expectation(alg: &Algorithm, p: usize) -> (Semantics, Envelope) {
    let pm1 = p.saturating_sub(1);
    let logp = ceil_log2(p.max(1)) as usize;
    let circulant_rounds = |s: &crate::topology::skips::SkipScheme| {
        s.skips(p).map(|v| v.len()).ok()
    };
    match alg {
        Algorithm::CirculantReduceScatter(s) => (
            Semantics::ReduceScatter,
            Envelope {
                rounds: circulant_rounds(s),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(pm1),
                rendezvous_all: true,
            },
        ),
        Algorithm::CirculantAllreduce(s) => (
            Semantics::Allreduce,
            Envelope {
                rounds: circulant_rounds(s).map(|q| 2 * q),
                blocks_sent: Some(2 * pm1),
                blocks_recv: Some(2 * pm1),
                blocks_combined: Some(pm1),
                rendezvous_all: true,
            },
        ),
        Algorithm::CirculantAllgather(s) => (
            Semantics::Allgather,
            Envelope {
                rounds: circulant_rounds(s),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(0),
                rendezvous_all: true,
            },
        ),
        Algorithm::RingReduceScatter => (
            Semantics::ReduceScatter,
            Envelope {
                rounds: Some(pm1),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(pm1),
                ..Default::default()
            },
        ),
        Algorithm::RingAllreduce => (
            Semantics::Allreduce,
            Envelope {
                rounds: Some(2 * pm1),
                blocks_sent: Some(2 * pm1),
                blocks_recv: Some(2 * pm1),
                blocks_combined: Some(pm1),
                ..Default::default()
            },
        ),
        Algorithm::RingAllgather => (
            Semantics::Allgather,
            Envelope {
                rounds: Some(pm1),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(0),
                ..Default::default()
            },
        ),
        // Power-of-two only: log₂ p rounds, volume-optimal like Alg. 1.
        Algorithm::RecursiveHalvingReduceScatter => (
            Semantics::ReduceScatter,
            Envelope {
                rounds: Some(logp),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(pm1),
                ..Default::default()
            },
        ),
        // Fold rounds (non-power-of-two p) give these per-rank-varying
        // counts and full-vector exchanges — semantics + matching +
        // aliasing only.
        Algorithm::RecursiveDoublingAllreduce => (Semantics::Allreduce, Envelope::default()),
        Algorithm::RabenseifnerAllreduce => (Semantics::Allreduce, Envelope::default()),
        Algorithm::BinomialReduce { root } => {
            (Semantics::ReduceToRoot { root: *root }, Envelope { rounds: Some(logp), ..Default::default() })
        }
        Algorithm::BinomialBcast { root } => {
            (Semantics::BcastFromRoot { root: *root }, Envelope { rounds: Some(logp), ..Default::default() })
        }
        Algorithm::BinomialAllreduce => (
            Semantics::Allreduce,
            Envelope { rounds: Some(2 * logp), ..Default::default() },
        ),
        Algorithm::BruckAllgather => (
            Semantics::Allgather,
            Envelope {
                rounds: Some(logp),
                blocks_sent: Some(pm1),
                blocks_recv: Some(pm1),
                blocks_combined: Some(0),
                ..Default::default()
            },
        ),
    }
}

/// Pass 3: check the schedule's round count and per-rank block counters
/// against an [`Envelope`]. Block counts are partition-independent, so
/// this derives them under a synthetic uniform partition.
pub fn check_optimality(schedule: &Schedule, env: &Envelope) -> Result<(), AnalysisError> {
    let p = schedule.p;
    if let Some(want) = env.rounds {
        if schedule.num_rounds() != want {
            return Err(AnalysisError::RoundCount {
                name: schedule.name.clone(),
                p,
                got: schedule.num_rounds(),
                want,
            });
        }
    }
    if env.blocks_sent.is_none() && env.blocks_recv.is_none() && env.blocks_combined.is_none() {
        return Ok(());
    }
    let part = BlockPartition::uniform(p, 1);
    for (rank, c) in schedule.counters(&part).iter().enumerate() {
        for (counter, got, want) in [
            ("blocks_sent", c.blocks_sent, env.blocks_sent),
            ("blocks_recv", c.blocks_recv, env.blocks_recv),
            ("blocks_combined", c.blocks_combined, env.blocks_combined),
        ] {
            if let Some(want) = want {
                if got != want {
                    return Err(AnalysisError::BlockCount {
                        name: schedule.name.clone(),
                        p,
                        rank,
                        counter,
                        got,
                        want,
                    });
                }
            }
        }
    }
    Ok(())
}

/// Per-(round, rank) zero-copy eligibility, statically proven by the
/// aliasing pass at plan-build time. The executor's rendezvous verdict
/// consults this instead of recomputing the block-overlap test per step;
/// by construction each entry equals `RankStep::rendezvous_safe` (the
/// executor debug-asserts the agreement).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierMap {
    safe: Vec<Vec<bool>>,
}

impl TierMap {
    /// Whether `(round, rank)` may use the zero-copy rendezvous tier.
    /// Out-of-range queries are trivially safe (idle/absent steps).
    pub fn rendezvous_ok(&self, round: usize, rank: usize) -> bool {
        self.safe.get(round).and_then(|r| r.get(rank)).copied().unwrap_or(true)
    }

    pub fn all_safe(&self) -> bool {
        self.safe.iter().all(|r| r.iter().all(|&b| b))
    }

    /// `(rendezvous-eligible steps, total steps)` over the whole map.
    pub fn safe_counts(&self) -> (usize, usize) {
        let total = self.safe.iter().map(|r| r.len()).sum();
        let safe = self.safe.iter().map(|r| r.iter().filter(|&&b| b).count()).sum();
        (safe, total)
    }
}

/// Compute the per-step tier eligibility map (block-level — exactly the
/// predicate the executor would recompute per step).
pub fn tier_map(schedule: &Schedule) -> TierMap {
    TierMap {
        safe: schedule
            .rounds
            .iter()
            .map(|round| round.steps.iter().map(|s| s.rendezvous_safe(schedule.p)).collect())
            .collect(),
    }
}

/// Pass 4: aliasing. Statically prove that whenever a step's send and
/// recv block ranges are disjoint (the rendezvous precondition), the
/// *element* views `exec.rs` carves from the working vector under `part`
/// are disjoint too — i.e. the block-level predicate the unsafe
/// zero-copy tier trusts is sound for this partition. Returns the
/// [`TierMap`] of per-step verdicts.
pub fn check_aliasing(
    schedule: &Schedule,
    part: &BlockPartition,
) -> Result<TierMap, AnalysisError> {
    let p = schedule.p;
    let map = tier_map(schedule);
    let ranges_overlap = |a: &std::ops::Range<usize>, b: &std::ops::Range<usize>| {
        a.start < b.end && b.start < a.end
    };
    for (k, round) in schedule.rounds.iter().enumerate() {
        for (r, step) in round.steps.iter().enumerate() {
            let (Some(send), Some(recv)) = (&step.send, &step.recv) else { continue };
            if !map.rendezvous_ok(k, r) {
                continue; // pooled tier: views never alias by copy
            }
            let sb = send.blocks.normalized(p);
            let rb = recv.blocks.normalized(p);
            let (s1, s2) = part.circular_ranges(sb.start, sb.len);
            let (r1, r2) = part.circular_ranges(rb.start, rb.len);
            let send_views = [Some(s1), s2];
            let recv_views = [Some(r1), r2];
            for sv in send_views.iter().flatten() {
                for rv in recv_views.iter().flatten() {
                    if ranges_overlap(sv, rv) {
                        return Err(AnalysisError::AliasViolation {
                            name: schedule.name.clone(),
                            rank: r,
                            round: k,
                        });
                    }
                }
            }
        }
    }
    Ok(map)
}

/// What a full audit proved about one `(algorithm, p)` pair.
#[derive(Debug, Clone)]
pub struct AuditReport {
    pub name: String,
    pub p: usize,
    pub rounds: usize,
    pub dataflow: DataflowReport,
    /// `(rendezvous-eligible steps, total steps)` from the aliasing pass.
    pub tier_counts: (usize, usize),
    pub partitions_checked: usize,
}

/// Run every pass over one schedule: structure, exactly-once dataflow
/// (once — it is partition-independent), then optimality and aliasing
/// under each supplied partition. Every partition must have `part.p() ==
/// schedule.p`.
pub fn audit_schedule(
    schedule: &Schedule,
    sem: Semantics,
    env: &Envelope,
    parts: &[&BlockPartition],
) -> Result<AuditReport, AnalysisError> {
    schedule.validate()?;
    let dataflow = check_dataflow(schedule, sem)?;
    check_optimality(schedule, env)?;
    let mut tier_counts = (0, 0);
    for part in parts {
        let map = check_aliasing(schedule, part)?;
        if env.rendezvous_all {
            for (k, round) in schedule.rounds.iter().enumerate() {
                for (r, _) in round.steps.iter().enumerate() {
                    if !map.rendezvous_ok(k, r) {
                        return Err(AnalysisError::RendezvousRegression {
                            name: schedule.name.clone(),
                            rank: r,
                            round: k,
                        });
                    }
                }
            }
        }
        tier_counts = map.safe_counts();
    }
    if parts.is_empty() {
        tier_counts = tier_map(schedule).safe_counts();
    }
    Ok(AuditReport {
        name: schedule.name.clone(),
        p: schedule.p,
        rounds: schedule.num_rounds(),
        dataflow,
        tier_counts,
        partitions_checked: parts.len(),
    })
}

/// Audit a shipped [`Algorithm`] at `p` under the given partitions, with
/// its semantics and envelope derived from [`expectation`].
pub fn audit_algorithm(
    alg: &Algorithm,
    p: usize,
    parts: &[&BlockPartition],
) -> Result<AuditReport, AnalysisError> {
    let schedule = alg.schedule(p);
    let (sem, env) = expectation(alg, p);
    audit_schedule(&schedule, sem, &env, parts)
}

/// Every shipped algorithm auditable at `p` — what `ccoll audit` and the
/// property sweep iterate. `p = 1` restricts to the circulant generators
/// (the baselines assume `p ≥ 2`); recursive halving is power-of-two
/// only.
pub fn shipped_roster(p: usize) -> Vec<Algorithm> {
    use crate::topology::skips::SkipScheme as S;
    let mut v = Vec::new();
    for s in [S::HalvingUp, S::PowerOfTwo, S::Sqrt, S::FullyConnected] {
        v.push(Algorithm::CirculantReduceScatter(s.clone()));
        v.push(Algorithm::CirculantAllreduce(s.clone()));
        v.push(Algorithm::CirculantAllgather(s));
    }
    if p >= 2 {
        v.extend([
            Algorithm::RingReduceScatter,
            Algorithm::RingAllreduce,
            Algorithm::RingAllgather,
            Algorithm::RecursiveDoublingAllreduce,
            Algorithm::RabenseifnerAllreduce,
            Algorithm::BinomialAllreduce,
            Algorithm::BruckAllgather,
            Algorithm::BinomialReduce { root: 0 },
            Algorithm::BinomialBcast { root: p / 2 },
        ]);
        if p.is_power_of_two() {
            v.push(Algorithm::RecursiveHalvingReduceScatter);
        }
    }
    v
}

/// Audit a *pipelined* (chunked) execution of `alg`: an `m`-element
/// vector split into `chunk_elems`-element chunk epochs, each running the
/// algorithm's schedule over its own regular partition.
///
/// Chunk epochs share nothing beyond the `Tag{op, round}` wire
/// discipline — each chunk owns a disjoint sub-slice of the working
/// vector, its own round-offset tag space, and its own rendezvous
/// publishes/acks — so the whole-op proof composes from per-chunk
/// proofs: exactly-once contribution holds per chunk iff it holds for
/// the chunk's schedule over the chunk's partition, and aliasing safety
/// likewise. The remainder folds into the last chunk, so at most two
/// distinct chunk partitions arise; this audits each distinct one once
/// and returns a report per distinct chunk length.
pub fn audit_pipelined(
    alg: &Algorithm,
    p: usize,
    m: usize,
    chunk_elems: usize,
) -> Result<Vec<AuditReport>, AnalysisError> {
    let sizes = crate::collectives::pipeline_chunk_sizes(m, chunk_elems);
    let mut reports = Vec::new();
    let mut audited: Vec<usize> = Vec::new();
    for len in sizes {
        if audited.contains(&len) {
            continue;
        }
        audited.push(len);
        let part = BlockPartition::regular(p, len);
        reports.push(audit_algorithm(alg, p, &[&part])?);
    }
    Ok(reports)
}

/// Whether plan-build-time auditing is on: always in debug builds,
/// opt-in via `CCOLL_AUDIT_PLANS=1` in release.
pub fn audit_plans_enabled() -> bool {
    cfg!(debug_assertions) || crate::env_knobs::knobs().audit_plans
}

/// The `PlanCache` build-time hook: verify a just-built plan. The plan
/// key's algorithm name recovers semantics + envelope when it parses as
/// a shipped [`Algorithm`]; otherwise (derived/auxiliary schedules) the
/// structure and aliasing passes still run.
pub fn audit_plan(
    algorithm: &str,
    schedule: &Schedule,
    part: &BlockPartition,
) -> Result<(), AnalysisError> {
    let (sem, env) = match Algorithm::parse(algorithm) {
        Some(alg) => expectation(&alg, schedule.p),
        None => (Semantics::Unknown, Envelope::default()),
    };
    audit_schedule(schedule, sem, &env, &[part]).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::skips::SkipScheme;

    #[test]
    fn audit_passes_on_shipped_circulant_algorithms() {
        for p in [1usize, 2, 7, 22] {
            let part = BlockPartition::regular(p, 3 * p + 1);
            for alg in [
                Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
                Algorithm::CirculantAllreduce(SkipScheme::PowerOfTwo),
                Algorithm::CirculantAllgather(SkipScheme::Sqrt),
            ] {
                let rep = audit_algorithm(&alg, p, &[&part])
                    .unwrap_or_else(|e| panic!("{} p={p}: {e}", alg.name()));
                assert_eq!(rep.p, p);
                // The paper's schedules are fully zero-copy eligible.
                assert_eq!(rep.tier_counts.0, rep.tier_counts.1, "p={p}");
            }
        }
    }

    #[test]
    fn pipelined_audit_covers_each_distinct_chunk_partition() {
        let alg = Algorithm::CirculantAllreduce(SkipScheme::HalvingUp);
        // m=100, chunk=32 → chunks [32, 32, 36]: two distinct lengths.
        let reports = audit_pipelined(&alg, 5, 100, 32).unwrap();
        assert_eq!(reports.len(), 2);
        for rep in &reports {
            assert_eq!(rep.partitions_checked, 1);
            assert_eq!(rep.tier_counts.0, rep.tier_counts.1, "chunk epochs stay zero-copy");
        }
        // Degenerate geometry (chunk ≥ m/2) is a single plain partition.
        let reports = audit_pipelined(&alg, 5, 100, 64).unwrap();
        assert_eq!(reports.len(), 1);
        // Divisible case: one distinct length even with many chunks.
        let reports = audit_pipelined(&alg, 5, 128, 32).unwrap();
        assert_eq!(reports.len(), 1);
    }

    #[test]
    fn audit_plan_accepts_cache_vocabulary_names() {
        let p = 6;
        let part = BlockPartition::regular(p, 30);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = crate::collectives::allreduce_schedule(p, &skips);
        audit_plan("allreduce:halving-up", &sched, &part).unwrap();
        audit_plan("ar", &sched, &part).unwrap();
        // Unknown vocabulary still gets structure + aliasing.
        audit_plan("custom-thing", &sched, &part).unwrap();
    }

    #[test]
    fn round_count_regression_is_named() {
        let p = 8;
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let mut sched = crate::collectives::reduce_scatter_schedule(p, &skips);
        sched.rounds.push(crate::schedule::Round::idle(p));
        let (sem, env) = expectation(
            &Algorithm::CirculantReduceScatter(SkipScheme::HalvingUp),
            p,
        );
        let part = BlockPartition::regular(p, 16);
        let e = audit_schedule(&sched, sem, &env, &[&part]).unwrap_err();
        assert_eq!(e.code(), "round-count");
    }

    #[test]
    fn rendezvous_regression_is_named() {
        // Force a full-vector overlap round into a circulant schedule.
        use crate::schedule::{BlockRange, RankStep, Recv, RecvAction, Round, Transfer};
        let p = 2;
        let all = BlockRange::new(0, 2);
        let mut sched = crate::collectives::reduce_scatter_schedule(p, &[1]);
        sched.rounds.push(Round {
            steps: vec![
                RankStep {
                    send: Some(Transfer { peer: 1, blocks: all }),
                    recv: Some(Recv { peer: 1, blocks: all, action: RecvAction::Store }),
                },
                RankStep {
                    send: Some(Transfer { peer: 0, blocks: all }),
                    recv: Some(Recv { peer: 0, blocks: all, action: RecvAction::Store }),
                },
            ],
        });
        let env = Envelope { rendezvous_all: true, ..Default::default() };
        let part = BlockPartition::regular(p, 8);
        let e = audit_schedule(&sched, Semantics::Unknown, &env, &[&part]).unwrap_err();
        assert_eq!(e.code(), "rendezvous-regression");
    }

    #[test]
    fn tier_map_matches_executor_predicate() {
        for (alg, p) in [
            (Algorithm::CirculantAllreduce(SkipScheme::HalvingUp), 22usize),
            (Algorithm::RecursiveDoublingAllreduce, 6),
            (Algorithm::BinomialAllreduce, 5),
        ] {
            let sched = alg.schedule(p);
            let map = tier_map(&sched);
            for (k, round) in sched.rounds.iter().enumerate() {
                for (r, step) in round.steps.iter().enumerate() {
                    assert_eq!(
                        map.rendezvous_ok(k, r),
                        step.rendezvous_safe(p),
                        "{} p={p} round {k} rank {r}",
                        alg.name()
                    );
                }
            }
            assert_eq!(map.all_safe(), sched.rendezvous_safe());
        }
    }
}
