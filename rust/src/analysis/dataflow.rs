//! Exactly-once dataflow: symbolic execution of schedules at block
//! granularity.
//!
//! Runs a schedule with a symbolic ⊕ that records the exact combine tree
//! per `(rank, global block)` cell. This is how we reproduce the paper's
//! §2.1 worked example (p = 22, processor 21) term for term, and how the
//! verifier proves that every result block is the full p-way reduction
//! with **no duplicate and no lost contribution** — the abstract
//! interpretation behind [`check_dataflow`]. The same run also answers
//! the §2.1 commutativity question: ⊕ needs to commute exactly when some
//! result's leaves are not a contiguous circular run of ranks.

use std::fmt;
use std::rc::Rc;

use crate::schedule::{RecvAction, Schedule};

use super::{AnalysisError, Semantics};

/// A symbolic partial result: either one processor's input block, or a
/// combine of two partials (bracketing preserved).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    /// `x_i`: the input block of processor `i` (for the destination under
    /// consideration).
    Leaf(usize),
    Add(Rc<Expr>, Rc<Expr>),
}

impl Expr {
    pub fn leaf(i: usize) -> Rc<Expr> {
        Rc::new(Expr::Leaf(i))
    }

    pub fn add(a: Rc<Expr>, b: Rc<Expr>) -> Rc<Expr> {
        Rc::new(Expr::Add(a, b))
    }

    /// All leaf indices, in bracketing (left-to-right) order.
    pub fn leaves(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.collect(&mut out);
        out
    }

    fn collect(&self, out: &mut Vec<usize>) {
        match self {
            Expr::Leaf(i) => out.push(*i),
            Expr::Add(a, b) => {
                a.collect(out);
                b.collect(out);
            }
        }
    }

    /// Depth of the combine tree (leaf = 0).
    pub fn depth(&self) -> usize {
        match self {
            Expr::Leaf(_) => 0,
            Expr::Add(a, b) => 1 + a.depth().max(b.depth()),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Leaf(i) => write!(f, "x{i}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
        }
    }
}

/// Symbolically execute `schedule` (blocks only, no element data).
///
/// `state[r][g]` is rank `r`'s current partial for *global block* `g`;
/// initialized to `Leaf(r)` — rank r's own contribution to destination g.
/// Returns the final state. For a reduce-scatter schedule, `state[r][r]`
/// is the full reduction tree for destination r written over contributor
/// indices *relative to nothing* — leaves are absolute rank ids.
///
/// Precondition: the schedule passes [`Schedule::validate`] (every recv
/// has its matching send). [`check_dataflow`] enforces this; direct
/// callers on hand-built schedules should validate first.
pub fn run_symbolic(schedule: &Schedule) -> Vec<Vec<Rc<Expr>>> {
    let p = schedule.p;
    let mut state: Vec<Vec<Rc<Expr>>> =
        (0..p).map(|r| (0..p).map(|_| Expr::leaf(r)).collect()).collect();
    for round in &schedule.rounds {
        // Snapshot senders first (simultaneous rounds).
        let mut incoming: Vec<Option<(usize, Vec<Rc<Expr>>)>> = vec![None; p];
        for (r, step) in round.steps.iter().enumerate() {
            if let Some(send) = &step.send {
                let b = send.blocks.normalized(p);
                let payload: Vec<Rc<Expr>> =
                    (0..b.len).map(|j| state[r][(b.start + j) % p].clone()).collect();
                incoming[send.peer] = Some((r, payload));
            }
        }
        for (r, step) in round.steps.iter().enumerate() {
            if let Some(recv) = &step.recv {
                let (from, payload) =
                    incoming[r].take().unwrap_or_else(|| panic!("no payload for rank {r}"));
                assert_eq!(from, recv.peer, "symbolic: peer mismatch at rank {r}");
                let b = recv.blocks.normalized(p);
                assert_eq!(payload.len(), b.len);
                for (j, expr) in payload.into_iter().enumerate() {
                    let g = (b.start + j) % p;
                    match recv.action {
                        RecvAction::Combine => {
                            state[r][g] = Expr::add(state[r][g].clone(), expr);
                        }
                        RecvAction::Store => state[r][g] = expr,
                    }
                }
            }
        }
    }
    state
}

/// What the verifier proved about a schedule's dataflow.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DataflowReport {
    /// Max combine-tree depth over all checked result cells.
    pub max_depth: usize,
    /// Whether ⊕ must commute for this schedule to be correct: `false`
    /// iff every checked reduction accumulates its contributions in
    /// consecutive circular rank order (a rotation of `0..p`), which
    /// associativity alone absorbs (§2.1's fully-connected observation).
    pub commutativity_required: bool,
    /// Result cells actually checked (p for reduce-scatter, p² for
    /// allreduce/allgather, …).
    pub cells_checked: usize,
}

/// The expected contribution multiset for one checked result cell.
enum Want {
    /// Each of `0..p` exactly once — a full p-way reduction.
    Full,
    /// Exactly the single input of this rank — pure data movement.
    One(usize),
}

/// The exactly-once dataflow pass: abstract-interpret the schedule (via
/// [`run_symbolic`]) and prove that every result cell the semantics
/// constrains holds exactly the right multiset of input contributions —
/// no duplicate, no lost, no foreign contribution.
pub fn check_dataflow(
    schedule: &Schedule,
    sem: Semantics,
) -> Result<DataflowReport, AnalysisError> {
    // The symbolic runner (like the real executor) requires a
    // structurally matched schedule; surface violations as typed errors
    // instead of letting it panic.
    schedule.validate()?;
    let p = schedule.p;
    let state = run_symbolic(schedule);
    // (rank, block, expected multiset) for every constrained cell.
    let cells: Vec<(usize, usize, Want)> = match sem {
        Semantics::ReduceScatter => (0..p).map(|r| (r, r, Want::Full)).collect(),
        Semantics::Allreduce => {
            (0..p).flat_map(|r| (0..p).map(move |g| (r, g, Want::Full))).collect()
        }
        Semantics::Allgather => {
            (0..p).flat_map(|r| (0..p).map(move |g| (r, g, Want::One(g)))).collect()
        }
        // Out-of-range roots cannot have produced a schedule; treat as
        // unconstrained rather than indexing out of bounds.
        Semantics::ReduceToRoot { root } if root < p => {
            (0..p).map(|g| (root, g, Want::Full)).collect()
        }
        Semantics::BcastFromRoot { root } if root < p => {
            (0..p).flat_map(|r| (0..p).map(move |g| (r, g, Want::One(root)))).collect()
        }
        Semantics::ReduceToRoot { .. } | Semantics::BcastFromRoot { .. } => Vec::new(),
        Semantics::Unknown => Vec::new(),
    };
    let mut report = DataflowReport { cells_checked: cells.len(), ..Default::default() };
    for (r, g, want) in cells {
        let expr = &state[r][g];
        let leaves = expr.leaves();
        let mut count = vec![0usize; p];
        for &leaf in &leaves {
            count[leaf] += 1;
        }
        let expected = |i: usize| match want {
            Want::Full => 1usize,
            Want::One(w) => usize::from(i == w),
        };
        // Duplicates first, then foreign contributions, then losses —
        // a fixed order so each corruption class maps to one diagnostic.
        for i in 0..p {
            if expected(i) > 0 && count[i] > expected(i) {
                return Err(AnalysisError::DuplicateContribution {
                    name: schedule.name.clone(),
                    rank: r,
                    block: g,
                    source: i,
                    got: count[i],
                });
            }
        }
        for i in 0..p {
            if expected(i) == 0 && count[i] > 0 {
                return Err(AnalysisError::WrongContribution {
                    name: schedule.name.clone(),
                    rank: r,
                    block: g,
                    source: i,
                });
            }
        }
        for i in 0..p {
            if count[i] < expected(i) {
                return Err(AnalysisError::LostContribution {
                    name: schedule.name.clone(),
                    rank: r,
                    block: g,
                    source: i,
                });
            }
        }
        report.max_depth = report.max_depth.max(expr.depth());
        // A multi-leaf reduction needs ⊕ to commute unless its leaves are
        // a contiguous circular run (leaves[j] = leaves[0] + j mod p).
        if leaves.len() > 1 {
            let canonical = leaves
                .iter()
                .enumerate()
                .all(|(j, &leaf)| leaf == (leaves[0] + j) % p);
            if !canonical {
                report.commutativity_required = true;
            }
        }
    }
    Ok(report)
}

/// Verify that a reduce-scatter schedule is symbolically correct: for every
/// rank `r`, the final partial for block `r` contains every rank exactly
/// once. Returns the max combine-tree depth over ranks.
pub fn verify_reduce_scatter(schedule: &Schedule) -> Result<usize, AnalysisError> {
    check_dataflow(schedule, Semantics::ReduceScatter).map(|rep| rep.max_depth)
}

/// Verify an allreduce schedule: every rank's every block must contain all
/// contributors exactly once.
pub fn verify_allreduce(schedule: &Schedule) -> Result<(), AnalysisError> {
    check_dataflow(schedule, Semantics::Allreduce).map(|_| ())
}

/// The paper's §2.1 example: the round-by-round bracketing of `W` at
/// processor `r` for `p` processors, rendered with `x_i` denoting
/// processor `i`'s contribution — returns one summand string per round.
pub fn paper_example_terms(schedule: &Schedule, r: usize) -> Vec<String> {
    let p = schedule.p;
    // Re-run symbolically, recording what arrives *into block r at rank r*
    // each round.
    let mut state: Vec<Vec<Rc<Expr>>> =
        (0..p).map(|rk| (0..p).map(|_| Expr::leaf(rk)).collect()).collect();
    let mut terms = vec![format!("x{r}")];
    for round in &schedule.rounds {
        let mut incoming: Vec<Option<(usize, usize, Vec<Rc<Expr>>)>> = vec![None; p];
        for (rk, step) in round.steps.iter().enumerate() {
            if let Some(send) = &step.send {
                let b = send.blocks.normalized(p);
                let payload: Vec<Rc<Expr>> =
                    (0..b.len).map(|j| state[rk][(b.start + j) % p].clone()).collect();
                incoming[send.peer] = Some((rk, b.start, payload));
            }
        }
        for rk in 0..p {
            if let Some(recv) = &round.steps[rk].recv {
                let (_, start, payload) = incoming[rk].take().unwrap();
                let b = recv.blocks.normalized(p);
                debug_assert_eq!(start % p, b.start);
                for (j, expr) in payload.into_iter().enumerate() {
                    let g = (b.start + j) % p;
                    if recv.action == RecvAction::Combine {
                        if rk == r && g == r {
                            terms.push(format!("{expr}"));
                        }
                        state[rk][g] = Expr::add(state[rk][g].clone(), expr);
                    } else {
                        state[rk][g] = expr;
                    }
                }
            }
        }
    }
    terms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::topology::skips::SkipScheme;

    #[test]
    fn p22_example_bracketing_matches_paper() {
        // Paper §2.1, r = 21, p = 22, skips 11,6,3,2,1. The five received
        // partial sums, in round order:
        let skips = SkipScheme::HalvingUp.skips(22).unwrap();
        let sched = reduce_scatter_schedule(22, &skips);
        let terms = paper_example_terms(&sched, 21);
        assert_eq!(terms[0], "x21");
        assert_eq!(terms[1], "x10"); // round 1 from 21−11
        assert_eq!(terms[2], "(x15 + x4)"); // round 2 from 21−6
        assert_eq!(terms[3], "((x18 + x7) + (x12 + x1))"); // round 3 from 21−3
        // round 4 from 21−2: contributors {19,8,13,2,16,5} (paper line 4)
        assert_eq!(terms[4], "(((x19 + x8) + (x13 + x2)) + (x16 + x5))");
        // round 5 from 21−1: contributors {20,9,14,3,17,6,11,0} (line 5)
        assert_eq!(terms[5], "(((x20 + x9) + (x14 + x3)) + ((x17 + x6) + (x11 + x0)))");
        // and all 22 contributors appear exactly once overall
        let mut leaves: Vec<usize> = Vec::new();
        for t in &terms[1..] {
            // crude re-parse via digits
            let mut cur = String::new();
            for ch in t.chars() {
                if ch.is_ascii_digit() {
                    cur.push(ch);
                } else if !cur.is_empty() {
                    leaves.push(cur.parse().unwrap());
                    cur.clear();
                }
            }
            if !cur.is_empty() {
                leaves.push(cur.parse().unwrap());
            }
        }
        leaves.push(21);
        leaves.sort_unstable();
        assert_eq!(leaves, (0..22).collect::<Vec<_>>());
    }

    #[test]
    fn symbolic_rs_correct_many_p() {
        for p in 2..=64usize {
            for scheme in [SkipScheme::HalvingUp, SkipScheme::PowerOfTwo, SkipScheme::Sqrt] {
                let skips = scheme.skips(p).unwrap();
                let sched = reduce_scatter_schedule(p, &skips);
                let depth = verify_reduce_scatter(&sched)
                    .unwrap_or_else(|e| panic!("{} p={p}: {e}", scheme.name()));
                assert!(depth <= 2 * skips.len(), "depth {depth} too deep p={p}");
            }
        }
    }

    #[test]
    fn symbolic_allreduce_correct() {
        for p in [2usize, 3, 10, 22, 31] {
            let skips = SkipScheme::HalvingUp.skips(p).unwrap();
            let sched = allreduce_schedule(p, &skips);
            verify_allreduce(&sched).unwrap_or_else(|e| panic!("p={p}: {e}"));
        }
    }

    #[test]
    fn all_ranks_same_bracketing_shape() {
        // Commutativity discussion (§2.1): all processors perform the
        // reductions in the same (rank-relative) order. Check: the combine
        // tree of W at rank r, with leaves rewritten relative to r, is
        // identical for all r.
        let p = 22;
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched = reduce_scatter_schedule(p, &skips);
        let state = run_symbolic(&sched);
        let rel = |r: usize| -> Vec<usize> {
            state[r][r].leaves().iter().map(|&x| (r + p - x) % p).collect()
        };
        let base = rel(0);
        for r in 1..p {
            assert_eq!(rel(r), base, "rank {r} reduces in a different order");
        }
    }

    #[test]
    fn fully_connected_reduces_in_consecutive_rank_order() {
        // §2.1 / §1: "with a fully connected network, the algorithm can
        // also work for non-commutative operators [11]". Reason: with
        // skips p−1, p−2, …, 1, every received partial is a single leaf
        // and W accumulates them in consecutive (mod p) rank order
        // starting at r — a rotation of the canonical order, which [11]'s
        // bookkeeping absorbs. Verify the order symbolically, and that
        // the pass reports commutativity as NOT required.
        for p in [3usize, 8, 13] {
            let skips = SkipScheme::FullyConnected.skips(p).unwrap();
            let sched = reduce_scatter_schedule(p, &skips);
            let state = run_symbolic(&sched);
            for r in 0..p {
                let leaves = state[r][r].leaves();
                let want: Vec<usize> = (0..p).map(|i| (r + i) % p).collect();
                assert_eq!(leaves, want, "p={p} r={r}");
                // and the bracketing is a pure left fold (depth = p−1):
                assert_eq!(state[r][r].depth(), p - 1);
            }
            let rep = check_dataflow(&sched, Semantics::ReduceScatter).unwrap();
            assert!(!rep.commutativity_required, "p={p}");
        }
        // Halving-up does NOT have this property (the paper's point that
        // commutativity is genuinely required there).
        let skips = SkipScheme::HalvingUp.skips(8).unwrap();
        let sched = reduce_scatter_schedule(8, &skips);
        let state = run_symbolic(&sched);
        let leaves = state[0][0].leaves();
        assert_ne!(leaves, (0..8).collect::<Vec<_>>(), "halving-up is not rank-ordered");
        let rep = check_dataflow(&sched, Semantics::ReduceScatter).unwrap();
        assert!(rep.commutativity_required);
    }

    #[test]
    fn symbolic_baselines_too() {
        use crate::collectives::baselines::ring::ring_reduce_scatter_schedule;
        for p in [2usize, 5, 9, 16] {
            verify_reduce_scatter(&ring_reduce_scatter_schedule(p)).unwrap();
        }
    }

    #[test]
    fn dataflow_names_the_defect() {
        // Lost contribution: drop one transfer pair from a valid schedule.
        let p = 8;
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let mut sched = reduce_scatter_schedule(p, &skips);
        let peer = sched.rounds[0].steps[0].send.unwrap().peer;
        sched.rounds[0].steps[0].send = None;
        sched.rounds[0].steps[peer].recv = None;
        let e = check_dataflow(&sched, Semantics::ReduceScatter).unwrap_err();
        assert_eq!(e.code(), "lost-contribution");

        // Duplicate contribution: flip an allgather Store into a Combine.
        let mut ar = allreduce_schedule(p, &skips);
        let q = ar.rounds.len();
        for step in ar.rounds[q - 1].steps.iter_mut() {
            if let Some(recv) = step.recv.as_mut() {
                recv.action = RecvAction::Combine;
            }
        }
        let e = check_dataflow(&ar, Semantics::Allreduce).unwrap_err();
        assert_eq!(e.code(), "duplicate-contribution");

        // Structure errors surface as typed diagnostics, not panics.
        let mut broken = reduce_scatter_schedule(p, &skips);
        broken.rounds[0].steps[0].recv = None;
        let e = check_dataflow(&broken, Semantics::ReduceScatter).unwrap_err();
        assert_eq!(e.code(), "unmatched-send");
    }
}
