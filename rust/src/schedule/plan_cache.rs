//! Plan cache: memoized `(algorithm, p, partition, dtype) → Arc<Plan>`.
//!
//! The paper's Algorithm 1/2 schedules are pure functions of
//! `(p, partition, skip scheme)` — yet the pre-engine code regenerated
//! them on every collective call. For one-shot benches that is noise; for
//! the ROADMAP's serving workload (thousands of repeated collectives per
//! second through one [`crate::engine::CollectiveEngine`]) it is pure
//! waste on the submission path. A [`PlanCache`] memoizes built plans
//! behind `Arc`s so repeated collectives pay one hash lookup, and both the
//! engine's submission path and every [`crate::coordinator::Communicator`]
//! route their schedules through one.
//!
//! Keys carry a 64-bit partition *fingerprint*
//! ([`crate::datatypes::BlockPartition::fingerprint`]) rather than the
//! whole offset vector; every hit verifies the stored partition against
//! the requested one, so a fingerprint collision degrades to a (counted)
//! miss instead of ever serving a wrong schedule.
//!
//! Hit/miss counters are surfaced two ways: globally per cache
//! ([`PlanCache::stats`], what `ccoll serve` and the engine report) and
//! per rank through `transport::Counters::{plan_hits, plan_misses}`
//! (credited by the communicator, aggregated by
//! [`crate::coordinator::RunMetrics`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::datatypes::{BlockPartition, DType};
use crate::schedule::Schedule;

/// A fully-resolved execution plan: the schedule plus the partition it was
/// built for, shared behind one `Arc` so every rank of every repeated
/// collective reuses a single allocation.
#[derive(Debug)]
pub struct Plan {
    pub schedule: Schedule,
    pub part: BlockPartition,
}

/// Cache key — what a schedule is a pure function of, plus the dtype (the
/// schedule itself is dtype-independent, but plans are handed to typed
/// executors; keying by dtype keeps one cached plan from pinning another
/// dtype's partition object and makes the counters per-dtype honest).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Canonical algorithm name (e.g. `allreduce:halving-up`,
    /// `binomial-scatter:3`) — free-form so non-`Algorithm` schedules
    /// (rooted scatter/gather trees) can participate. `Arc<str>` so
    /// steady-state callers (communicator, engine) key repeated lookups
    /// with a refcount bump instead of a fresh `String` allocation.
    pub algorithm: Arc<str>,
    pub p: usize,
    /// [`BlockPartition::fingerprint`] of the exact block layout.
    pub partition: u64,
    pub dtype: DType,
}

impl PlanKey {
    pub fn new(
        algorithm: impl Into<Arc<str>>,
        p: usize,
        part: &BlockPartition,
        dtype: DType,
    ) -> Self {
        Self { algorithm: algorithm.into(), p, partition: part.fingerprint(), dtype }
    }
}

/// Snapshot of a cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to build (including the never-cached collision
    /// fallback).
    pub misses: u64,
    /// Entries dropped to stay under the capacity bound.
    pub evictions: u64,
    /// Distinct plans currently held.
    pub entries: usize,
}

/// Default capacity bound ([`PlanCache::with_capacity`]): generous for
/// any realistic working set of collective geometries, while keeping a
/// long-lived serving engine fed arbitrary payload sizes from growing
/// its plan map without limit.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 1024;

/// Thread-safe memo of built plans. Cheap to share: clone the `Arc` the
/// launcher/engine wraps it in.
///
/// Bounded: when full, inserting a new plan evicts an arbitrary resident
/// entry (plans are cheap to rebuild, so a simple bound beats LRU
/// bookkeeping on the submission path; evictions are counted in
/// [`PlanCacheStats`]).
#[derive(Debug)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<Plan>>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache holding at most `capacity` plans (0 = unbounded).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            plans: Mutex::new(HashMap::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up `key`, building (and caching) the schedule on a miss.
    /// Returns the shared plan and whether this lookup was a hit.
    ///
    /// The build runs *outside* the lock, so concurrent ranks missing on
    /// the same key may build in parallel; the first insert wins and the
    /// losers adopt it (each still counts as a miss — they did the work).
    /// A fingerprint collision (stored partition ≠ requested) returns a
    /// fresh, **uncached** plan rather than ever serving a wrong schedule.
    pub fn get_or_build(
        &self,
        key: PlanKey,
        part: &BlockPartition,
        build: impl FnOnce() -> Schedule,
    ) -> (Arc<Plan>, bool) {
        let mut collision = false;
        if let Some(plan) = self.plans.lock().unwrap().get(&key) {
            if plan.part == *part {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (plan.clone(), true);
            }
            collision = true;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(Plan { schedule: build(), part: part.clone() });
        if collision {
            // Never cached: the slot is owned by the other layout.
            return (plan, false);
        }
        let mut map = self.plans.lock().unwrap();
        if let Some(existing) = map.get(&key) {
            // Raced with another builder; adopt the winner if its layout
            // matches (it does unless we also collided).
            if existing.part == *part {
                return (existing.clone(), false);
            }
            return (plan, false);
        }
        // Capacity bound: evict an arbitrary resident entry before
        // inserting (see the type docs for why not LRU).
        if self.capacity > 0 && map.len() >= self.capacity {
            if let Some(victim) = map.keys().next().cloned() {
                map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        map.insert(key, plan.clone());
        (plan, false)
    }

    /// Counter + size snapshot.
    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.plans.lock().unwrap().len(),
        }
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::generators::{allreduce_schedule, reduce_scatter_schedule};
    use crate::topology::skips::SkipScheme;

    fn build(p: usize, m: usize, allreduce: bool) -> (BlockPartition, Schedule) {
        let part = BlockPartition::regular(p, m);
        let skips = SkipScheme::HalvingUp.skips(p).unwrap();
        let sched =
            if allreduce { allreduce_schedule(p, &skips) } else { reduce_scatter_schedule(p, &skips) };
        (part, sched)
    }

    #[test]
    fn second_lookup_is_a_hit_and_shares_the_arc() {
        let cache = PlanCache::new();
        let (part, sched) = build(6, 60, true);
        let key = PlanKey::new("allreduce:halving-up", 6, &part, DType::F32);
        let (a, hit_a) = cache.get_or_build(key.clone(), &part, || sched.clone());
        let (b, hit_b) = cache.get_or_build(key, &part, || panic!("must not rebuild"));
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b), "hit must share the cached Arc");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn differing_partition_dtype_or_algorithm_miss() {
        let cache = PlanCache::new();
        let (part, sched) = build(5, 50, true);
        let (part2, _) = build(5, 55, true); // different layout
        let mk = |alg: &str, part: &BlockPartition, dt| PlanKey::new(alg, 5, part, dt);
        cache.get_or_build(mk("allreduce:halving-up", &part, DType::F32), &part, || sched.clone());
        // same algorithm, different partition → miss
        let (_, hit) = cache.get_or_build(
            mk("allreduce:halving-up", &part2, DType::F32),
            &part2,
            || sched.clone(),
        );
        assert!(!hit);
        // same partition, different dtype → miss
        let (_, hit) =
            cache.get_or_build(mk("allreduce:halving-up", &part, DType::I64), &part, || sched.clone());
        assert!(!hit);
        // same partition + dtype, different algorithm/scheme → miss
        let (_, hit) =
            cache.get_or_build(mk("allreduce:pow2", &part, DType::F32), &part, || sched.clone());
        assert!(!hit);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 4, 4));
        // and each of those now hits
        let (_, hit) =
            cache.get_or_build(mk("allreduce:pow2", &part, DType::F32), &part, || unreachable!());
        assert!(hit);
    }

    #[test]
    fn fingerprint_collision_never_serves_a_wrong_plan() {
        // Forge a key whose fingerprint belongs to a *different* layout:
        // the cache must detect the mismatch and build fresh, uncached.
        let cache = PlanCache::new();
        let (part_a, sched_a) = build(4, 40, false);
        let (part_b, sched_b) = build(4, 44, false);
        let key_a = PlanKey::new("rs", 4, &part_a, DType::F32);
        cache.get_or_build(key_a.clone(), &part_a, || sched_a.clone());
        // Same key bits, but the caller's partition is B's layout.
        let (plan, hit) = cache.get_or_build(key_a, &part_b, || sched_b.clone());
        assert!(!hit);
        assert_eq!(plan.part, part_b, "must carry the requested layout");
        assert_eq!(cache.stats().entries, 1, "collision fallback is never cached");
    }

    #[test]
    fn capacity_bound_evicts_instead_of_growing() {
        let cache = PlanCache::with_capacity(4);
        for m in 0..10usize {
            let (part, sched) = build(3, 30 + m, true);
            cache.get_or_build(PlanKey::new("ar", 3, &part, DType::F32), &part, || sched.clone());
        }
        let s = cache.stats();
        assert!(s.entries <= 4, "{} entries exceed the capacity bound", s.entries);
        assert_eq!(s.evictions, 6, "10 distinct plans through a 4-slot cache");
        assert_eq!(s.misses, 10);
        // An evicted key simply rebuilds (a miss), never errors.
        let (part, sched) = build(3, 30, true);
        let (plan, _) = cache.get_or_build(PlanKey::new("ar", 3, &part, DType::F32), &part, || {
            sched.clone()
        });
        assert_eq!(plan.part, part);
    }

    #[test]
    fn fingerprints_distinguish_layouts_with_equal_totals() {
        let a = BlockPartition::from_counts(&[2, 3, 5]);
        let b = BlockPartition::from_counts(&[3, 2, 5]);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), BlockPartition::from_counts(&[2, 3, 5]).fingerprint());
    }
}
